"""Online partition-autotuner benchmark (the "tune" section).

Three measurements on a skewed-degree (power-law) serving mix, merged as
a ``tuning`` key into ``benchmarks/results/serve_stats.json`` for
``scripts/check_bench.py``:

* **offline** — exhaustive one-shot candidate ranking via
  :func:`repro.tuning.tune_offline` (what ``scripts/tune_partition.py``
  prints), recording the best candidate's speedup over the default
  config.
* **online** — a :class:`GraphServeEngine` with a live
  :class:`~repro.tuning.PlanTuner`: sustained traffic on a hot graph
  until shadow measurements promote a non-default config through the
  version chain, then steady-state dispatch walls of the TUNED engine vs
  a fresh DEFAULT-config engine on identical requests
  (``tuned_speedup >= 1.0`` is the nightly gate).
* **shadow overhead** — p99 request latency of a concurrent open-loop
  mix with shadowing forced on every dispatch vs tuning disabled; the
  invariant is that candidates are measured OFF the critical path, so
  the ratio stays ~1 (gated <= 1.05 on parallel hardware; on a
  single-core host the shadow worker steals the only CPU, so the ratio
  is informational there).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.graph import gcn_normalize
from repro.data.graphs import make_power_law_graph
from repro.serve import GraphServeEngine
from repro.tuning import PlanTuner, tune_offline

from .common import csv_row
from .serve_graphs import RESULTS_JSON


def _steady_wall(engine, gid: str, x, reps: int = 24) -> float:
    """Median of 3 sequential-serve walls (engine already warm)."""
    engine.serve_one(gid, x)
    walls = []
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(reps):
            engine.serve_one(gid, x)
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


def _p99_traffic(engine, feats: Dict, n_threads: int = 4,
                 per_thread: int = 24) -> float:
    names = list(feats)
    futs: List = []
    lock = threading.Lock()

    def submitter(t):
        local = []
        for k in range(per_thread):
            gid = names[(t + k) % len(names)]
            local.append(engine.submit(gid, feats[gid]))
            time.sleep(0.001)
        with lock:
            futs.extend(local)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for f in futs:
        f.result()
    return float(engine.stats()["sched_p99_latency_s"])


def run(budget_edges: int = 200_000, feat: int = 16) -> List[str]:
    rows: List[str] = []
    # sized so the default config's 409 blocks pad badly into the 512
    # bucket while half-slab's 499 fit it snugly — a skewed-degree mix
    # with genuine (measured ~3x) config headroom for the tuner to find
    n = max(2_000, min(18_000, budget_edges // 2))
    g = gcn_normalize(make_power_law_graph(n, 2 * n, seed=3))
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(g.n_cols, feat)), jnp.float32)
    results: Dict = {}

    # ---------------------------------------------------------- offline
    off = tune_offline(g, feat_dim=feat, repeats=3)
    results["offline"] = {
        "best_label": off["best"]["label"] if off["best"] else None,
        "best_speedup": off["best_speedup"],
        "base_time_s": off["base"]["time_s"],
    }
    rows.append(csv_row("tune/offline_best",
                        (off["best"]["time_s"] * 1e6 if off["best"]
                         else 0.0),
                        f"label={results['offline']['best_label']};"
                        f"speedup={off['best_speedup']:.2f}x"))

    # ----------------------------------------------------------- online
    tuner = PlanTuner(hot_rate=5.0, shadow_fraction=0.5, win_streak=2,
                      min_improvement=0.01, max_trials=10, halflife_s=2.0)
    tuned_eng = GraphServeEngine(backend="blocked", tuner=tuner,
                                 max_wait_ms=1.0)
    tuned_eng.register_graph("hot", g)
    promoted_at = None
    for i in range(400):
        tuned_eng.serve_one("hot", x)
        # pace the stream so the shadow worker measures candidates on an
        # otherwise-idle host (on a single-core box back-to-back requests
        # contend with the shadow thread and poison its timings)
        time.sleep(0.02)
        if tuned_eng.stats()["tuned_promotions"] >= 1:
            promoted_at = i + 1
            break
    # let any in-flight shadow drain, then measure the tuned steady state
    time.sleep(0.3)
    tuned_wall = _steady_wall(tuned_eng, "hot", x)
    st = tuned_eng.stats()
    tuned_plan = tuned_eng.plan_for("hot")

    base_eng = GraphServeEngine(backend="blocked", max_wait_ms=1.0)
    base_eng.register_graph("hot", g)
    base_wall = _steady_wall(base_eng, "hot", x)

    results["online"] = {
        "promotions": int(st["tuned_promotions"]),
        "promoted_after_requests": promoted_at,
        "tuned_label": (tuned_plan.tuned or {}).get("label"),
        "tuned_config_default": tuned_plan.config == base_eng.config,
        "shadow_dispatches": int(st["shadow_dispatches"]),
        "shadow_skipped": int(st["shadow_skipped"]),
        "comparisons": int(st["tuner_comparisons"]),
        "tuned_wall_s": tuned_wall,
        "default_wall_s": base_wall,
        "tuned_speedup": base_wall / tuned_wall if tuned_wall else 0.0,
    }
    rows.append(csv_row(
        "tune/online_steady_state", tuned_wall * 1e6,
        f"promotions={results['online']['promotions']};"
        f"label={results['online']['tuned_label']};"
        f"speedup={results['online']['tuned_speedup']:.2f}x"))
    base_eng.close()
    tuned_eng.close()

    # -------------------------------------------------- shadow overhead
    # small recurring graphs, concurrent submitters; tuner candidates are
    # shadowed on EVERY dispatch of every hot graph (fraction=1.0, huge
    # trial budget so the stream never goes quiet) vs no tuner at all
    graphs = {f"m{i}": gcn_normalize(make_power_law_graph(
        400 + 60 * i, 2500 + 200 * i, seed=20 + i)) for i in range(3)}
    feats = {k: jnp.asarray(rng.normal(size=(gg.n_cols, feat)), jnp.float32)
             for k, gg in graphs.items()}

    def _mk(with_tuner: bool):
        t = (PlanTuner(hot_rate=1.0, shadow_fraction=1.0, win_streak=10**6,
                       min_improvement=10.0, max_trials=10**6)
             if with_tuner else None)
        e = GraphServeEngine(backend="blocked", tuner=t, max_wait_ms=2.0,
                             max_graphs_per_batch=4)
        for k, gg in graphs.items():
            e.register_graph(k, gg)
        _p99_traffic(e, feats)          # warm (compile + heat the tuner)
        return e

    p99 = {}
    for label, with_tuner in (("off", False), ("on", True)):
        e = _mk(with_tuner)
        p99[label] = min(_p99_traffic(e, feats) for _ in range(3))
        if with_tuner:
            results["shadow"] = {
                "shadow_dispatches": int(e.stats()["shadow_dispatches"]),
                "shadow_skipped": int(e.stats()["shadow_skipped"]),
            }
        e.close()
    results.setdefault("shadow", {})
    results["shadow"].update({
        "p99_without_s": p99["off"],
        "p99_with_s": p99["on"],
        "p99_ratio": p99["on"] / p99["off"] if p99["off"] else 0.0,
    })
    rows.append(csv_row("tune/shadow_p99", p99["on"] * 1e6,
                        f"ratio_vs_no_tuner="
                        f"{results['shadow']['p99_ratio']:.3f}"))

    # ------------------------------------------------------------ merge
    merged = {}
    if os.path.exists(RESULTS_JSON):
        try:
            with open(RESULTS_JSON) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    merged["tuning"] = results
    os.makedirs(os.path.dirname(RESULTS_JSON), exist_ok=True)
    with open(RESULTS_JSON, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    rows.append(csv_row("tune/stats", 0.0,
                        f"json={os.path.relpath(RESULTS_JSON)}"))
    return rows
