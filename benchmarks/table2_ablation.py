"""Paper Table II / Figs. 7-8 analogues: the two ablations.

(1) Block-level partition vs warp-level partition (both with full-width
    feature tiling): runtime ratio + the structural quantities the paper
    credits for the win — metadata bytes (Eq. 1) and issue-slot utilization.
(2) Combined warp vs inner-loop column traversal: the non-combined variant
    processes the feature dimension in 32-wide slices with an outer loop
    (GNNAdvisor-style), breaking lane-width alignment.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import degree_sort_csr
from repro.core.partition import (balance_stats, block_level_partition,
                                  get_partition_patterns, metadata_bytes,
                                  warp_level_partition)
from repro.core.spmm import make_accel_spmm

from .common import csv_row, staged_graph, time_call

GRAPHS = ["Collab", "Arxiv", "Pubmed", "Artist", "TWITTER-Partial"]
COL_RANGES = [(16, 32), (33, 64), (65, 96), (97, 128)]


def _sliced_call(op, X, slice_w=32):
    """Inner-loop column traversal: one SpMM per 32-wide feature slice."""
    import jax.numpy as jnp
    outs = []
    for s in range(0, X.shape[1], slice_w):
        outs.append(op(X[:, s:s + slice_w]))
    return jnp.concatenate(outs, axis=1)


def run(budget_edges=200_000, quiet=False):
    import jax.numpy as jnp
    rows = []
    blk_ratios, cw_ratios = {r: [] for r in COL_RANGES}, {r: [] for r in COL_RANGES}
    for name in GRAPHS:
        g, scale = staged_graph(name, budget_edges)
        op = make_accel_spmm(g, with_baselines=True)
        # structural quantities (exact, hardware-independent)
        gs = degree_sort_csr(g)
        bp = block_level_partition(gs, get_partition_patterns(12, 32, "paper"))
        wp = warp_level_partition(g, 32)
        meta_ratio = metadata_bytes(bp) / metadata_bytes(wp)
        util_b = balance_stats(bp)["reserved_utilization"]
        util_w = balance_stats(wp)["utilization"]
        rows.append(csv_row(f"table2/{name}/structure", 0.0,
                            f"metadata_ratio={meta_ratio:.3f};"
                            f"util_block={util_b:.3f};util_warp={util_w:.3f}"))
        for lo, hi in COL_RANGES:
            F = (lo + hi) // 2 // 8 * 8 or 16
            X = jnp.asarray(np.random.default_rng(0).normal(size=(g.n_cols, F)),
                            dtype=jnp.float32)
            t_blk = time_call(lambda: op(X, backend="blocked"))
            t_wrp = time_call(lambda: op(X, backend="warp"))
            t_cw_off = time_call(lambda: _sliced_call(
                lambda Xs: op(Xs, backend="blocked"), X))
            blk_ratios[(lo, hi)].append(t_wrp / t_blk)
            cw_ratios[(lo, hi)].append(t_cw_off / t_blk)
            rows.append(csv_row(
                f"table2/{name}/F{F}", t_blk,
                f"block_vs_warp={t_wrp/t_blk:.3f};"
                f"combined_vs_sliced={t_cw_off/t_blk:.3f}"))
    for (lo, hi) in COL_RANGES:
        b = np.asarray(blk_ratios[(lo, hi)])
        c = np.asarray(cw_ratios[(lo, hi)])
        rows.append(csv_row(
            f"table2/range[{lo},{hi}]", 0.0,
            f"block_speed_ratio_avg={b.mean()*100:.1f}%;max={b.max()*100:.1f}%;"
            f"min={b.min()*100:.1f}%;combined_warp_avg={c.mean()*100:.1f}%;"
            f"max={c.max()*100:.1f}%;min={c.min()*100:.1f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
