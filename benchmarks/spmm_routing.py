"""Backend-routing benchmark: resident vs windowed vs HBM-gather vs auto.

Three graph mixes straddle the routing thresholds (f32 defaults: resident
N_pad <= 4096, windowed <= 4 x 4096, hbm beyond — see ``router.py``):

  resident_mix   several small graphs, concatenated features fit VMEM
  windowed_mix   mid-size graphs whose concatenation needs 2 row windows
  hbm_mix        one sparse huge-column graph (the web-scale shape) + smalls

For each mix, every *legal* backend is timed through the fused batched path
(``spmm_batched``), plus ``auto``, which should match the best legal choice.
Backends whose forced run would exceed the VMEM budget emit a
``raises=VmemBudgetError`` row instead of a timing — that raise (rather
than a silent oversized compile) is the contract under test. Timings are
interpret-mode CPU numbers: regime *rankings* here reflect emulation cost,
not TPU DMA behavior; the row to watch is auto vs its chosen backend
(routing overhead ~= 0).
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core.graph import csr_from_edges, gcn_normalize
from repro.core.plan_cache import PartitionConfig, build_partition_plan
from repro.kernels.router import VmemBudgetError, route_spmm
from repro.kernels.spmm_batched import spmm_batched

from .common import csv_row, time_call

BACKENDS = ["pallas", "windowed", "hbm", "auto"]


def _rand_graph(n_rows: int, n_cols: int, nnz: int, seed: int):
    rng = np.random.default_rng(seed)
    src = np.sort(rng.integers(0, n_rows, nnz))
    dst = rng.integers(0, n_cols, nnz)
    return gcn_normalize(csr_from_edges(src, dst, n_cols))


def _mixes(feat: int):
    """(name, [(n_rows, n_cols, nnz), ...]) mixes around the boundaries."""
    return [
        # sum n_cols = 2_400 -> resident (<= 4096)
        ("resident_mix", [(800, 800, 3_000)] * 3),
        # sum n_cols = 7_200 -> windowed, 2 windows (4096 < N <= 16384)
        ("windowed_mix", [(2_400, 2_400, 6_000)] * 3),
        # sum n_cols = 19_200: one huge sparse graph tips the batch -> hbm
        ("hbm_mix", [(600, 18_000, 2_000), (600, 600, 2_000),
                     (600, 600, 2_000)]),
    ]


def run(budget_edges: int = 200_000, feat: int = 32) -> List[str]:
    rows: List[str] = []
    cfg = PartitionConfig()
    rng = np.random.default_rng(0)
    scale = min(1.0, budget_edges / 200_000)

    for mix_name, shapes in _mixes(feat):
        plans, xs = [], []
        for i, (n_r, n_c, nnz) in enumerate(shapes):
            g = _rand_graph(n_r, n_c, max(200, int(nnz * scale)), seed=i)
            plans.append(build_partition_plan(g, cfg))
            xs.append(jnp.asarray(rng.normal(size=(g.n_cols, feat)),
                                  jnp.float32))
        n_cat = sum(int(x.shape[0]) for x in xs)
        decision = route_spmm(n_cat, feat, int(plans[0].slabs["C"]),
                              int(plans[0].slabs["R"]))

        for backend in BACKENDS:
            def call(backend=backend):
                return spmm_batched([p.slabs for p in plans], xs,
                                    [p.n_rows for p in plans],
                                    backend=backend)
            try:
                us = time_call(call, warmup=1, iters=3)
            except VmemBudgetError:
                rows.append(csv_row(
                    f"routing/{mix_name}_{backend}", 0.0,
                    f"raises=VmemBudgetError;n_cat={n_cat};"
                    f"budget_rows={decision.window_rows}"))
                continue
            if backend == "auto":
                note = (f"exec={decision.backend};"
                        f"vmem~{decision.vmem_bytes // 1024}KiB")
            else:
                note = f"exec={backend}"
            rows.append(csv_row(f"routing/{mix_name}_{backend}", us,
                                f"{note};n_cat={n_cat}"))

        # grid-order experiment (ROADMAP): the resident kernel iterated
        # (block, feature-tile) vs (feature-tile, block). Outputs are
        # identical; on hardware ft_major keeps one X tile resident across
        # the whole block sweep. Interpret-mode timings only rank the
        # emulation; both orders are recorded for the real-TPU run.
        for order in ("block_major", "ft_major"):
            def call_order(order=order):
                return spmm_batched([p.slabs for p in plans], xs,
                                    [p.n_rows for p in plans],
                                    backend="pallas", grid_order=order)
            try:
                us = time_call(call_order, warmup=1, iters=3)
            except VmemBudgetError:
                break   # mix does not fit the resident kernel at all
            rows.append(csv_row(f"routing/{mix_name}_grid_{order}", us,
                                f"exec=resident;n_cat={n_cat}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(r)
