"""Benchmark harness entry point — one section per paper table/figure.

Emits ``name,us_per_call,derived`` CSV. Sections:
  fig5      overall SpMM comparison on the 18 Table-I graph analogues
  fig6      runtime vs RHS column dimension (16..128 + odd widths)
  table2    block-vs-warp partition + combined-warp ablations
  preproc   O(n) preprocessing scaling (paper §III-C)
  repair    streaming-update plan repair vs full rebuild at 0.1/1/10% nnz
            deltas (merges a "repair" key into
            benchmarks/results/serve_stats.json; nightly gates the 0.1%
            speedup >= 3x)
  serve     plan-cache amortization + batched multi-graph dispatch, plus
            the concurrent-submitter section (N threads of open-loop
            traffic: continuous-batching scheduler vs per-call dispatch;
            stats also land in benchmarks/results/serve_stats.json)
  routing   resident vs windowed vs HBM-gather vs auto at the VMEM
            boundaries (mixes that straddle the routing thresholds), and
            the resident kernel's block_major vs ft_major grid orders
  fleet     multi-device serving: FleetGraphEngine vs the single-device
            scheduler on the concurrent mix, plus the block-sharded giant
            graph with per-device balance (merges a "fleet" key into
            benchmarks/results/serve_stats.json; run with
            XLA_FLAGS=--xla_force_host_platform_device_count=8)
  multihost cross-host serving: a two-subprocess CPU fleet (REAL
            multi-process jax) routed by the placement directory —
            forwarded traffic + the collective global-mesh giant (merges
            a "multihost" key into benchmarks/results/serve_stats.json)
  tune      online partition autotuner: offline candidate ranking, the
            live shadow-measured promotion loop (steady-state tuned vs
            default dispatch), and the shadow p99-overhead check (merges
            a "tuning" key into benchmarks/results/serve_stats.json)
  sample    neighbor-sampling service: zipf seed-stream frontier hit rate,
            sampled-path throughput, full-fanout exactness vs the full
            graph on both backends, and the two-subprocess partitioned
            store with cross-partition frontier exchange (merges a
            "sampling" key into benchmarks/results/serve_stats.json;
            nightly gates with --require-sampling)
  moe       beyond-paper: block dispatch for MoE
  roofline  summary rows from the dry-run results (if present)
"""
from __future__ import annotations

import argparse
import json
import os


def _roofline_rows():
    from .common import csv_row
    path = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")
    rows = []
    if not os.path.exists(path):
        return [csv_row("roofline/missing", 0.0, "run repro.launch.dryrun first")]
    with open(path) as f:
        for rec in json.load(f):
            cell = f"{rec['arch']}x{rec['shape']}"
            if "skipped" in rec:
                rows.append(csv_row(f"roofline/{cell}", 0.0,
                                    f"skipped={rec['skipped']}"))
                continue
            if "error" in rec:
                rows.append(csv_row(f"roofline/{cell}", 0.0,
                                    f"ERROR={rec['error'][:80]}"))
                continue
            rl = rec.get("roofline")
            if rl:
                dom = rl["bottleneck"]
                rows.append(csv_row(
                    f"roofline/{cell}", rl[dom + "_s"] * 1e6,
                    f"bottleneck={dom};compute_s={rl['compute_s']:.4g};"
                    f"memory_s={rl['memory_s']:.4g};"
                    f"collective_s={rl['collective_s']:.4g};"
                    f"useful={rl['useful_ratio']:.3f}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,fig6,table2,preproc,repair,"
                         "serve,routing,fleet,multihost,tune,sample,moe,"
                         "roofline")
    ap.add_argument("--budget-edges", type=int, default=200_000)
    args = ap.parse_args()
    # multihost and sample spawn their own 2-process fleets, so they are
    # opt-in (not part of the default sweep: nightly CI runs them
    # explicitly)
    want = set(args.only.split(",")) if args.only else \
        {"fig5", "fig6", "table2", "preproc", "repair", "serve", "routing",
         "fleet", "tune", "moe", "roofline"}

    print("name,us_per_call,derived")
    if "fig5" in want:
        from .fig5_overall import run as fig5
        for r in fig5(budget_edges=args.budget_edges):
            print(r)
    if "fig6" in want:
        from .fig6_coldim import run as fig6
        for r in fig6(budget_edges=args.budget_edges):
            print(r)
    if "table2" in want:
        from .table2_ablation import run as t2
        for r in t2(budget_edges=args.budget_edges):
            print(r)
    if "preproc" in want:
        from .preprocessing import run as pp
        for r in pp():
            print(r)
    if "repair" in want:
        from .preprocessing import run_repair
        for r in run_repair():
            print(r)
    if "serve" in want:
        from .serve_graphs import run as serve
        for r in serve(budget_edges=args.budget_edges):
            print(r)
    if "routing" in want:
        from .spmm_routing import run as routing
        for r in routing(budget_edges=args.budget_edges):
            print(r)
    if "fleet" in want:
        from .fleet_serve import run as fleet
        for r in fleet(budget_edges=args.budget_edges):
            print(r)
    if "multihost" in want:
        from .multihost_serve import run as multihost
        for r in multihost(budget_edges=args.budget_edges):
            print(r)
    if "tune" in want:
        from .tune_partition import run as tune
        for r in tune(budget_edges=args.budget_edges):
            print(r)
    if "sample" in want:
        from .sampling_serve import run as sample
        for r in sample(budget_edges=args.budget_edges):
            print(r)
    if "moe" in want:
        from .moe_dispatch import run as moe
        for r in moe():
            print(r)
    if "roofline" in want:
        for r in _roofline_rows():
            print(r)


if __name__ == "__main__":
    main()
