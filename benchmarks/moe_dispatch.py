"""Beyond-paper integration benchmark: Accel-GCN block dispatch for MoE.

Compares the paper-technique grouped-GEMM dispatch (degree sort by expert +
fixed-block partition + 128-lane tiles) against the capacity-einsum dispatch
across routing skews, and reports the balance property: every block has
identical FLOPs, and no token is dropped.
"""
from __future__ import annotations

from .common import csv_row, time_call


def run(quiet=False):
    import jax, jax.numpy as jnp
    from repro.models.moe import init_moe, moe_block, moe_capacity

    rows = []
    B, T, D, FF, E, k = 4, 256, 128, 256, 16, 4
    p = init_moe(jax.random.PRNGKey(0), D, FF, E, dtype=jnp.float32)
    for skew_name, bias in [("balanced", 0.0), ("skewed", 6.0)]:
        p2 = dict(p)
        bias_vec = jnp.zeros((E,)).at[0].set(bias)
        p2["router"] = p["router"] + bias_vec
        x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
        f_blk = jax.jit(lambda x: moe_block(p2, x, top_k=k, n_experts=E,
                                            m_tile=64, use_pallas=False)[0])
        f_cap = jax.jit(lambda x: moe_capacity(p2, x, top_k=k, n_experts=E,
                                               capacity_factor=1.25)[0])
        f_cap_big = jax.jit(lambda x: moe_capacity(p2, x, top_k=k, n_experts=E,
                                                   capacity_factor=8.0)[0])
        t_blk = time_call(f_blk, x)
        t_cap = time_call(f_cap, x)
        t_cap_d = time_call(f_cap_big, x)
        # dropped fraction under capacity dispatch
        drop = float(jnp.abs(f_cap(x) - f_cap_big(x)).max())
        rows.append(csv_row(f"moe/{skew_name}/block", t_blk,
                            f"dropless=True"))
        rows.append(csv_row(f"moe/{skew_name}/capacity1.25", t_cap,
                            f"max_token_delta_vs_dropless={drop:.3g}"))
        rows.append(csv_row(f"moe/{skew_name}/capacity8.0", t_cap_d, ""))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
