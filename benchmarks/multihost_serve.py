"""Cross-host fleet benchmark: two REAL JAX processes, directory-routed.

Spawns a two-subprocess CPU fleet (4 fake devices per host, the CI smoke
topology) via :func:`repro.distributed.multihost.run_cpu_fleet` and
measures the serving paths the multihost engine adds:

  multihost/two_host_serve    rank 0 submits open-loop traffic for every
                              registered graph; remote-owned groups
                              forward to rank 1 over the data plane
                              (acceptance: the directory spreads plans —
                              each host owns >= 1 — and forwarding
                              actually happened)
  multihost/global_giant      both ranks enter the COLLECTIVE global-mesh
                              dispatch of one giant graph (blocks
                              round-robin over all 8 global devices,
                              cross-host psum)

Results merge into ``benchmarks/results/serve_stats.json`` under the
``"multihost"`` key; nightly CI asserts the placement spread.
"""
from __future__ import annotations

import json
import os
import textwrap
from typing import Dict, List

from .common import csv_row
from .serve_graphs import RESULTS_JSON

_WORKER = textwrap.dedent("""
    import json, os, sys, threading, time
    sys.path.insert(0, "src")
    import numpy as np
    from repro.distributed.multihost import initialize_multihost
    ctx = initialize_multihost()
    import jax, jax.numpy as jnp
    from jax.experimental import multihost_utils
    from repro.core.graph import gcn_normalize
    from repro.data.graphs import make_power_law_graph
    from repro.serve.fleet import MultihostGraphEngine
    from repro.serve.graph_engine import GraphRequest

    budget_edges = int(os.environ.get("REPRO_MH_BENCH_BUDGET", "40000"))
    engine = MultihostGraphEngine(context=ctx, backend="blocked",
                                  max_graphs_per_batch=4,
                                  max_batch_requests=16, max_wait_ms=3.0)
    served_evt = threading.Event()
    engine.server.register("phase-served", lambda _p: served_evt.set())
    engine.connect_peers()

    rng = np.random.default_rng(7)
    graphs, feats, owned = {}, {}, 0
    for i in range(6):
        gid = f"svc{i}"
        g = gcn_normalize(make_power_law_graph(
            200 + 31 * i, min(1400 + 90 * i, budget_edges // 6), seed=i))
        graphs[gid] = g
        owned += int(engine.register_graph(gid, g) is not None)
        feats[gid] = jnp.asarray(rng.normal(size=(g.n_cols, 8)), jnp.float32)
    multihost_utils.sync_global_devices("registered")

    serve_wall = 0.0
    if ctx.process_index == 0:
        reqs = [GraphRequest(g, feats[g]) for g in graphs] * 4
        engine.serve(reqs[:len(graphs)])          # warm both hosts
        t0 = time.perf_counter()
        engine.serve(reqs)
        serve_wall = time.perf_counter() - t0
        engine.peers[1].request("phase-served", None)
    else:
        assert served_evt.wait(300)

    # collective giant across the global mesh
    n_big = max(4000, min(8000, budget_edges // 5))
    big = gcn_normalize(make_power_law_graph(n_big, budget_edges, seed=99))
    engine.register_graph("big", big)
    xb = jnp.asarray(rng.normal(size=(big.n_cols, 16)), jnp.float32)
    engine.serve_global("big", xb)                # warm (compile + prep)
    t0 = time.perf_counter()
    out = engine.serve_global("big", xb)
    giant_wall = time.perf_counter() - t0
    multihost_utils.sync_global_devices("done")

    st = engine.stats()
    engine.close()
    print(json.dumps({
        "rank": ctx.process_index,
        "owned_plans": owned,
        "serve_wall_s": serve_wall,
        "giant_wall_s": giant_wall,
        "requests_served": st["requests_served"],
        "forwarded": st["fleet_forwarded"],
        "remote_served": st["fleet_remote_served"],
        "host_placements": st["fleet_dir_host_placements"],
        "global_dispatches": st["fleet_global_dispatches"],
        "block_counts": st["fleet_block_counts"],
        "failovers": st["fleet_host_failovers"],
    }))
""")


def run(budget_edges: int = 200_000, num_processes: int = 2,
        n_local_devices: int = 4) -> List[str]:
    from repro.distributed.multihost import run_cpu_fleet

    rows: List[str] = []
    repo_root = os.path.join(os.path.dirname(__file__), "..")
    budget = min(budget_edges, 60_000)    # the fleet is 2 cold processes
    records = run_cpu_fleet(
        _WORKER,
        num_processes=num_processes, n_local_devices=n_local_devices,
        timeout_s=560, cwd=repo_root,
        extra_env={"REPRO_MH_BENCH_BUDGET": str(budget)})
    records.sort(key=lambda r: r["rank"])
    r0 = records[0]
    results: Dict = {
        "processes": num_processes,
        "devices_per_host": n_local_devices,
        "per_rank": records,
        "serve_wall_s": r0["serve_wall_s"],
        "requests": r0["requests_served"],
        "forwarded": r0["forwarded"],
        "host_placements": r0["host_placements"],
        "giant_wall_s": max(r["giant_wall_s"] for r in records),
        "block_counts": r0["block_counts"],
    }
    rows.append(csv_row(
        "multihost/two_host_serve", r0["serve_wall_s"] * 1e6,
        f"hosts={num_processes};requests={r0['requests_served']};"
        f"forwarded={r0['forwarded']};"
        f"placements={'|'.join(map(str, r0['host_placements']))};"
        f"failovers={sum(r['failovers'] for r in records)}"))
    counts = r0["block_counts"]
    bal = (max(counts) * len(counts) / sum(counts)
           if counts and sum(counts) else 0.0)
    rows.append(csv_row(
        "multihost/global_giant", results["giant_wall_s"] * 1e6,
        f"global_devices={num_processes * n_local_devices};"
        f"balance={bal:.3f};counts={'|'.join(map(str, counts))}"))

    merged = {}
    if os.path.exists(RESULTS_JSON):
        try:
            with open(RESULTS_JSON) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged["multihost"] = results
    os.makedirs(os.path.dirname(RESULTS_JSON), exist_ok=True)
    with open(RESULTS_JSON, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    rows.append(csv_row(
        "multihost/stats_json", 0.0,
        f"hosts={num_processes};json={os.path.relpath(RESULTS_JSON)}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(r)
