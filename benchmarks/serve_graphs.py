"""Serving benchmark: plan-cache amortization + batched multi-graph dispatch.

Rows emitted:
  serve/plan_cold_<name>      one full preprocessing pass (cache miss)
  serve/plan_warm_<name>      the same request again (cache hit)
  serve/spmm_individual       G graphs dispatched one kernel call each
  serve/spmm_batched          the same G graphs in ONE fused kernel call
  serve/engine_throughput     steady-state engine rows/s over mixed traffic

Caveat on this CPU harness: the G "individual" dispatches are independent
XLA computations and overlap across host cores, while the fused call only
has intra-op parallelism — so batching shows little CPU-side win here. The
batched path exists for the dispatch-bound regime (one compilation, one
launch, one scatter on TPU); the unambiguous CPU-visible wins are the
plan_warm rows (cache) and the requests/batch amortization in the engine.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan_cache import PartitionConfig, PlanCache
from repro.kernels.ops import spmm_auto
from repro.kernels.spmm_batched import spmm_batched
from repro.serve.graph_engine import GraphRequest, GraphServeEngine

from .common import csv_row, staged_graph, time_call

SERVE_GRAPHS = ["Pubmed", "Artist", "Collab", "Arxiv"]


def run(budget_edges: int = 200_000, feat: int = 64) -> List[str]:
    rows: List[str] = []
    cfg = PartitionConfig()
    cache = PlanCache(capacity=16)
    rng = np.random.default_rng(0)

    graphs, plans, xs = {}, [], []
    for name in SERVE_GRAPHS:
        g, _ = staged_graph(name, budget_edges=budget_edges // len(SERVE_GRAPHS))
        graphs[name] = g

        t0 = time.perf_counter()
        plan = cache.get_or_build(g, cfg)
        cold = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        cache.get_or_build(g, cfg)
        warm = (time.perf_counter() - t0) * 1e6
        rows.append(csv_row(f"serve/plan_cold_{name}", cold,
                            f"n={g.n_rows};nnz={g.nnz};blocks={plan.num_blocks}"))
        rows.append(csv_row(f"serve/plan_warm_{name}", warm,
                            f"speedup={cold / max(warm, 1e-9):.0f}x"))
        plans.append(plan)
        xs.append(jnp.asarray(rng.normal(size=(g.n_rows, feat)), jnp.float32))

    # G individual dispatches vs one fused dispatch over the same work.
    # Both go through the VMEM router: at real sizes the per-graph features
    # (Pubmed ~10k rows) and, always, the concatenated batch exceed the
    # resident kernel's N_pad <= 4096 budget, which now raises instead of
    # silently compiling an oversized tile.
    def individual():
        return [spmm_auto(p.slabs, x, p.n_rows)
                for p, x in zip(plans, xs)]

    def batched():
        return spmm_batched([p.slabs for p in plans], xs,
                            [p.n_rows for p in plans], backend="auto")

    # Pre-merged: the host-side slab merge done once (what the engine
    # amortizes for steady traffic), timing only the single fused dispatch.
    from repro.kernels.spmm_batched import batch_graph_slabs
    merged, _, _, n_out = batch_graph_slabs(
        [p.slabs for p in plans], [p.n_rows for p in plans],
        [p.n_cols for p in plans])
    m_dev = {k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
             for k, v in merged.items()}
    x_cat = jnp.concatenate(xs, axis=0)

    def premerged():
        return spmm_auto(m_dev, x_cat, n_out)

    us_ind = time_call(individual, warmup=1, iters=3)
    us_bat = time_call(batched, warmup=1, iters=3)
    us_pre = time_call(premerged, warmup=1, iters=3)
    rows.append(csv_row("serve/spmm_individual", us_ind,
                        f"graphs={len(plans)}"))
    rows.append(csv_row("serve/spmm_batched", us_bat,
                        f"graphs={len(plans)};vs_individual="
                        f"{us_ind / max(us_bat, 1e-9):.2f}x;incl_host_merge"))
    rows.append(csv_row("serve/spmm_batched_premerged", us_pre,
                        f"graphs={len(plans)};vs_individual="
                        f"{us_ind / max(us_pre, 1e-9):.2f}x"))

    # Steady-state mixed traffic through the engine.
    engine = GraphServeEngine(config=cfg, cache=cache, backend="blocked",
                              max_graphs_per_batch=4)
    for name, g in graphs.items():
        engine.register_graph(name, g)
    names = list(graphs)
    reqs = [GraphRequest(names[i % len(names)],
                         xs[i % len(names)]) for i in range(12)]
    engine.serve(reqs)  # warm compile
    t0 = time.perf_counter()
    for _ in range(3):
        engine.serve([GraphRequest(r.graph_id, r.x) for r in reqs])
    dt = time.perf_counter() - t0
    st = engine.stats()
    rows.append(csv_row("serve/engine_throughput", dt / 3 * 1e6,
                        f"rows_per_s={st['rows_per_s']:.3g};"
                        f"hit_rate={st['cache_hit_rate']:.3f};"
                        f"builds={st['cache_builds']:.0f}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(r)
