"""Serving benchmark: plan-cache amortization + batched multi-graph dispatch.

Rows emitted:
  serve/plan_cold_<name>      one full preprocessing pass (cache miss)
  serve/plan_warm_<name>      the same request again (cache hit)
  serve/spmm_individual       G graphs dispatched one kernel call each
  serve/spmm_batched          the same G graphs in ONE fused kernel call
  serve/engine_throughput     steady-state engine rows/s over mixed traffic
  serve/concurrent_unbatched  N submitter threads, every request its own
                              dispatch (the old call-site batching limit:
                              concurrent callers never share a batch)
  serve/concurrent_scheduler  the same open-loop traffic through the
                              continuous-batching scheduler (cross-caller
                              coalescing into fused dispatches)

The concurrent section also writes its stats to
``benchmarks/results/serve_stats.json`` (consumed by the scheduled CI job).

Caveat on this CPU harness: the G "individual" dispatches are independent
XLA computations and overlap across host cores, while the fused call only
has intra-op parallelism — so batching shows little CPU-side win here. The
batched path exists for the dispatch-bound regime (one compilation, one
launch, one scatter on TPU); the unambiguous CPU-visible wins are the
plan_warm rows (cache), the requests/batch amortization in the engine, and
the dispatch-count collapse in the concurrent section.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.plan_cache import PartitionConfig, PlanCache
from repro.kernels.ops import spmm_auto
from repro.kernels.spmm_batched import spmm_batched
from repro.serve.graph_engine import GraphRequest, GraphServeEngine

from .common import csv_row, staged_graph, time_call

SERVE_GRAPHS = ["Pubmed", "Artist", "Collab", "Arxiv"]

RESULTS_JSON = os.path.join(os.path.dirname(__file__), "results",
                            "serve_stats.json")


def run(budget_edges: int = 200_000, feat: int = 64) -> List[str]:
    rows: List[str] = []
    cfg = PartitionConfig()
    cache = PlanCache(capacity=16)
    rng = np.random.default_rng(0)

    graphs, plans, xs = {}, [], []
    for name in SERVE_GRAPHS:
        g, _ = staged_graph(name, budget_edges=budget_edges // len(SERVE_GRAPHS))
        graphs[name] = g

        t0 = time.perf_counter()
        plan = cache.get_or_build(g, cfg)
        cold = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        cache.get_or_build(g, cfg)
        warm = (time.perf_counter() - t0) * 1e6
        rows.append(csv_row(f"serve/plan_cold_{name}", cold,
                            f"n={g.n_rows};nnz={g.nnz};blocks={plan.num_blocks}"))
        rows.append(csv_row(f"serve/plan_warm_{name}", warm,
                            f"speedup={cold / max(warm, 1e-9):.0f}x"))
        plans.append(plan)
        xs.append(jnp.asarray(rng.normal(size=(g.n_rows, feat)), jnp.float32))

    # G individual dispatches vs one fused dispatch over the same work.
    # Both go through the VMEM router: at real sizes the per-graph features
    # (Pubmed ~10k rows) and, always, the concatenated batch exceed the
    # resident kernel's N_pad <= 4096 budget, which now raises instead of
    # silently compiling an oversized tile.
    def individual():
        return [spmm_auto(p.slabs, x, p.n_rows)
                for p, x in zip(plans, xs)]

    def batched():
        return spmm_batched([p.slabs for p in plans], xs,
                            [p.n_rows for p in plans], backend="auto")

    # Pre-merged: the host-side slab merge done once (what the engine
    # amortizes for steady traffic), timing only the single fused dispatch.
    from repro.kernels.spmm_batched import batch_graph_slabs
    merged, _, _, n_out = batch_graph_slabs(
        [p.slabs for p in plans], [p.n_rows for p in plans],
        [p.n_cols for p in plans])
    m_dev = {k: jnp.asarray(v) if isinstance(v, np.ndarray) else v
             for k, v in merged.items()}
    x_cat = jnp.concatenate(xs, axis=0)

    def premerged():
        return spmm_auto(m_dev, x_cat, n_out)

    us_ind = time_call(individual, warmup=1, iters=3)
    us_bat = time_call(batched, warmup=1, iters=3)
    us_pre = time_call(premerged, warmup=1, iters=3)
    rows.append(csv_row("serve/spmm_individual", us_ind,
                        f"graphs={len(plans)}"))
    rows.append(csv_row("serve/spmm_batched", us_bat,
                        f"graphs={len(plans)};vs_individual="
                        f"{us_ind / max(us_bat, 1e-9):.2f}x;incl_host_merge"))
    rows.append(csv_row("serve/spmm_batched_premerged", us_pre,
                        f"graphs={len(plans)};vs_individual="
                        f"{us_ind / max(us_pre, 1e-9):.2f}x"))

    # Steady-state mixed traffic through the engine.
    engine = GraphServeEngine(config=cfg, cache=cache, backend="blocked",
                              max_graphs_per_batch=4)
    for name, g in graphs.items():
        engine.register_graph(name, g)
    names = list(graphs)
    reqs = [GraphRequest(names[i % len(names)],
                         xs[i % len(names)]) for i in range(12)]
    engine.serve(reqs)  # warm compile
    t0 = time.perf_counter()
    for _ in range(3):
        engine.serve([GraphRequest(r.graph_id, r.x) for r in reqs])
    dt = time.perf_counter() - t0
    st = engine.stats()
    rows.append(csv_row("serve/engine_throughput", dt / 3 * 1e6,
                        f"rows_per_s={st['rows_per_s']:.3g};"
                        f"hit_rate={st['cache_hit_rate']:.3f};"
                        f"builds={st['cache_builds']:.0f}"))

    # ---------------------------------------------------- concurrent section
    # N submitter threads, open-loop single-request arrivals on recurring
    # graphs. "unbatched" caps every flush at one request — the old
    # call-site-batching limit, where concurrent callers never share a
    # dispatch. "scheduler" lets the continuous batcher coalesce across
    # callers into fused multi-graph dispatches. Sized for the
    # dispatch-bound regime (many tiny recurring graphs, narrow features):
    # that is continuous batching's design point — per-dispatch overhead
    # amortizes across coalesced requests; at compute-bound sizes the CPU
    # caveat above applies to the fused path too.
    from repro.core.graph import gcn_normalize as _norm
    from repro.data.graphs import make_power_law_graph
    small = {f"svc{i}": _norm(make_power_law_graph(220 + 37 * i,
                                                   1500 + 100 * i,
                                                   seed=10 + i))
             for i in range(4)}
    results: Dict[str, Dict] = {}
    for label, sched_kw in [
        ("unbatched", dict(max_batch_requests=1, max_wait_ms=0.0)),
        ("scheduler", dict(max_batch_requests=16, max_wait_ms=3.0)),
    ]:
        results[label] = _concurrent_traffic(
            cfg, cache, small, feat=8, n_threads=4, per_thread=12,
            **sched_kw)
    for label, rec in results.items():
        rows.append(csv_row(
            f"serve/concurrent_{label}", rec["wall_s"] * 1e6,
            f"req_per_s={rec['requests_per_s']:.3g};"
            f"dispatches={rec['batches_dispatched']:.0f};"
            f"graphs_per_dispatch={rec['graphs_per_dispatch']:.2f};"
            f"req_per_batch={rec['requests_per_batch']:.2f};"
            f"p99_ms={rec['p99_latency_s'] * 1e3:.1f}"))
    results["speedup_vs_unbatched"] = (
        results["scheduler"]["requests_per_s"]
        / max(results["unbatched"]["requests_per_s"], 1e-9))
    # merge over any sections another `--only` pass already wrote (repair
    # runs BEFORE serve in a combined run — replacing the file here would
    # silently drop its stats); our own top-level keys still overwrite
    merged = {}
    if os.path.exists(RESULTS_JSON):
        try:
            with open(RESULTS_JSON) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(results)
    os.makedirs(os.path.dirname(RESULTS_JSON), exist_ok=True)
    with open(RESULTS_JSON, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    rows.append(csv_row(
        "serve/concurrent_speedup", 0.0,
        f"scheduler_vs_unbatched={results['speedup_vs_unbatched']:.2f}x;"
        f"json={os.path.relpath(RESULTS_JSON)}"))
    return rows


def _concurrent_traffic(cfg, cache, graphs, feat: int, *, n_threads: int,
                        per_thread: int, **sched_kw) -> Dict:
    """Push open-loop multi-threaded traffic through one engine config and
    return its throughput + scheduling stats (JSON-serializable).

    The warmup pass (jit compiles for the common fused shapes — the compile
    cache is process-global) runs on a THROWAWAY engine so the reported
    stats, in particular the latency percentiles, describe only the timed
    steady-state run."""
    rng = np.random.default_rng(7)
    feats = {name: jnp.asarray(rng.normal(size=(g.n_cols, feat)),
                               jnp.float32) for name, g in graphs.items()}
    names = list(graphs)

    def traffic(engine):
        futs = []
        lock = threading.Lock()

        def submitter(t):
            local = []
            for k in range(per_thread):
                gid = names[(t + k) % len(names)]
                local.append(engine.submit(gid, feats[gid]))
            with lock:
                futs.extend(local)

        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for f in futs:
            f.result()
        return time.perf_counter() - t0

    def make_engine():
        engine = GraphServeEngine(config=cfg, cache=cache, backend="blocked",
                                  max_graphs_per_batch=4, **sched_kw)
        for name, g in graphs.items():
            engine.register_graph(name, g)
        return engine

    warm = make_engine()        # warm the jit cache for the common shapes
    traffic(warm)
    warm.close()
    # best-of-3 timed passes, each on a fresh engine: interpret-mode CPU
    # walls on a shared host are noisy (stray 10x stalls), and the best
    # pass is the one that reflects the architecture rather than the box
    wall, st = None, None
    for _ in range(3):
        engine = make_engine()
        w = traffic(engine)
        if wall is None or w < wall:
            wall, st = w, engine.stats()
        engine.close()
    total = n_threads * per_thread
    return {
        "wall_s": wall,
        "requests": total,
        "threads": n_threads,
        "requests_per_s": total / wall,
        "batches_dispatched": st["batches_dispatched"],
        "graphs_per_dispatch": st["graphs_per_dispatch"],
        "requests_per_batch": st["requests_per_batch"],
        "rows_per_s": st["rows_per_s"],
        "p50_latency_s": st["sched_p50_latency_s"],
        "p99_latency_s": st["sched_p99_latency_s"],
        "flush_size": st["sched_flush_size"],
        "flush_deadline": st["sched_flush_deadline"],
        "mid_flush_admissions": st["sched_mid_flush_admissions"],
    }


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(r)
