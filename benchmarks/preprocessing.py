"""Paper §III-C claim: degree sorting + block-level partitioning are O(n).

Times the full preprocessing pipeline across a size ladder and fits the
log-log slope — O(n) <=> slope ~= 1.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.graph import degree_sort_csr, gcn_normalize
from repro.core.partition import block_level_partition, get_partition_patterns
from repro.data.graphs import make_power_law_graph

from .common import csv_row

SIZES = [10_000, 30_000, 100_000, 300_000]


def run(quiet=False):
    rows = []
    pats = get_partition_patterns(64, 4, mode="tpu")
    ts = []
    for n in SIZES:
        g = gcn_normalize(make_power_law_graph(n, n * 8, seed=1))
        t0 = time.perf_counter()
        gs = degree_sort_csr(g)
        block_level_partition(gs, pats)
        dt = time.perf_counter() - t0
        ts.append(dt)
        rows.append(csv_row(f"preproc/n{n}", dt * 1e6, f"edges={g.nnz}"))
    slope = np.polyfit(np.log(SIZES), np.log(ts), 1)[0]
    rows.append(csv_row("preproc/loglog_slope", 0.0,
                        f"slope={slope:.2f};O(n)_iff_slope~1"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
