"""Paper §III-C claim: degree sorting + block-level partitioning are O(n).

Times the full preprocessing pipeline across a size ladder and fits the
log-log slope — O(n) <=> slope ~= 1.

``run_repair`` is the streaming-update companion: incremental plan repair
(:func:`repro.core.plan_repair.repair_plan` with the O(delta) chained key,
exactly what the serving ``mutate()`` path runs) vs a from-scratch
``build_partition_plan`` on the post-delta graph, at deltas of 0.1% / 1% /
10% of nnz. Results merge into ``benchmarks/results/serve_stats.json``
under a ``"repair"`` key; nightly CI gates ``repair_speedup >= 3x`` at the
0.1% point via ``scripts/check_bench.py``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.graph import degree_sort_csr, gcn_normalize
from repro.core.partition import block_level_partition, get_partition_patterns
from repro.data.graphs import make_power_law_graph

from .common import csv_row

SIZES = [10_000, 30_000, 100_000, 300_000]

REPAIR_N = 100_000
REPAIR_FRACS = [0.001, 0.01, 0.10]
REPAIR_REPEATS = 5


def run(quiet=False):
    rows = []
    pats = get_partition_patterns(64, 4, mode="tpu")
    ts = []
    for n in SIZES:
        g = gcn_normalize(make_power_law_graph(n, n * 8, seed=1))
        t0 = time.perf_counter()
        gs = degree_sort_csr(g)
        block_level_partition(gs, pats)
        dt = time.perf_counter() - t0
        ts.append(dt)
        rows.append(csv_row(f"preproc/n{n}", dt * 1e6, f"edges={g.nnz}"))
    slope = np.polyfit(np.log(SIZES), np.log(ts), 1)[0]
    rows.append(csv_row("preproc/loglog_slope", 0.0,
                        f"slope={slope:.2f};O(n)_iff_slope~1"))
    return rows


def _sample_delta(g, frac, rng):
    """A realistic streaming delta: deletes uniform over existing edges,
    insert sources preferential-attachment (sampled from existing edge
    endpoints — degree-weighted, like real edge streams on power-law
    graphs)."""
    from repro.core.plan_repair import EdgeDelta

    k = max(1, int(g.nnz * frac))
    kd, ki = k // 2, k - k // 2
    eids = rng.choice(g.nnz, size=min(kd, g.nnz), replace=False)
    dsrc = np.searchsorted(g.rowptr, eids, side="right") - 1
    ddst = g.colidx[eids]
    seed_e = rng.choice(g.nnz, size=ki)
    isrc = np.searchsorted(g.rowptr, seed_e, side="right") - 1
    idst = rng.integers(0, g.n_cols, ki)
    return EdgeDelta(insert_src=isrc, insert_dst=idst,
                     insert_val=rng.standard_normal(ki).astype(np.float32),
                     delete_src=dsrc, delete_dst=ddst,
                     on_duplicate="replace", on_missing="ignore")


def run_repair(quiet=False):
    """plan_repair section: incremental repair vs full rebuild per delta
    size. Both sides consume the already-applied post-delta graph — delta
    application is a shared cost of any update path, so the comparison
    isolates the plan phase the repair subsystem actually replaces."""
    from repro.core.plan_cache import PartitionConfig, build_partition_plan
    from repro.core.plan_repair import delta_chain_hash, repair_plan

    rng = np.random.default_rng(7)
    g = gcn_normalize(make_power_law_graph(REPAIR_N, REPAIR_N * 8, seed=1))
    cfg = PartitionConfig()
    plan = build_partition_plan(g, cfg)

    rows = []
    stats = {}
    for frac in REPAIR_FRACS:
        delta = _sample_delta(g, frac, rng)
        g_new = delta.apply(g)
        touched = delta.touched_rows()
        gh = delta_chain_hash(plan.graph_hash, delta)
        # untimed warmup: first calls pay one-off jit/alloc costs on both
        # sides, which would otherwise skew a small-repeat median
        repair_plan(plan, g, g_new, touched, graph_hash=gh)
        build_partition_plan(g_new, cfg)
        reps, rebs = [], []
        pv = None
        for _ in range(REPAIR_REPEATS):
            t0 = time.perf_counter()
            pv = repair_plan(plan, g, g_new, touched, graph_hash=gh)
            pv.plan.slabs["values"].block_until_ready()
            reps.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            full = build_partition_plan(g_new, cfg)
            full.slabs["values"].block_until_ready()
            rebs.append(time.perf_counter() - t0)
        rep_us = float(np.median(reps)) * 1e6
        reb_us = float(np.median(rebs)) * 1e6
        speedup = reb_us / rep_us
        tag = f"{frac:g}"
        stats[f"frac_{tag}"] = {
            "delta_edges": int(delta.size),
            "repair_us": rep_us, "rebuild_us": reb_us,
            "speedup": speedup, "repaired": bool(pv.repaired),
            "dirty_rows": int(pv.dirty_rows),
        }
        rows.append(csv_row(
            f"repair/frac{tag}", rep_us,
            f"rebuild_us={reb_us:.0f};speedup={speedup:.2f};"
            f"repaired={pv.repaired};dirty_rows={pv.dirty_rows};"
            f"delta_edges={delta.size}"))
    # the gated headline: incremental repair at the smallest (steady-state
    # streaming) delta must beat the rebuild it replaces by >= 3x
    stats["repair_speedup"] = stats[f"frac_{REPAIR_FRACS[0]:g}"]["speedup"]

    from .serve_graphs import RESULTS_JSON
    merged = {}
    if os.path.exists(RESULTS_JSON):
        try:
            with open(RESULTS_JSON) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged["repair"] = stats
    os.makedirs(os.path.dirname(RESULTS_JSON), exist_ok=True)
    with open(RESULTS_JSON, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    rows.append(csv_row(
        "repair/stats_json", 0.0,
        f"speedup_at_{REPAIR_FRACS[0]:g}={stats['repair_speedup']:.2f};"
        f"json={os.path.relpath(RESULTS_JSON)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
    for r in run_repair():
        print(r)
