"""Paper Fig. 5 analogue: overall SpMM comparison across the 18 benchmark
graphs. Backends (CPU-measurable analogues of the paper's baselines):

  accel   — degree sort + block-level partition + combined-warp tiling (ours)
  warp    — fixed non-zero groups, one record per warp (GNNAdvisor analogue)
  segment — COO + segment_sum, the generic vendor-library formulation
            (cuSPARSE analogue; speedups are normalized to it, as in Fig. 5)

Graphs are power-law analogues of Table I scaled to a fixed edge budget (the
degree *distribution*, which drives the paper's effects, is preserved).
"""
from __future__ import annotations

import numpy as np

from repro.core.spmm import make_accel_spmm
from repro.data.graphs import BENCHMARK_GRAPHS

from .common import csv_row, staged_graph, time_call

GRAPHS = sorted(BENCHMARK_GRAPHS)
F = 64


def run(budget_edges=300_000, graphs=None, quiet=False):
    import jax.numpy as jnp
    rows, speedups = [], []
    for name in graphs or GRAPHS:
        g, scale = staged_graph(name, budget_edges)
        op = make_accel_spmm(g, with_baselines=True)
        X = jnp.asarray(np.random.default_rng(0).normal(size=(g.n_cols, F)),
                        dtype=jnp.float32)
        t = {be: time_call(lambda be=be: op(X, backend=be))
             for be in ("blocked", "warp", "segment")}
        sp_seg = t["segment"] / t["blocked"]
        sp_warp = t["warp"] / t["blocked"]
        speedups.append((sp_seg, sp_warp))
        rows.append(csv_row(f"fig5/{name}/accel", t["blocked"],
                            f"speedup_vs_segment={sp_seg:.2f};"
                            f"speedup_vs_warp={sp_warp:.2f};scale={scale:.3g}"))
        rows.append(csv_row(f"fig5/{name}/warp", t["warp"], ""))
        rows.append(csv_row(f"fig5/{name}/segment", t["segment"], ""))
    gm = np.exp(np.mean(np.log([s for s, _ in speedups])))
    gw = np.exp(np.mean(np.log([w for _, w in speedups])))
    rows.append(csv_row("fig5/geomean", 0.0,
                        f"accel_vs_segment={gm:.2f};accel_vs_warp={gw:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
