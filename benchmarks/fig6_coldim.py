"""Paper Fig. 6 analogue: SpMM runtime vs right-hand column dimension 16..128.

The paper's claim: with the combined-warp strategy, runtime grows smoothly
with column dimension and is insensitive to non-power-of-2 widths (alignment
comes from lane-width padding). We measure the accel backend across
16..128-step-16 plus deliberately odd widths.
"""
from __future__ import annotations

import numpy as np

from repro.core.spmm import make_accel_spmm

from .common import csv_row, staged_graph, time_call

COLS = [16, 32, 48, 64, 80, 96, 112, 128, 100, 72]  # incl. non-pow2 / odd
GRAPHS = ["Collab", "Pubmed", "Artist"]


def run(budget_edges=250_000, quiet=False):
    import jax.numpy as jnp
    rows = []
    for name in GRAPHS:
        g, scale = staged_graph(name, budget_edges)
        op = make_accel_spmm(g)
        times = {}
        for F in COLS:
            X = jnp.asarray(np.random.default_rng(0).normal(size=(g.n_cols, F)),
                            dtype=jnp.float32)
            times[F] = time_call(lambda X=X: op(X))
            rows.append(csv_row(f"fig6/{name}/F{F}", times[F], ""))
        # smoothness metric: runtime of odd width vs next pow2-ish width
        ratio_odd = times[100] / times[112]
        rows.append(csv_row(f"fig6/{name}/odd_width_penalty", 0.0,
                            f"t(F=100)/t(F=112)={ratio_odd:.2f};scale={scale:.3g}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
