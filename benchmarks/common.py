"""Shared benchmark utilities: timing, graph staging, CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.core.graph import gcn_normalize
from repro.data.graphs import BENCHMARK_GRAPHS, make_power_law_graph


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def staged_graph(name: str, budget_edges: int = 400_000, seed: int = 0):
    """A Table-I analogue scaled to a CPU-friendly edge budget.

    Returns (normalized CSRGraph, scale_applied)."""
    n_full, e_full, sc = BENCHMARK_GRAPHS[name]
    e_target = int(e_full * sc)
    scale = min(1.0, budget_edges / e_target)
    n = max(100, int(n_full * scale))
    e = max(200, int(e_target * scale))
    g = gcn_normalize(make_power_law_graph(n, e, seed=seed))
    return g, scale


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
