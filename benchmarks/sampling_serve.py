"""Sampled-inference benchmark: the neighbor-sampling service end to end.

Four sections, all landing under the ``"sampling"`` key of
``benchmarks/results/serve_stats.json`` (nightly gates with
``scripts/check_bench.py --require-sampling``):

  sample/zipf_hit_rate   a zipf-distributed seed-batch stream (production
                         seed batches recur heavily) through the frontier
                         LRU — acceptance: hit rate >= 0.5, i.e. recurring
                         frontiers amortize both the sampling AND their
                         partition plans
  sample/throughput      steady-state sampled 2-layer GCN inference
                         (seeds/s through the plan-cache/SpMM path)
  sample/exact_*         full-fanout sampled inference vs the full-graph
                         reference on BOTH kernel backends — acceptance:
                         bit-for-bit equal
  sample/partitioned     a two-subprocess partitioned store (REAL peer
                         data plane): each rank owns half the nodes,
                         frontiers straddle the boundary through
                         FrontierExchange — acceptance: sampling parity
                         with the monolithic store, remote hops actually
                         crossed, zero failovers
"""
from __future__ import annotations

import json
import os
import textwrap
import time
from typing import Dict, List

import numpy as np

from .common import csv_row
from .serve_graphs import RESULTS_JSON


def _build(n: int, m: int, seed: int = 0):
    import jax
    from repro.data.graphs import make_power_law_graph
    from repro.models.gcn import init_gcn
    from repro.sampling import GraphStore

    store = GraphStore.build(make_power_law_graph(n, m, seed=seed),
                             normalize=True)
    rng = np.random.default_rng(seed + 1)
    x = rng.normal(size=(n, 16)).astype(np.float32)
    params = init_gcn(jax.random.PRNGKey(seed), [16, 16, 8])
    return store, x, params


def _zipf_stream(backend: str, budget_edges: int) -> Dict:
    """Zipf-recurring seed batches through the frontier LRU."""
    from repro.data.graphs import seed_batches, seed_splits
    from repro.sampling import SamplingService
    from repro.serve import GraphServeEngine

    n = max(1000, min(4000, budget_edges // 12))
    store, x, params = _build(n, min(budget_edges, 8 * n), seed=3)
    engine = GraphServeEngine(backend=backend)
    try:
        svc = SamplingService(engine, store, fanouts=[8, 8], store=store,
                              max_cached_frontiers=48)
        train, _ = seed_splits(n, [0.3, 0.1], seed=0)
        batches = [b for _, b in zip(range(40), seed_batches(
            train, 32, seed=1))]
        # zipf over the batch pool: a handful of hot batches dominate
        zipf = np.random.default_rng(2).zipf(1.3, size=240)
        order = [batches[int(z - 1) % len(batches)] for z in zipf]
        for b in order[:8]:
            svc.infer(b, x, params)               # warm plans + compile
        t0 = time.perf_counter()
        seeds_served = 0
        for b in order:
            svc.infer(b, x, params)
            seeds_served += len(b)
        wall = time.perf_counter() - t0
        st = svc.stats()
        est = engine.stats()
        return {
            "backend": backend,
            "n_nodes": n,
            "batches": len(order),
            "hit_rate": st["frontier_hit_rate"],
            "frontier_hits": st["frontier_hits"],
            "frontier_misses": st["frontier_misses"],
            "sampled_edges": st["sampled_edges"],
            "plan_cache_hits": est["cache_hits"],
            "seeds_per_s": seeds_served / wall if wall else 0.0,
            "us_per_batch": wall / len(order) * 1e6,
        }
    finally:
        engine.close()


def _exactness(backend: str, budget_edges: int) -> Dict:
    """Full-fanout sampled 2-layer GCN vs the full-graph reference."""
    import jax
    import jax.numpy as jnp
    from repro.sampling import SamplingService
    from repro.serve import GraphServeEngine

    n = max(400, min(1200, budget_edges // 40))
    store, x, params = _build(n, 6 * n, seed=5)
    engine = GraphServeEngine(backend=backend)
    try:
        engine.register_graph("full", store.in_adj)
        svc = SamplingService(engine, store, fanouts=[None, None],
                              store=store)
        h = jnp.asarray(x)
        for i, p in enumerate(params):
            agg = engine.submit("full", jnp.dot(h, p["w"])).result()
            h = agg + p["b"]
            if i < len(params) - 1:
                h = jax.nn.relu(h)
        ref = np.asarray(h)
        seeds = np.random.default_rng(0).choice(n, 48, replace=False)
        out = svc.infer(seeds, x, params)
        return {"backend": backend, "n_nodes": n,
                "exact": bool(np.array_equal(out, ref[seeds])),
                "max_abs_diff": float(np.abs(out - ref[seeds]).max())}
    finally:
        engine.close()


_PARTITION_WORKER = textwrap.dedent("""
    import json, os, threading, time
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    from repro.data.graphs import make_power_law_graph
    from repro.distributed.multihost import (
        FrontierExchange, PeerClient, PeerServer, peer_ports,
    )
    from repro.sampling import (
        GraphStore, PartitionedStoreClient, sample_frontier,
    )

    rank = int(os.environ["REPRO_MH_PID"])
    nprocs = int(os.environ["REPRO_MH_NPROCS"])
    n = int(os.environ.get("REPRO_MH_SAMPLE_NODES", "2000"))
    ports = peer_ports()

    full = GraphStore.build(make_power_law_graph(n, 6 * n, seed=0),
                            normalize=True)
    shards = full.partition(nprocs)
    bounds = [s.node_range[0] for s in shards] + [full.n_nodes]

    server = PeerServer(ports[rank], process_index=rank, epoch=0,
                        n_devices=1)
    FrontierExchange.serve(server, shards[rank])
    done = threading.Event()
    server.register("peer-done", lambda _p: done.set())

    peers = {r: PeerClient(("127.0.0.1", p), process_index=rank)
             for r, p in ports.items() if r != rank}
    exchange = FrontierExchange(peers)
    client = PartitionedStoreClient(shards[rank], bounds,
                                    exchange.remote_map(), rank)

    rng = np.random.default_rng(rank)
    checks, t0 = [], time.perf_counter()
    for i in range(6):
        seeds = rng.choice(n, 24, replace=False)
        fp = sample_frontier(client.sample_in_neighbors, seeds, [4, 4],
                             seed=i)
        fm = sample_frontier(full.sample_in_neighbors, seeds, [4, 4],
                             seed=i)
        checks.append(fp.content_key() == fm.content_key())
    wall = time.perf_counter() - t0

    for peer in peers.values():
        peer.request("peer-done", None)
    assert done.wait(300), "peer never finished sampling"
    for peer in peers.values():
        peer.close()
    server.close()
    print(json.dumps({"rank": rank, "parity": all(checks),
                      "frontiers": len(checks),
                      "remote_edges": int(client.remote_edges),
                      "local_edges": int(client.local_edges),
                      "failovers": exchange.failovers,
                      "requests": exchange.requests,
                      "wall_s": wall}))
""")


def _partitioned(budget_edges: int, num_processes: int = 2) -> Dict:
    from repro.distributed.multihost import run_cpu_fleet

    repo_root = os.path.join(os.path.dirname(__file__), "..")
    n = max(800, min(3000, budget_edges // 12))
    records = run_cpu_fleet(
        _PARTITION_WORKER, num_processes=num_processes, n_local_devices=1,
        timeout_s=420, cwd=repo_root,
        extra_env={"REPRO_MH_SAMPLE_NODES": str(n)})
    records.sort(key=lambda r: r["rank"])
    return {
        "processes": num_processes,
        "n_nodes": n,
        "per_rank": records,
        "parity": all(r["parity"] for r in records),
        "remote_edges": sum(r["remote_edges"] for r in records),
        "local_edges": sum(r["local_edges"] for r in records),
        "failovers": sum(r["failovers"] for r in records),
        "exchange_requests": sum(r["requests"] for r in records),
        "wall_s": max(r["wall_s"] for r in records),
    }


def run(budget_edges: int = 200_000,
        skip_partitioned: bool = False) -> List[str]:
    rows: List[str] = []
    results: Dict = {}

    stream = _zipf_stream("blocked", budget_edges)
    results["zipf_stream"] = stream
    rows.append(csv_row(
        "sample/zipf_hit_rate", stream["us_per_batch"],
        f"hit_rate={stream['hit_rate']:.3f};"
        f"hits={stream['frontier_hits']};"
        f"misses={stream['frontier_misses']};"
        f"plan_hits={stream['plan_cache_hits']}"))
    rows.append(csv_row(
        "sample/throughput", stream["us_per_batch"],
        f"seeds_per_s={stream['seeds_per_s']:.0f};"
        f"batches={stream['batches']};n={stream['n_nodes']}"))

    results["exactness"] = {}
    for backend in ("blocked", "pallas"):
        ex = _exactness(backend, budget_edges)
        results["exactness"][backend] = ex
        rows.append(csv_row(
            f"sample/exact_{backend}", 0.0,
            f"exact={ex['exact']};max_abs_diff={ex['max_abs_diff']:.3g}"))

    if not skip_partitioned:
        part = _partitioned(budget_edges)
        results["partitioned"] = part
        rows.append(csv_row(
            "sample/partitioned", part["wall_s"] * 1e6,
            f"parity={part['parity']};"
            f"remote_edges={part['remote_edges']};"
            f"failovers={part['failovers']};"
            f"requests={part['exchange_requests']}"))

    merged = {}
    if os.path.exists(RESULTS_JSON):
        try:
            with open(RESULTS_JSON) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged["sampling"] = results
    os.makedirs(os.path.dirname(RESULTS_JSON), exist_ok=True)
    with open(RESULTS_JSON, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    rows.append(csv_row("sample/stats_json", 0.0,
                        f"json={os.path.relpath(RESULTS_JSON)}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(r)
