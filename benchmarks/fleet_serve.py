"""Fleet serving benchmark: multi-device dispatch vs the single-device engine.

Rows emitted:
  fleet/concurrent_single   the single-device continuous-batching engine on
                            the concurrent mix (the PR-3 baseline)
  fleet/concurrent_fleet    the same open-loop traffic through
                            FleetGraphEngine: per-device dispatch groups
                            launched concurrently (acceptance: fleet
                            graphs/round >= single-device graphs/dispatch)
  fleet/block_shard_giant   one narrow giant graph block-sharded across the
                            mesh, with per-device live block counts
                            (acceptance: balanced within 10%)
  fleet/zipf_replicated     zipf-skewed popularity (one hot graph dominates)
                            with hot-plan replication ON: the hot plan
                            promotes to several devices and its groups split
                            across them (acceptance: occupancy >= 0.75)
  fleet/zipf_disabled       the SAME zipf schedule with replication OFF —
                            the single-owner ceiling this PR removes

Results also merge into ``benchmarks/results/serve_stats.json`` under the
``"fleet"`` key (nightly CI uploads that file as an artifact and asserts
the acceptance numbers). Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for real
multi-device numbers; on one device the section still runs degenerately.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import gcn_normalize
from repro.data.graphs import make_power_law_graph
from repro.serve.fleet import FleetGraphEngine
from repro.serve.graph_engine import GraphServeEngine

from .common import csv_row
from .serve_graphs import RESULTS_JSON


def _traffic(engine, feats, names, n_threads: int, per_thread: int) -> float:
    futs = []
    lock = threading.Lock()

    def submitter(t):
        local = []
        for k in range(per_thread):
            gid = names[(t + k) % len(names)]
            local.append(engine.submit(gid, feats[gid]))
        with lock:
            futs.extend(local)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def _zipf_schedule(names: List[str], total: int, *, s: float = 1.6,
                   seed: int = 13) -> List[str]:
    """A fixed zipf-skewed request schedule (same for every engine under
    test): graph ranked r drawn with probability proportional to r^-s."""
    ranks = np.arange(1, len(names) + 1, dtype=np.float64)
    p = ranks ** -s
    p /= p.sum()
    rng = np.random.default_rng(seed)
    return [names[i] for i in rng.choice(len(names), size=total, p=p)]


def _zipf_traffic(engine, feats, schedule: List[str],
                  n_threads: int) -> float:
    """Open-loop submission of a fixed schedule, round-robined over
    ``n_threads`` submitter threads."""
    futs = []
    lock = threading.Lock()
    chunks = [schedule[t::n_threads] for t in range(n_threads)]

    def submitter(t):
        local = [engine.submit(gid, feats[gid]) for gid in chunks[t]]
        with lock:
            futs.extend(local)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def _measure_zipf(make_engine, feats, schedule: List[str], *,
                  n_threads: int = 8) -> Dict:
    """Best-of-3 STEADY-STATE passes of the fixed zipf schedule: one warm
    pass lets the EWMA tracker learn the hot set and stage its replicas,
    then ``reset_stats()`` zeroes the occupancy window so each measured
    pass sees the fully-replicated fleet (promotion latency is a
    correctness property, tested in tests/test_fleet.py — the benchmark
    measures the replicated steady state it converges to)."""
    engine = make_engine()
    _zipf_traffic(engine, feats, schedule, n_threads)
    wall, st = None, None
    for _ in range(3):
        engine.reset_stats()
        w = _zipf_traffic(engine, feats, schedule, n_threads)
        if wall is None or w < wall:
            wall, st = w, engine.stats()
    engine.close()
    return {
        "wall_s": wall,
        "requests": len(schedule),
        "requests_per_s": len(schedule) / wall,
        "p99_latency_s": st["sched_p99_latency_s"],
        "fleet_occupancy": st.get("fleet_occupancy", 0.0),
        "fleet_rounds": st.get("fleet_rounds", 0),
        "fleet_device_requests": st.get("fleet_device_requests", []),
        "fleet_device_dispatches": st.get("fleet_device_dispatches", []),
        "promotions": st.get("fleet_promotions", 0),
        "demotions": st.get("fleet_demotions", 0),
        "replica_copies": st.get("cache_replica_copies", 0),
        "replicated_keys": st.get("cache_replicated_keys", 0),
    }


def _measure(make_engine, graphs, feats, *, n_threads=4, per_thread=12
             ) -> Dict:
    """Best-of-3 open-loop concurrent passes (same protocol as the serve
    section: interpret-mode CPU walls are noisy on shared hosts)."""
    names = list(graphs)
    warm = make_engine()
    _traffic(warm, feats, names, n_threads, per_thread)
    warm.close()
    wall, st = None, None
    for _ in range(3):
        engine = make_engine()
        w = _traffic(engine, feats, names, n_threads, per_thread)
        if wall is None or w < wall:
            wall, st = w, engine.stats()
        engine.close()
    total = n_threads * per_thread
    rec = {
        "wall_s": wall,
        "requests": total,
        "requests_per_s": total / wall,
        "batches_dispatched": st["batches_dispatched"],
        "requests_per_batch": st["requests_per_batch"],
        "graphs_per_dispatch": st["graphs_per_dispatch"],
        "p99_latency_s": st["sched_p99_latency_s"],
    }
    for k in ("fleet_devices", "fleet_rounds", "fleet_graphs_per_round",
              "fleet_occupancy", "fleet_device_dispatches",
              "fleet_device_requests"):
        if k in st:
            rec[k] = st[k]
    return rec


def run(budget_edges: int = 200_000, feat: int = 8) -> List[str]:
    rows: List[str] = []
    n_dev = len(jax.devices())
    rng = np.random.default_rng(7)

    # the serve section's dispatch-bound concurrent mix: small recurring
    # graphs, narrow features
    graphs = {f"svc{i}": gcn_normalize(make_power_law_graph(
        220 + 37 * i, 1500 + 100 * i, seed=10 + i)) for i in range(4)}
    feats = {name: jnp.asarray(rng.normal(size=(g.n_cols, feat)),
                               jnp.float32) for name, g in graphs.items()}
    sched_kw = dict(max_batch_requests=16, max_wait_ms=3.0,
                    max_graphs_per_batch=4, backend="blocked")

    def make_single():
        e = GraphServeEngine(**sched_kw)
        for name, g in graphs.items():
            e.register_graph(name, g)
        return e

    def make_fleet():
        e = FleetGraphEngine(**sched_kw)
        for name, g in graphs.items():
            e.register_graph(name, g)
        return e

    results: Dict[str, Dict] = {"devices": n_dev}
    results["single"] = _measure(make_single, graphs, feats)
    results["fleet"] = _measure(make_fleet, graphs, feats)
    rows.append(csv_row(
        "fleet/concurrent_single", results["single"]["wall_s"] * 1e6,
        f"req_per_s={results['single']['requests_per_s']:.3g};"
        f"graphs_per_dispatch={results['single']['graphs_per_dispatch']:.2f}"))
    gpr = results["fleet"].get("fleet_graphs_per_round", 0.0)
    rows.append(csv_row(
        "fleet/concurrent_fleet", results["fleet"]["wall_s"] * 1e6,
        f"req_per_s={results['fleet']['requests_per_s']:.3g};"
        f"devices={n_dev};graphs_per_round={gpr:.2f};"
        f"vs_single_gpd={results['single']['graphs_per_dispatch']:.2f};"
        f"occupancy={results['fleet'].get('fleet_occupancy', 0.0):.2f}"))

    # zipf-skewed popularity: a hot graph owning most of the traffic — the
    # single-owner ceiling (one device saturated, the rest idle) vs
    # hot-plan replication (promote + split across replicas)
    zgraphs = {f"zipf{i}": gcn_normalize(make_power_law_graph(
        1000 + 80 * i, 8000 + 600 * i, seed=40 + i)) for i in range(6)}
    zfeats = {name: jnp.asarray(rng.normal(size=(g.n_cols, 128)),
                                jnp.float32) for name, g in zgraphs.items()}
    znames = list(zgraphs)
    schedule = _zipf_schedule(znames, 192)
    hot_share = schedule.count(znames[0]) / len(schedule)
    # bigger rounds + one dispatch per split sub-group: each device gets
    # several back-to-back dispatches per round, so its busy span covers
    # the round instead of idling behind the stragglers
    zipf_kw = dict(sched_kw, max_batch_requests=48, max_graphs_per_batch=1)

    def _make_zipf(**replica_kw):
        def make():
            e = FleetGraphEngine(**replica_kw, **zipf_kw)
            for name, g in zgraphs.items():
                e.register_graph(name, g)
            return e
        return make

    zipf: Dict[str, object] = {
        "hot_graph": znames[0], "hot_fraction": hot_share,
        "schedule_len": len(schedule),
    }
    zipf["replicated"] = _measure_zipf(
        _make_zipf(rate_per_replica=1.0, max_replicas=n_dev,
                   replica_halflife_s=4.0, replication_interval_s=0.01,
                   split_min_requests=1),
        zfeats, schedule)
    zipf["disabled"] = _measure_zipf(
        _make_zipf(replicate_hot=False), zfeats, schedule)
    zipf["speedup"] = (zipf["replicated"]["requests_per_s"]
                       / zipf["disabled"]["requests_per_s"])
    zipf["occupancy_ratio"] = (
        zipf["replicated"]["fleet_occupancy"]
        / max(zipf["disabled"]["fleet_occupancy"], 1e-9))
    results["zipf"] = zipf
    rows.append(csv_row(
        "fleet/zipf_replicated", zipf["replicated"]["wall_s"] * 1e6,
        f"req_per_s={zipf['replicated']['requests_per_s']:.3g};"
        f"occupancy={zipf['replicated']['fleet_occupancy']:.2f};"
        f"promotions={zipf['replicated']['promotions']};"
        f"replicas={zipf['replicated']['replica_copies']};"
        f"hot_frac={hot_share:.2f}"))
    rows.append(csv_row(
        "fleet/zipf_disabled", zipf["disabled"]["wall_s"] * 1e6,
        f"req_per_s={zipf['disabled']['requests_per_s']:.3g};"
        f"occupancy={zipf['disabled']['fleet_occupancy']:.2f};"
        f"speedup={zipf['speedup']:.2f}"))

    # narrow giant graph: block-sharded across the mesh
    n_big = max(5000, min(9000, budget_edges // 4))
    big = gcn_normalize(make_power_law_graph(n_big, budget_edges // 3,
                                             seed=99))
    fleet = FleetGraphEngine(backend="blocked")
    plan = fleet.register_graph("big", big)
    xb = jnp.asarray(rng.normal(size=(big.n_cols, 16)), jnp.float32)
    fleet.serve_one("big", xb)              # warm
    t0 = time.perf_counter()
    fleet.serve_one("big", xb)
    dt = time.perf_counter() - t0
    st = fleet.stats()
    fleet.close()
    counts = st["fleet_block_counts"]
    balance = st["fleet_block_balance"]
    results["giant"] = {
        "n_rows": big.n_rows, "nnz": big.nnz,
        "num_blocks": plan.num_blocks,
        "block_sharded_dispatches": st["fleet_block_sharded"],
        "block_counts": counts, "block_balance": balance,
    }
    rows.append(csv_row(
        "fleet/block_shard_giant", dt * 1e6,
        f"n={big.n_rows};blocks={plan.num_blocks};devices={n_dev};"
        f"balance={balance:.3f};counts={'|'.join(map(str, counts))}"))

    # merge into the serve stats artifact (the serve section owns the file;
    # running fleet alone still produces a valid JSON)
    merged = {}
    if os.path.exists(RESULTS_JSON):
        try:
            with open(RESULTS_JSON) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged["fleet"] = results
    os.makedirs(os.path.dirname(RESULTS_JSON), exist_ok=True)
    with open(RESULTS_JSON, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    rows.append(csv_row(
        "fleet/stats_json", 0.0,
        f"devices={n_dev};json={os.path.relpath(RESULTS_JSON)}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(r)
