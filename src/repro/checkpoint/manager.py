"""Fault-tolerant checkpointing: atomic, keep-k, mesh-independent.

Design (DESIGN.md §6):
* checkpoints are written to ``<dir>/step_<n>.tmp`` then atomically renamed,
  so a preempted writer never corrupts the latest checkpoint;
* arrays are saved *unsharded-logical* (gathered host-side), so a restart may
  use a different mesh/data-axis extent (elastic scaling) — resharding
  happens on load via the caller's shardings;
* ``latest_step`` scans for complete checkpoints only; the training loop
  restarts from there after any failure (crash-consistency is the rename).

Format: one ``.npz`` per checkpoint + a msgpack manifest of the pytree.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "MANIFEST.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        final = self._path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = _flatten(tree)
        arrs = {}
        dtypes = []
        for i, leaf in enumerate(leaves):
            a = np.asarray(jax.device_get(leaf))
            dtypes.append(a.dtype.name)
            if a.dtype.name == "bfloat16":   # npz can't store bf16
                a = a.astype(np.float32)
            arrs[f"a{i}"] = a
        np.savez(os.path.join(tmp, "arrays.npz"), **arrs)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(leaves),
                       "dtypes": dtypes,
                       "treedef": str(treedef)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for name in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)", name)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (reshard via ``shardings``)."""
        path = self._path(step)
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), "checkpoint/model mismatch"
        out = []
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else [None] * len(leaves))
        import ml_dtypes  # registered by jax; provides bfloat16 numpy dtype
        for i, (_leaf, sh) in enumerate(zip(leaves, sh_leaves)):
            a = data[f"a{i}"]
            want = manifest["dtypes"][i]
            a = a.astype(ml_dtypes.bfloat16 if want == "bfloat16" else want)
            out.append(jax.device_put(a, sh) if sh is not None else jax.numpy.asarray(a))
        return jax.tree_util.tree_unflatten(treedef, out)
