"""Drives the statics rules over a file set.

Two passes: pass 1 builds the cross-file class -> bases map (so guarded
attributes follow inheritance: ``MultihostGraphEngine`` ->
``FleetGraphEngine`` -> ``GraphServeEngine``); pass 2 runs every rule
module per file and filters findings through the per-line suppressions.
"""

from __future__ import annotations

import ast
from pathlib import Path

from . import future_rules, lock_rules, pallas_rules
from .findings import Finding, apply_suppressions, parse_suppressions

ALL_RULES: tuple[str, ...] = (
    "locked-call-outside-lock",
    "guarded-attr-outside-lock",
    "blocking-call-under-lock",
    "pallas-static-args",
    "pallas-traced-branch",
    "pallas-closure-numpy",
    "pallas-tile-divisibility",
    "future-leak",
    "future-double-settle",
    "bad-suppression",
)

RULE_FAMILIES: dict[str, tuple[str, ...]] = {
    "lock": (
        "locked-call-outside-lock",
        "guarded-attr-outside-lock",
        "blocking-call-under-lock",
    ),
    "pallas": (
        "pallas-static-args",
        "pallas-traced-branch",
        "pallas-closure-numpy",
        "pallas-tile-divisibility",
    ),
    "future": ("future-leak", "future-double-settle"),
    "meta": ("bad-suppression",),
}


def collect_py_files(paths: list[str | Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            files.append(path)
    # dedupe, keep order
    seen: set[Path] = set()
    out = []
    for f in files:
        r = f.resolve()
        if r not in seen and "__pycache__" not in f.parts:
            seen.add(r)
            out.append(f)
    return out


def _class_bases(trees: dict[Path, ast.Module]) -> dict[str, list[str]]:
    bases: dict[str, list[str]] = {}
    for tree in trees.values():
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                names = []
                for b in node.bases:
                    if isinstance(b, ast.Name):
                        names.append(b.id)
                    elif isinstance(b, ast.Attribute):
                        names.append(b.attr)
                bases[node.name] = names
    return bases


def analyze_paths(
    paths: list[str | Path],
    rules: set[str] | None = None,
    guarded_attrs: dict[str, dict[str, str]] | None = None,
) -> tuple[list[Finding], int]:
    """Run the analyzer. Returns (findings, files_checked).

    ``rules`` restricts output to a subset of ALL_RULES (None = all).
    ``guarded_attrs`` overrides lock_rules.DEFAULT_GUARDED_ATTRS.
    """
    files = collect_py_files(paths)
    sources: dict[Path, str] = {}
    trees: dict[Path, ast.Module] = {}
    findings: list[Finding] = []
    for f in files:
        try:
            src = f.read_text()
            trees[f] = ast.parse(src, filename=str(f))
            sources[f] = src
        except SyntaxError as e:
            findings.append(
                Finding(
                    rule="syntax-error",
                    path=str(f),
                    line=e.lineno or 0,
                    message=f"file does not parse: {e.msg}",
                )
            )

    registry = lock_rules.GuardedRegistry(
        guarded_attrs if guarded_attrs is not None else lock_rules.DEFAULT_GUARDED_ATTRS,
        _class_bases(trees),
    )

    for f, tree in trees.items():
        path = str(f)
        raw: list[Finding] = []
        raw.extend(lock_rules.check(path, tree, registry))
        raw.extend(pallas_rules.check(path, tree))
        raw.extend(future_rules.check(path, tree))
        raw = apply_suppressions(raw, parse_suppressions(sources[f]), path)
        findings.extend(raw)

    if rules is not None:
        findings = [f for f in findings if f.rule in rules or f.rule == "syntax-error"]
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, len(files)
