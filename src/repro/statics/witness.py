"""Opt-in runtime lock-order witness (lockdep-lite).

The static rules are lexical; they cannot see the ORDER in which two
locks are taken across threads.  This witness can: when installed it
wraps every ``threading.Lock`` / ``RLock`` / ``Condition`` created by
``repro.*`` modules, records a global acquisition-order graph (edge
``A -> B`` whenever a thread acquires B while holding A), and flags a
cycle in that graph as a potential deadlock — even on runs that never
actually deadlock.

Enabled from tests/conftest.py when ``REPRO_LOCK_WITNESS=1``; nothing is
patched otherwise, so the default test path has zero overhead.

Known approximation: nodes are lock *instances* labelled by creation
site.  Per-instance tracking avoids false cycles between two unrelated
instances of the same class, at the cost of missing A1/B1-vs-B2/A2
inversions across instance pairs.
"""

from __future__ import annotations

import sys
import threading

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition


class LockWitness:
    """Acquisition-order graph + per-thread held-lock stacks."""

    def __init__(self):
        self._meta = _REAL_LOCK()
        self._edges: dict[int, set[int]] = {}
        self._labels: dict[int, str] = {}
        self._tls = threading.local()
        self.cycles: list[tuple[str, ...]] = []

    # -- bookkeeping -------------------------------------------------------

    def _stack(self) -> list[int]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def register(self, node: int, label: str) -> None:
        with self._meta:
            self._labels[node] = label

    def label(self, node: int) -> str:
        return self._labels.get(node, hex(node))

    # -- events ------------------------------------------------------------

    def before_acquire(self, node: int) -> None:
        st = self._stack()
        if node in st:
            return  # reentrant re-acquire: no new ordering information
        held = list(dict.fromkeys(st))
        if not held:
            return
        with self._meta:
            for h in held:
                succ = self._edges.setdefault(h, set())
                if node in succ:
                    continue
                path = self._find_path(node, h)
                if path is not None:
                    cyc = tuple(self.label(n) for n in [h, *path])
                    self.cycles.append(cyc)
                succ.add(node)

    def after_acquire(self, node: int) -> None:
        self._stack().append(node)

    def on_release(self, node: int) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] == node:
                del st[i]
                return

    def _find_path(self, src: int, dst: int) -> list[int] | None:
        """DFS path src -> dst over the recorded edges (meta lock held)."""
        seen = {src}
        stack: list[tuple[int, list[int]]] = [(src, [src])]
        while stack:
            n, path = stack.pop()
            if n == dst:
                return path
            for m in self._edges.get(n, ()):
                if m not in seen:
                    seen.add(m)
                    stack.append((m, path + [m]))
        return None

    # -- reporting ---------------------------------------------------------

    def assert_no_cycles(self) -> None:
        if self.cycles:
            lines = "\n".join("  " + " -> ".join(c) for c in self.cycles)
            raise AssertionError(
                f"lock-order witness found {len(self.cycles)} acquisition-order "
                f"cycle(s) — potential deadlock:\n{lines}"
            )


class InstrumentedLock:
    """Wraps a real Lock/RLock, reporting events to a LockWitness.

    Also implements the private Condition protocol (``_release_save`` /
    ``_acquire_restore`` / ``_is_owned``) so ``threading.Condition`` built
    on an instrumented RLock keeps full reentrancy semantics, and
    ``cond.wait()`` correctly pops/pushes the held stack around the
    blocking window.
    """

    def __init__(self, inner, witness: LockWitness, label: str):
        self._inner = inner
        self._witness = witness
        self._node = id(self)
        witness.register(self._node, label)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._witness.before_acquire(self._node)
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._witness.after_acquire(self._node)
        return ok

    def release(self) -> None:
        self._inner.release()
        self._witness.on_release(self._node)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def locked(self) -> bool:
        fn = getattr(self._inner, "locked", None)
        return bool(fn()) if fn is not None else False

    # Condition protocol -----------------------------------------------------

    def _release_save(self):
        fn = getattr(self._inner, "_release_save", None)
        state = fn() if fn is not None else self._inner.release()
        self._witness.on_release(self._node)
        return state

    def _acquire_restore(self, state) -> None:
        self._witness.before_acquire(self._node)
        fn = getattr(self._inner, "_acquire_restore", None)
        if fn is not None:
            fn(state)
        else:
            self._inner.acquire()
        self._witness.after_acquire(self._node)

    def _is_owned(self) -> bool:
        fn = getattr(self._inner, "_is_owned", None)
        if fn is not None:
            return fn()
        return self._node in self._witness._stack()


_active: LockWitness | None = None


def current() -> LockWitness | None:
    return _active


def install(module_prefix: str = "repro.") -> LockWitness:
    """Patch the threading lock factories for `repro.*` callers.

    Locks created by other modules (threading internals, jax, pytest)
    pass through untouched; the caller module is read off the stack
    frame at construction time.
    """
    global _active
    if _active is not None:
        return _active
    witness = LockWitness()

    def _caller():
        f = sys._getframe(2)
        mod = f.f_globals.get("__name__", "")
        return mod, f.f_lineno

    def make_lock():
        mod, line = _caller()
        if not mod.startswith(module_prefix):
            return _REAL_LOCK()
        return InstrumentedLock(_REAL_LOCK(), witness, f"{mod}:{line}")

    def make_rlock():
        mod, line = _caller()
        if not mod.startswith(module_prefix):
            return _REAL_RLOCK()
        return InstrumentedLock(_REAL_RLOCK(), witness, f"{mod}:{line}")

    def make_condition(lock=None):
        mod, line = _caller()
        if lock is None and mod.startswith(module_prefix):
            lock = InstrumentedLock(_REAL_RLOCK(), witness, f"{mod}:{line} (cond)")
        if lock is None:
            return _REAL_CONDITION()
        return _REAL_CONDITION(lock)

    threading.Lock = make_lock
    threading.RLock = make_rlock
    threading.Condition = make_condition
    _active = witness
    return witness


def uninstall() -> None:
    global _active
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    threading.Condition = _REAL_CONDITION
    _active = None
