"""Finding + suppression machinery shared by every statics rule.

A finding is one (rule, file, line, message) tuple.  Suppressions are
per-line comments of the form::

    # statics: ignore[rule-a,rule-b] -- reason the violation is intentional

The reason string after ``--`` is mandatory: a suppression without one
does not suppress anything and instead raises a ``bad-suppression``
finding, so "shut it up and move on" leaves a visible trail.
"""

from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass(frozen=True)
class Suppression:
    line: int
    rules: tuple[str, ...]
    reason: str | None


_SUPPRESS_RE = re.compile(
    r"#\s*statics:\s*ignore\[(?P<rules>[A-Za-z0-9_,\- ]*)\]"
    r"(?:\s*--\s*(?P<reason>\S.*))?"
)


def parse_suppressions(source: str) -> list[Suppression]:
    out: list[Suppression] = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",") if r.strip())
        reason = m.group("reason")
        out.append(Suppression(line=lineno, rules=rules, reason=reason))
    return out


def apply_suppressions(
    findings: list[Finding], suppressions: list[Suppression], path: str
) -> list[Finding]:
    """Drop findings covered by a well-formed same-line suppression.

    Malformed suppressions (no rule list, or no ``-- reason``) never
    suppress and each contribute one ``bad-suppression`` finding.
    """
    valid_by_line: dict[int, set[str]] = {}
    kept: list[Finding] = []
    for s in suppressions:
        if s.rules and s.reason:
            valid_by_line.setdefault(s.line, set()).update(s.rules)
        else:
            kept.append(
                Finding(
                    rule="bad-suppression",
                    path=path,
                    line=s.line,
                    message=(
                        "suppression needs both a rule list and a reason: "
                        "'# statics: ignore[rule] -- why this is safe'"
                    ),
                )
            )
    for f in findings:
        if f.rule in valid_by_line.get(f.line, ()):
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept
