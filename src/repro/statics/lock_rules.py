"""Lock-discipline rules.

The codebase-wide convention: a method named ``*_locked`` assumes its
caller already holds the owning lock, so

* ``locked-call-outside-lock`` — every call to a ``*_locked`` method must
  be lexically inside a ``with self._lock:`` / ``with self._cond:`` block
  (any attribute or name matching the lock-name pattern counts), unless
  the enclosing function is itself ``*_locked``.
* ``guarded-attr-outside-lock`` — attributes registered in the guarded
  registry (e.g. ``BatchScheduler._queues`` -> ``_cond``) may only be
  touched while lexically holding the registered lock, inside a
  ``*_locked`` method, or inside ``__init__`` (no concurrent readers can
  exist before ``__init__`` returns).
* ``blocking-call-under-lock`` — no blocking call (``time.sleep``,
  ``Future.result`` without ``timeout=0``, foreign ``.wait()``, socket
  and peer I/O, ``scheduler.submit``) inside a ``with <lock>:`` body or a
  ``*_locked`` method.  This is the PR-5 mutual-forwarding deadlock
  class.

All checks are lexical: holding a lock inside a helper the caller
invoked is invisible, which is exactly why the ``*_locked`` naming
convention exists.
"""

from __future__ import annotations

import ast
import re

from .findings import Finding

# matches _lock, _bind_lock, _cond, _cv, lock, prep_lock, mutex, ...
LOCK_NAME_RE = re.compile(r"(^|_)(lock|locks|cv|cond|mutex)($|_)")

# methods whose *receiver* makes the call blocking under a lock
_SOCKET_METHODS = {
    "recv",
    "recv_into",
    "accept",
    "connect",
    "create_connection",
    "sendall",
    "makefile",
    "request",
    "handshake",
}
_SCHEDULER_SUBMIT = {"submit", "submit_many"}


def _is_lock_expr(expr: ast.expr) -> bool:
    if isinstance(expr, ast.Attribute):
        return bool(LOCK_NAME_RE.search(expr.attr))
    if isinstance(expr, ast.Name):
        return bool(LOCK_NAME_RE.search(expr.id))
    return False


def _expr_key(expr: ast.expr) -> str:
    return ast.dump(expr)


def _receiver_text(expr: ast.expr) -> str:
    """Best-effort dotted-source rendering of a call receiver."""
    try:
        return ast.unparse(expr)
    except Exception:
        return ""


class GuardedRegistry:
    """class name -> {attr name -> owning lock attr}, closed over bases.

    ``class_bases`` maps every class seen across the analyzed tree to its
    base-class names, so subclasses (FleetGraphEngine, MultihostGraphEngine)
    inherit their parents' guarded attributes.
    """

    def __init__(self, guarded: dict[str, dict[str, str]], class_bases: dict[str, list[str]]):
        self._guarded = guarded
        self._bases = class_bases
        self._cache: dict[str, dict[str, str]] = {}

    def for_class(self, name: str) -> dict[str, str]:
        if name in self._cache:
            return self._cache[name]
        merged: dict[str, str] = {}
        seen: set[str] = set()
        stack = [name]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            for attr, lock in self._guarded.get(cur, {}).items():
                merged.setdefault(attr, lock)
            stack.extend(self._bases.get(cur, []))
        self._cache[name] = merged
        return merged


# The default registry: state that has bitten us before (non-atomic stats
# snapshots, torn bindings).  Keys are attribute names on ``self``; values
# are the lock attribute that owns them.
DEFAULT_GUARDED_ATTRS: dict[str, dict[str, str]] = {
    "BatchScheduler": {
        "_queues": "_cond",
        "_credits": "_cond",
        "_latencies": "_cond",
        "_class_latencies": "_cond",
    },
    "PlanCache": {
        "_plans": "_lock",
        "_pins": "_lock",
        "_retired": "_lock",
        "_inflight": "_lock",
    },
    "GraphServeEngine": {
        "_graphs": "_bind_lock",
        "_keys": "_bind_lock",
        "_versions": "_bind_lock",
    },
}


class _FunctionLockChecker:
    """Walks one function body tracking the lexical stack of held locks."""

    def __init__(
        self,
        path: str,
        func: ast.FunctionDef | ast.AsyncFunctionDef,
        class_name: str | None,
        guarded: dict[str, str],
    ):
        self.path = path
        self.func = func
        self.class_name = class_name
        self.guarded = guarded
        self.in_locked_fn = func.name.endswith("_locked")
        self.is_init = func.name == "__init__"
        self.findings: list[Finding] = []
        # stack of ast.dump() keys of held lock expressions
        self.held: list[str] = []

    def run(self) -> list[Finding]:
        for stmt in self.func.body:
            self._walk(stmt)
        return self.findings

    # -- statement walking -------------------------------------------------

    def _walk(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def runs later, possibly on another thread: check it
            # as its own scope with a fresh (empty) held-lock stack
            sub = _FunctionLockChecker(self.path, node, self.class_name, self.guarded)
            self.findings.extend(sub.run())
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            pushed = 0
            for item in node.items:
                self._visit_expr(item.context_expr)
                if _is_lock_expr(item.context_expr):
                    self.held.append(_expr_key(item.context_expr))
                    pushed += 1
            for child in node.body:
                self._walk(child)
            for _ in range(pushed):
                self.held.pop()
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                self._walk(child)
            elif isinstance(child, ast.expr):
                self._visit_expr(child)
            elif isinstance(child, (ast.excepthandler, ast.withitem)):
                for sub in ast.iter_child_nodes(child):
                    if isinstance(sub, ast.stmt):
                        self._walk(sub)
                    elif isinstance(sub, ast.expr):
                        self._visit_expr(sub)

    def _visit_expr(self, node: ast.expr) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._check_call(sub)
            elif isinstance(sub, ast.Attribute):
                self._check_guarded_attr(sub)

    # -- rule bodies -------------------------------------------------------

    def _under_lock(self) -> bool:
        return bool(self.held) or self.in_locked_fn

    def _check_call(self, call: ast.Call) -> None:
        func = call.func
        name = None
        if isinstance(func, ast.Attribute):
            name = func.attr
        elif isinstance(func, ast.Name):
            name = func.id
        if name is None:
            return

        # locked-call-outside-lock
        if name.endswith("_locked") and not self._under_lock():
            self.findings.append(
                Finding(
                    rule="locked-call-outside-lock",
                    path=self.path,
                    line=call.lineno,
                    message=(
                        f"call to {name}() outside any 'with <lock>:' block; "
                        "*_locked methods require the caller to hold the lock"
                    ),
                )
            )

        # blocking-call-under-lock
        if self._under_lock():
            self._check_blocking(call, func, name)

    def _check_blocking(self, call: ast.Call, func: ast.expr, name: str) -> None:
        def flag(what: str) -> None:
            self.findings.append(
                Finding(
                    rule="blocking-call-under-lock",
                    path=self.path,
                    line=call.lineno,
                    message=(
                        f"{what} while holding a lock can deadlock or stall every "
                        "other thread contending for it; move it outside the "
                        "'with' block"
                    ),
                )
            )

        if not isinstance(func, ast.Attribute):
            if isinstance(func, ast.Name) and func.id == "sleep":
                flag("sleep()")
            return

        recv = func.value
        if name == "sleep":
            if isinstance(recv, ast.Name) and recv.id == "time":
                flag("time.sleep()")
            return
        if name == "result":
            if not self._is_zero_timeout(call):
                flag("Future.result() without timeout=0")
            return
        if name == "wait":
            # cond.wait() on a lock we are lexically holding releases it —
            # that is the one legitimate blocking wait under a lock
            if _expr_key(recv) in self.held:
                return
            flag(f"{_receiver_text(recv)}.wait()")
            return
        if name == "join":
            if isinstance(recv, ast.Constant) and isinstance(recv.value, str):
                return  # "sep".join(...) is string join, not thread join
            if _receiver_text(recv).endswith(("path", "os.path")):
                return
            flag(f"{_receiver_text(recv)}.join()")
            return
        if name in _SOCKET_METHODS:
            flag(f"socket/peer I/O ({_receiver_text(recv)}.{name}())")
            return
        if name in _SCHEDULER_SUBMIT:
            text = _receiver_text(recv).lower()
            if "sched" in text:
                flag(f"{_receiver_text(recv)}.{name}()")
            return

    @staticmethod
    def _is_zero_timeout(call: ast.Call) -> bool:
        for kw in call.keywords:
            if kw.arg == "timeout":
                return isinstance(kw.value, ast.Constant) and kw.value.value == 0
        if call.args:
            a = call.args[0]
            return isinstance(a, ast.Constant) and a.value == 0
        return False

    def _check_guarded_attr(self, node: ast.Attribute) -> None:
        if not self.guarded or self.is_init or self.in_locked_fn:
            return
        if not (isinstance(node.value, ast.Name) and node.value.id == "self"):
            return
        lock_attr = self.guarded.get(node.attr)
        if lock_attr is None:
            return
        want = _expr_key(ast.parse(f"self.{lock_attr}", mode="eval").body)
        if want in self.held:
            return
        self.findings.append(
            Finding(
                rule="guarded-attr-outside-lock",
                path=self.path,
                line=node.lineno,
                message=(
                    f"self.{node.attr} is guarded by self.{lock_attr}; access it "
                    f"inside 'with self.{lock_attr}:' or from a *_locked method"
                ),
            )
        )


def check(
    path: str,
    tree: ast.Module,
    registry: GuardedRegistry,
) -> list[Finding]:
    findings: list[Finding] = []

    def visit_scope(body: list[ast.stmt], class_name: str | None) -> None:
        for node in body:
            if isinstance(node, ast.ClassDef):
                visit_scope(node.body, node.name)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                guarded = registry.for_class(class_name) if class_name else {}
                findings.extend(
                    _FunctionLockChecker(path, node, class_name, guarded).run()
                )
            elif isinstance(node, (ast.If, ast.Try, ast.With)):
                visit_scope(
                    [n for n in ast.iter_child_nodes(node) if isinstance(n, ast.stmt)],
                    class_name,
                )
    visit_scope(tree.body, None)
    return findings
