"""Future/WorkItem settlement rule.

A ``WorkItem`` (or bare ``concurrent.futures.Future``) constructed and
*fully owned* by one function must reach exactly one settle call —
``complete`` / ``fail`` / ``cancel`` / ``set_result`` / ``set_exception``
— on every path out of that function.  Futures that escape (returned,
stored into an attribute/container, passed to another call, or captured
by a nested function) are someone else's responsibility and are skipped.

The path arithmetic is a conservative (min, max) settle count over the
statement tree: ``min == 0`` means some path leaks the future
(``future-leak``); ``max >= 2`` means some path can settle twice — the
mid-flush ``InvalidStateError`` class (``future-double-settle``).
"""

from __future__ import annotations

import ast

from .findings import Finding

_CONSTRUCTORS = {"WorkItem", "Future"}
_SETTLE_METHODS = {"complete", "fail", "cancel", "set_result", "set_exception"}


def _constructed_names(func: ast.FunctionDef) -> dict[str, int]:
    """local name -> lineno for `name = WorkItem(...)` / `name = Future()`."""
    out: dict[str, int] = {}
    for node in ast.walk(func):
        if not isinstance(node, ast.Assign) or not isinstance(node.value, ast.Call):
            continue
        f = node.value.func
        ctor = None
        if isinstance(f, ast.Name) and f.id in _CONSTRUCTORS:
            ctor = f.id
        elif isinstance(f, ast.Attribute) and f.attr in _CONSTRUCTORS:
            ctor = f.attr
        if ctor is None:
            continue
        for t in node.targets:
            if isinstance(t, ast.Name):
                out[t.id] = node.lineno
    return out


def _escapes(func: ast.FunctionDef, name: str) -> bool:
    for node in ast.walk(func):
        # returned / yielded
        if isinstance(node, (ast.Return, ast.Yield, ast.YieldFrom)) and node.value:
            if any(
                isinstance(n, ast.Name) and n.id == name for n in ast.walk(node.value)
            ):
                return True
        # stored somewhere that outlives the frame, or aliased
        if isinstance(node, ast.Assign):
            if any(
                isinstance(n, ast.Name) and n.id == name
                for n in ast.walk(node.value)
            ) and not (
                isinstance(node.value, ast.Call)
                and any(
                    isinstance(t, ast.Name) and t.id == name for t in node.targets
                )
            ):
                # e.g. self._items[k] = item, other = item, lst = [item]
                if not all(
                    isinstance(t, ast.Name) and t.id == name for t in node.targets
                ):
                    return True
        # passed as an argument (incl. queue.append(item), fn(item))
        if isinstance(node, ast.Call):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for n in ast.walk(arg):
                    if isinstance(n, ast.Name) and n.id == name:
                        # item.complete(x) has `item` as receiver, not arg
                        return True
        # captured by a nested function / lambda
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            if node is not func:
                body = node.body if isinstance(node.body, list) else [node.body]
                for stmt in body:
                    for n in ast.walk(stmt):
                        if isinstance(n, ast.Name) and n.id == name:
                            return True
    return False


def _stmt_settles(stmt: ast.stmt, name: str) -> int:
    """Settle calls on `name` directly inside this statement (not in nested
    compound bodies — those are handled by _count)."""
    count = 0
    for node in ast.walk(stmt):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            f = node.func
            if (
                f.attr in _SETTLE_METHODS
                and isinstance(f.value, ast.Name)
                and f.value.id == name
            ):
                count += 1
    return count


def _count(body: list[ast.stmt], name: str) -> tuple[int, int]:
    """(min, max) settle count along paths through `body`.

    Approximations: loops count as 0-or-double their body; try-bodies may
    be interrupted anywhere, so their settle count is 0..max; a return /
    raise ends the path.
    """
    lo, hi = 0, 0
    for stmt in body:
        if isinstance(stmt, ast.If):
            blo, bhi = _count(stmt.body, name)
            olo, ohi = _count(stmt.orelse, name)
            lo += min(blo, olo)
            hi += max(bhi, ohi)
        elif isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
            _, bhi = _count(stmt.body, name)
            olo, ohi = _count(stmt.orelse, name)
            lo += olo
            hi += (2 * bhi if bhi else 0) + ohi
        elif isinstance(stmt, ast.Try):
            blo, bhi = _count(stmt.body + stmt.orelse, name)
            hlos = [_count(h.body, name) for h in stmt.handlers]
            flo, fhi = _count(stmt.finalbody, name)
            if hlos:
                lo += min([blo] + [0 + h[0] for h in hlos]) + flo
                hi += max([bhi] + [bhi + h[1] for h in hlos]) + fhi
            else:
                lo += blo + flo
                hi += bhi + fhi
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            blo, bhi = _count(stmt.body, name)
            lo += blo
            hi += bhi
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        else:
            n = _stmt_settles(stmt, name)
            lo += n
            hi += n
        if isinstance(stmt, (ast.Return, ast.Raise)):
            break
    return lo, hi


def check(path: str, tree: ast.Module) -> list[Finding]:
    findings: list[Finding] = []
    for func in ast.walk(tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for name, line in _constructed_names(func).items():
            if _escapes(func, name):
                continue
            lo, hi = _count(func.body, name)
            if lo == 0:
                findings.append(
                    Finding(
                        rule="future-leak",
                        path=path,
                        line=line,
                        message=(
                            f"'{name}' is constructed here but some path through "
                            f"{func.name}() never settles it (complete/fail/"
                            "cancel); waiters would hang forever"
                        ),
                    )
                )
            if hi >= 2:
                findings.append(
                    Finding(
                        rule="future-double-settle",
                        path=path,
                        line=line,
                        message=(
                            f"'{name}' can be settled more than once on some path "
                            f"through {func.name}(); the second settle raises "
                            "InvalidStateError mid-flush"
                        ),
                    )
                )
    return findings
