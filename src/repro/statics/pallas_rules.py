"""Pallas kernel lint rules.

Applied only to files that import ``jax.experimental.pallas``.  These
turn trace-time lowering crashes into lint errors:

* ``pallas-static-args`` — a jit-wrapped function whose body calls
  ``pl.pallas_call`` must list every non-array parameter (int/str/bool
  annotation or literal default) in ``static_argnames``; a traced scalar
  there becomes an opaque tracer inside grid/BlockSpec math.
* ``pallas-traced-branch`` — kernel bodies (functions taking ``*_ref``
  parameters) must not branch with Python ``if``/``while`` on traced
  values (refs, ``pl.program_id``, or anything derived from them); use
  ``pl.when`` / ``jnp.where``.
* ``pallas-closure-numpy`` — kernel bodies must not construct or close
  over host numpy arrays; they get baked into the jaxpr as constants
  (silent recompile per distinct array, or a lowering error).
* ``pallas-tile-divisibility`` — where both the BlockSpec tile shape and
  the ``out_shape`` dims are integer literals, the tile must divide the
  padded dim exactly.
"""

from __future__ import annotations

import ast

from .findings import Finding

_NONARRAY_ANNOTATIONS = {"int", "str", "bool"}
_NP_ARRAY_BUILDERS = {
    "array",
    "zeros",
    "ones",
    "full",
    "arange",
    "empty",
    "asarray",
    "linspace",
    "eye",
}


def _imports_pallas(tree: ast.Module) -> tuple[bool, str, set[str]]:
    """Returns (uses_pallas, pallas_alias, numpy_aliases)."""
    uses = False
    pl_alias = "pl"
    np_aliases: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if "pallas" in a.name:
                    uses = True
                    if a.asname:
                        pl_alias = a.asname
                if a.name == "numpy":
                    np_aliases.add(a.asname or "numpy")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if "pallas" in mod:
                uses = True
                for a in node.names:
                    if a.name == "pallas" or "pallas" in a.name:
                        pl_alias = a.asname or a.name
            if mod == "jax.experimental" and any(a.name == "pallas" for a in node.names):
                uses = True
                for a in node.names:
                    if a.name == "pallas":
                        pl_alias = a.asname or "pallas"
    return uses, pl_alias, np_aliases


def _calls_pallas_call(func: ast.FunctionDef, pl_alias: str) -> bool:
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "pallas_call":
                return True
            if isinstance(f, ast.Name) and f.id == "pallas_call":
                return True
    return False


def _static_argnames_from_decorators(func: ast.FunctionDef) -> tuple[bool, set[str]]:
    """Returns (is_jit_wrapped, static names). Handles @functools.partial(jax.jit,
    static_argnames=(...)) and @jax.jit(static_argnames=(...))."""
    for dec in func.decorator_list:
        if not isinstance(dec, ast.Call):
            # bare @jax.jit / @jit: jit-wrapped with no statics
            if isinstance(dec, ast.Attribute) and dec.attr == "jit":
                return True, set()
            if isinstance(dec, ast.Name) and dec.id == "jit":
                return True, set()
            continue
        target = dec.func
        is_partial = (
            isinstance(target, ast.Attribute) and target.attr == "partial"
        ) or (isinstance(target, ast.Name) and target.id == "partial")
        is_jit = (isinstance(target, ast.Attribute) and target.attr == "jit") or (
            isinstance(target, ast.Name) and target.id == "jit"
        )
        mentions_jit = any(
            (isinstance(a, ast.Attribute) and a.attr == "jit")
            or (isinstance(a, ast.Name) and a.id == "jit")
            for a in dec.args
        )
        if not (is_jit or (is_partial and mentions_jit)):
            continue
        names: set[str] = set()
        for kw in dec.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                        names.add(sub.value)
        return True, names
    return False, set()


def _nonarray_params(func: ast.FunctionDef) -> list[tuple[str, int, str]]:
    """Params that are statically non-array: (name, line, why)."""
    out = []
    args = func.args
    all_args = list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    defaults: dict[str, ast.expr] = {}
    pos = list(args.posonlyargs) + list(args.args)
    for a, d in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        defaults[a.arg] = d
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if d is not None:
            defaults[a.arg] = d
    for a in all_args:
        if a.arg in ("self", "cls"):
            continue
        ann = a.annotation
        if isinstance(ann, ast.Name) and ann.id in _NONARRAY_ANNOTATIONS:
            out.append((a.arg, a.lineno, f"annotated {ann.id}"))
            continue
        d = defaults.get(a.arg)
        if (
            isinstance(d, ast.Constant)
            and isinstance(d.value, (int, str, bool))
            and not isinstance(d.value, float)
            and d.value is not None
        ):
            out.append((a.arg, a.lineno, f"default {d.value!r}"))
    return out


def _check_static_args(path: str, func: ast.FunctionDef, pl_alias: str) -> list[Finding]:
    is_jit, statics = _static_argnames_from_decorators(func)
    if not is_jit:
        return []
    findings = []
    for name, line, why in _nonarray_params(func):
        if name not in statics:
            findings.append(
                Finding(
                    rule="pallas-static-args",
                    path=path,
                    line=line,
                    message=(
                        f"parameter '{name}' of jit-wrapped pallas function "
                        f"{func.name}() is non-array ({why}) but missing from "
                        "static_argnames; it would trace as a dynamic value"
                    ),
                )
            )
    return findings


# -- kernel-body rules -----------------------------------------------------


def _is_kernel_body(func: ast.FunctionDef) -> bool:
    params = [a.arg for a in func.args.posonlyargs + func.args.args]
    return any(p.endswith("_ref") for p in params)


def _taint_set(func: ast.FunctionDef, pl_alias: str) -> set[str]:
    tainted = {
        a.arg
        for a in func.args.posonlyargs + func.args.args + func.args.kwonlyargs
        if a.arg.endswith("_ref")
    }

    def expr_tainted(expr: ast.expr) -> bool:
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id in tainted:
                return True
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "program_id":
                    return True
        return False

    # propagate through simple assignments, in order, twice (cheap fixpoint
    # for the straight-line bodies kernels actually have)
    for _ in range(2):
        for node in ast.walk(func):
            if isinstance(node, ast.Assign) and expr_tainted(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        tainted.add(t.id)
            elif isinstance(node, ast.AugAssign) and isinstance(node.target, ast.Name):
                if expr_tainted(node.value) or node.target.id in tainted:
                    tainted.add(node.target.id)
    return tainted


def _check_kernel_body(
    path: str, func: ast.FunctionDef, pl_alias: str, np_aliases: set[str]
) -> list[Finding]:
    findings = []
    tainted = _taint_set(func, pl_alias)

    def expr_refs_taint(expr: ast.expr) -> str | None:
        for node in ast.walk(expr):
            if isinstance(node, ast.Name) and node.id in tainted:
                return node.id
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "program_id":
                    return "program_id(...)"
        return None

    for node in ast.walk(func):
        if isinstance(node, (ast.If, ast.While)):
            hit = expr_refs_taint(node.test)
            if hit:
                kind = "if" if isinstance(node, ast.If) else "while"
                findings.append(
                    Finding(
                        rule="pallas-traced-branch",
                        path=path,
                        line=node.lineno,
                        message=(
                            f"Python '{kind}' on traced value '{hit}' inside kernel "
                            f"{func.name}(); traced values are abstract at trace "
                            "time — use pl.when(...) or jnp.where(...)"
                        ),
                    )
                )
        elif isinstance(node, ast.Call):
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id in np_aliases
                and f.attr in _NP_ARRAY_BUILDERS
            ):
                findings.append(
                    Finding(
                        rule="pallas-closure-numpy",
                        path=path,
                        line=node.lineno,
                        message=(
                            f"host numpy array built inside kernel {func.name}() "
                            f"({f.value.id}.{f.attr}); it becomes a baked-in jaxpr "
                            "constant — pass it as a kernel operand instead"
                        ),
                    )
                )
    return findings


def _check_module_np_closures(
    path: str, tree: ast.Module, np_aliases: set[str]
) -> list[Finding]:
    """Kernel bodies referencing module-level numpy-array constants."""
    module_arrays: dict[str, int] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            f = node.value.func
            if (
                isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id in np_aliases
                and f.attr in _NP_ARRAY_BUILDERS
            ):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        module_arrays[t.id] = node.lineno
    if not module_arrays:
        return []
    findings = []
    for func in ast.walk(tree):
        if not isinstance(func, ast.FunctionDef) or not _is_kernel_body(func):
            continue
        local = {
            a.arg
            for a in func.args.posonlyargs + func.args.args + func.args.kwonlyargs
        }
        for node in ast.walk(func):
            if (
                isinstance(node, ast.Name)
                and isinstance(node.ctx, ast.Load)
                and node.id in module_arrays
                and node.id not in local
            ):
                findings.append(
                    Finding(
                        rule="pallas-closure-numpy",
                        path=path,
                        line=node.lineno,
                        message=(
                            f"kernel {func.name}() closes over module-level numpy "
                            f"array '{node.id}' (defined line "
                            f"{module_arrays[node.id]}); pass it as an operand"
                        ),
                    )
                )
    return findings


# -- tile divisibility -----------------------------------------------------


def _literal_int_tuple(expr: ast.expr) -> list[int] | None:
    if not isinstance(expr, ast.Tuple):
        return None
    out = []
    for el in expr.elts:
        if isinstance(el, ast.Constant) and isinstance(el.value, int):
            out.append(el.value)
        else:
            return None
    return out


def _check_tile_divisibility(path: str, tree: ast.Module) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not (
            (isinstance(f, ast.Attribute) and f.attr == "pallas_call")
            or (isinstance(f, ast.Name) and f.id == "pallas_call")
        ):
            continue
        out_dims: list[int] | None = None
        tile_dims_list: list[tuple[list[int], int]] = []
        for kw in node.keywords:
            if kw.arg == "out_shape":
                # jax.ShapeDtypeStruct((literal, dims), dtype)
                v = kw.value
                if isinstance(v, ast.Call) and v.args:
                    out_dims = _literal_int_tuple(v.args[0])
            elif kw.arg == "out_specs":
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Call):
                        sf = sub.func
                        if (
                            isinstance(sf, ast.Attribute) and sf.attr == "BlockSpec"
                        ) or (isinstance(sf, ast.Name) and sf.id == "BlockSpec"):
                            if sub.args:
                                dims = _literal_int_tuple(sub.args[0])
                                if dims is not None:
                                    tile_dims_list.append((dims, sub.lineno))
        if out_dims is None:
            continue
        for tile_dims, line in tile_dims_list:
            if len(tile_dims) != len(out_dims):
                continue
            for tile, dim in zip(tile_dims, out_dims):
                if tile > 0 and dim % tile != 0:
                    findings.append(
                        Finding(
                            rule="pallas-tile-divisibility",
                            path=path,
                            line=line,
                            message=(
                                f"BlockSpec tile {tuple(tile_dims)} does not divide "
                                f"out_shape {tuple(out_dims)} ({dim} % {tile} != 0); "
                                "pad the dim or shrink the tile"
                            ),
                        )
                    )
                    break
    return findings


def check(path: str, tree: ast.Module) -> list[Finding]:
    uses, pl_alias, np_aliases = _imports_pallas(tree)
    if not uses:
        return []
    findings: list[Finding] = []
    for func in ast.walk(tree):
        if not isinstance(func, ast.FunctionDef):
            continue
        if _calls_pallas_call(func, pl_alias):
            findings.extend(_check_static_args(path, func, pl_alias))
        if _is_kernel_body(func):
            findings.extend(_check_kernel_body(path, func, pl_alias, np_aliases))
    findings.extend(_check_module_np_closures(path, tree, np_aliases))
    findings.extend(_check_tile_divisibility(path, tree))
    return findings
