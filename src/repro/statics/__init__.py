"""AST-based project invariant analyzer + runtime lock-order witness.

Static side (`analyze_paths`): lock-discipline, Pallas-kernel, and
future-settlement rules over the tree — see `scripts/check_invariants.py`
for the CLI that gates CI.  Runtime side (`witness`): an opt-in
instrumented-lock acquisition-order graph that fails a test run on a
cycle (enable with REPRO_LOCK_WITNESS=1).
"""

from .analyzer import ALL_RULES, RULE_FAMILIES, analyze_paths, collect_py_files
from .findings import Finding, apply_suppressions, parse_suppressions
from .lock_rules import DEFAULT_GUARDED_ATTRS
from .witness import InstrumentedLock, LockWitness, install, uninstall

__all__ = [
    "ALL_RULES",
    "RULE_FAMILIES",
    "analyze_paths",
    "collect_py_files",
    "Finding",
    "apply_suppressions",
    "parse_suppressions",
    "DEFAULT_GUARDED_ATTRS",
    "InstrumentedLock",
    "LockWitness",
    "install",
    "uninstall",
]
