"""Token serving engine: continuous batched greedy decoding behind the
shared :class:`~repro.serve.scheduler.BatchScheduler`.

Serving architecture (same scheduler -> flush -> dispatch shape as the
graph engine)::

    callers ----- submit(prompt, max_new) -> Future ---.
    generate() -- submit_many (sync wrapper) ----------+--> BatchScheduler
                                                           admission queue
                                                               |
                                flush (size >= batch, or oldest request
                                is max_wait_ms old)
                                                               |
                               _run_round: admit up to ``batch`` requests
                               into decode slots, then step the jit'd
                               decode loop; a slot that finishes (eos /
                               max_new) is REFILLED mid-round from the
                               queue via take_ready() — slot-reuse
                               admission, not one fixed request list per
                               call
                                                               |
                               item.complete(tokens) resolves each Future

Slot reuse is sound because the decode state tracks a per-slot sequence
start ( :func:`repro.models.lm.reset_decode_slot` ): the recycled slot's
attention masks every cache position before its admission point, and its
recurrent (mamba) state is zeroed. The jit'd step never re-specializes —
batch width, cache length and the start vector keep one shape for the
engine's lifetime.

A round ends when every active slot finished and the queue has nothing
admissible; requests whose prompt no longer fits the remaining KV budget
carry over into a fresh round (new cache) inside the same flush. A
sequence still generating when the cache fills is answered with what it
has (``cache_exhausted`` counts these truncations).

``generate()`` is the synchronous wrapper kept for backward compatibility:
it admits through the same queue, so its requests coalesce with concurrent
submitters. ``stats()`` merges engine counters with the scheduler's
(``sched_*``) — one scheduling/stats vocabulary with the graph engine.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import lm
from ..train.step import make_serve_step
from .scheduler import BatchScheduler, WorkItem


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int
    out: Optional[List[int]] = None
    latency_s: Optional[float] = None  # enqueue -> answer (queue wait incl.)


@dataclasses.dataclass
class _Slot:
    """One occupied decode slot of the running round."""

    item: WorkItem
    prompt: List[int]
    max_new: int
    fed: int = 0                # prompt tokens already fed
    emitted: List[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    """Continuous-batching greedy-decode server with slot-reuse admission."""

    def __init__(self, cfg: ArchConfig, params, batch: int, max_seq: int,
                 eos_id: int = 0, *, max_wait_ms: float = 2.0,
                 max_pending: int = 256):
        self.cfg, self.params = cfg, params
        self.batch, self.max_seq, self.eos = batch, max_seq, eos_id
        self.step_fn = jax.jit(make_serve_step(cfg))
        self.scheduler = BatchScheduler(
            self._run_round, max_batch=batch, max_wait_ms=max_wait_ms,
            max_queue=max_pending, name="lm-serve")
        # round counters (mutated only on the scheduler's flush thread)
        self.rounds = 0
        self.steps = 0              # decode-loop iterations (model calls)
        self.tokens_generated = 0
        self.prompt_tokens = 0
        self.slots_reused = 0       # mid-round admissions into freed slots
        self.cache_exhausted = 0    # sequences truncated by the KV budget
        self.total_round_s = 0.0

    # ------------------------------------------------------------ admission
    def submit(self, prompt: Sequence[int], max_new: int, *,
               block: bool = True) -> Future:
        """Admit one request; returns a ``Future`` of the generated tokens.

        Validation raises synchronously; a full queue blocks
        (backpressure) or raises ``QueueFullError`` with ``block=False``.
        """
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("prompt must be non-empty")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if len(prompt) + 1 > self.max_seq:
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot fit the "
                f"max_seq={self.max_seq} KV budget")
        return self.scheduler.submit((prompt, int(max_new)),
                                     block=block).future

    def generate(self, requests: List[Request]) -> List[Request]:
        """Synchronous wrapper: admit every request and wait for all answers."""
        assert len(requests) <= self.batch
        for r in requests:
            # validate all before admitting any (matches the graph engine)
            if not r.prompt or r.max_new < 1 \
                    or len(r.prompt) + 1 > self.max_seq:
                raise ValueError(f"invalid request: prompt={len(r.prompt)} "
                                 f"tokens, max_new={r.max_new}")
        items = self.scheduler.submit_many(
            [([int(t) for t in r.prompt], int(r.max_new)) for r in requests])
        for r, item in zip(requests, items):
            r.out = item.future.result()
            r.latency_s = item.latency_s
        return requests

    def close(self) -> None:
        """Stop the background scheduler (drains anything still queued)."""
        self.scheduler.stop()

    # ------------------------------------------------------------ decoding
    def _run_round(self, items: List[WorkItem]) -> None:
        """Scheduler flush callback: decode rounds until every item (and
        every mid-round admission) is answered."""
        pending = list(items)
        while pending:
            pending = self._round(pending)

    def _admit(self, slots: List[Optional[_Slot]], slot_idx: int,
               item: WorkItem, tokens: np.ndarray) -> _Slot:
        prompt, max_new = item.payload
        s = _Slot(item=item, prompt=prompt, max_new=max_new, fed=1)
        slots[slot_idx] = s
        tokens[slot_idx, 0] = prompt[0]
        self.prompt_tokens += len(prompt)
        return s

    def _round(self, initial: List[WorkItem]) -> List[WorkItem]:
        """One decode round over a fresh cache; returns carried-over items
        that arrived mid-round but need a fresh cache of their own."""
        t0 = time.perf_counter()
        B, S = self.batch, self.max_seq
        state = lm.track_slot_starts(
            lm.init_decode_state(self.cfg, B, S), B)
        slots: List[Optional[_Slot]] = [None] * B
        tokens = np.zeros((B, 1), np.int32)
        carry: List[WorkItem] = []

        for i, item in enumerate(initial[:B]):
            self._admit(slots, i, item, tokens)
        carry.extend(initial[B:])   # oversized burst: next round's seed

        pos = 0                     # tokens already in the cache
        while any(s is not None for s in slots):
            if pos >= S:
                # KV budget exhausted: answer active slots with what they
                # have (prefill-complete slots only; admission guarantees
                # every admitted prompt finishes prefilling before this)
                for i, s in enumerate(slots):
                    if s is not None:
                        self.cache_exhausted += 1
                        self._finish(slots, i)
                break
            # snapshot the token buffer: on CPU, jnp.asarray aliases the
            # numpy memory zero-copy, and `tokens` is mutated in place below
            # while this step may still be executing asynchronously
            nxt, _, state = self.step_fn(self.params, state,
                                         jnp.asarray(tokens.copy()))
            self.steps += 1
            pos += 1
            nxt_np: Optional[np.ndarray] = None
            for i, s in enumerate(slots):
                if s is None:
                    continue
                if s.fed < len(s.prompt):       # still prefilling
                    tokens[i, 0] = s.prompt[s.fed]
                    s.fed += 1
                    continue
                if nxt_np is None:
                    nxt_np = np.asarray(nxt)
                tok = int(nxt_np[i, 0])
                s.emitted.append(tok)
                self.tokens_generated += 1
                if len(s.emitted) >= s.max_new or tok == self.eos:
                    self._finish(slots, i)
                else:
                    tokens[i, 0] = tok

            # slot-reuse admission: refill freed slots with queued work
            free = [i for i, s in enumerate(slots) if s is None]
            if free and any(s is not None for s in slots) and pos + 2 <= S:
                for item in self.scheduler.take_ready(len(free)):
                    prompt, _ = item.payload
                    if free and pos + len(prompt) + 1 <= S:
                        i = free.pop(0)
                        self._admit(slots, i, item, tokens)
                        state = lm.reset_decode_slot(self.cfg, state, i)
                        self.slots_reused += 1
                    else:           # needs a fresh cache: next round
                        carry.append(item)

        self.rounds += 1
        self.total_round_s += time.perf_counter() - t0
        return carry

    def _finish(self, slots: List[Optional[_Slot]], i: int) -> None:
        s = slots[i]
        slots[i] = None
        s.item.complete(list(s.emitted))

    # ------------------------------------------------------------ stats
    def stats(self) -> dict:
        s = {f"sched_{k}": v for k, v in self.scheduler.stats().items()}
        s.update(
            rounds=self.rounds,
            steps=self.steps,
            tokens_generated=self.tokens_generated,
            prompt_tokens=self.prompt_tokens,
            slots_reused=self.slots_reused,
            cache_exhausted=self.cache_exhausted,
            total_round_s=self.total_round_s,
            tokens_per_s=(self.tokens_generated / self.total_round_s
                          if self.total_round_s else 0.0),
            # decode-slot utilization: generated tokens per model step,
            # out of `batch` slots stepping each iteration
            slot_utilization=(self.tokens_generated
                              / (self.steps * self.batch)
                              if self.steps else 0.0),
        )
        return s
