"""Batched serving engine: continuous batched greedy decoding with a static
KV budget. Requests are padded into a fixed batch; finished sequences are
masked and replaced (slot reuse), so the jit'd step never re-specializes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import lm
from ..train.step import make_serve_step


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int
    out: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, batch: int, max_seq: int,
                 eos_id: int = 0):
        self.cfg, self.params = cfg, params
        self.batch, self.max_seq, self.eos = batch, max_seq, eos_id
        self.step_fn = jax.jit(make_serve_step(cfg))

    def _prefill(self, state, tokens_np):
        """Prefill by stepping tokens one at a time through the decode path
        (exactly equal to the chunked prefill by construction; see tests)."""
        T = tokens_np.shape[1]
        toks = jnp.asarray(tokens_np)
        logits = None
        for t in range(T):
            _, logits, state = self.step_fn(self.params, state, toks[:, t:t + 1])
        return state, logits

    def generate(self, requests: List[Request]) -> List[Request]:
        assert len(requests) <= self.batch
        B = self.batch
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        state = lm.init_decode_state(self.cfg, B, self.max_seq)
        state, logits = self._prefill(state, prompts)
        nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)[:, None]
        max_new = max(r.max_new for r in requests)
        outs = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        for _ in range(max_new):
            for i in range(len(requests)):
                if not done[i]:
                    outs[i].append(int(nxt[i, 0]))
                    if len(outs[i]) >= requests[i].max_new or nxt[i, 0] == self.eos:
                        done[i] = True
            if done[: len(requests)].all():
                break
            nxt_j, _, state = self.step_fn(self.params, state, jnp.asarray(nxt))
            nxt = np.asarray(nxt_j)
        for i, r in enumerate(requests):
            r.out = outs[i]
        return requests
