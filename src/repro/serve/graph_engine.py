"""Graph inference serving: plan-cached, multi-graph-batched SpMM dispatch.

The serving shape of the Accel-GCN operator: requests name a registered graph
and carry a feature matrix; the engine

1. resolves each graph to its cached :class:`PartitionPlan` (the O(n)
   preprocessing — degree sort, pattern table, slab packing — runs once per
   distinct graph and config, then is a cache hit forever);
2. merges same-graph requests along the feature axis (one gather of the
   slabs serves every concurrent request on that graph);
3. packs up to ``max_graphs_per_batch`` distinct graphs into ONE fused
   kernel dispatch (`repro.kernels.spmm_batched`), with block-count
   bucketing so repeated batches reuse a single compiled kernel;
4. routes each fused dispatch by VMEM footprint (``backend="auto"``):
   the concatenated feature rows of a batch can overflow the resident
   kernel's budget even when every member graph fits, so oversized batches
   fall back to the row-windowed or HBM-gather kernel instead of silently
   blowing the budget — per-dispatch choices are logged and counted in
   ``stats()`` (``routed_resident`` / ``routed_windowed`` / ``routed_hbm``);
5. un-permutes each graph's rows back to original order and splits feature
   columns back per request.

Throughput/latency counters accumulate across ``serve`` calls; ``stats()``
merges them with the plan cache's hit/miss/build/eviction counters. Each
request records its enqueue->answer wall time (queue wait included);
per-dispatch kernel time accumulates separately in ``total_serve_s``.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core.graph import CSRGraph, gcn_normalize
from ..core.plan_cache import (
    PartitionConfig, PartitionPlan, PlanCache, build_partition_plan,
)
from ..kernels.router import RoutingDecision
from ..kernels.spmm_batched import bucket_blocks, spmm_batched

__all__ = ["GraphRequest", "GraphServeEngine"]

logger = logging.getLogger(__name__)

_BACKENDS = ("auto", "pallas", "windowed", "hbm", "blocked")


@dataclasses.dataclass
class GraphRequest:
    """One aggregation request: A'_graph_id @ x, answered in ORIGINAL row order."""

    graph_id: str
    x: jax.Array                       # [n_cols(graph), F]
    out: Optional[jax.Array] = None    # filled by serve()
    latency_s: Optional[float] = None  # enqueue -> answer wall time (includes
    #                                    queue wait behind earlier dispatches
    #                                    of the same serve() call)


class GraphServeEngine:
    """Batched multi-graph SpMM server over a partition-plan cache."""

    def __init__(
        self,
        *,
        config: Optional[PartitionConfig] = None,
        cache: Optional[PlanCache] = None,
        cache_capacity: int = 32,
        backend: str = "blocked",
        interpret: bool = True,
        max_graphs_per_batch: int = 8,
        block_bucket: Optional[int] = 8,
    ):
        self.config = config or PartitionConfig()
        self.cache = cache if cache is not None else PlanCache(cache_capacity)
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {'|'.join(_BACKENDS)}")
        self.backend = backend
        self.interpret = interpret
        self.max_graphs_per_batch = max_graphs_per_batch
        # min bucket tier: power-of-two tiers from here cap padding waste
        # below 2x the live blocks (the old fixed-256 floor padded a 3-block
        # batch to 256 — 85x dead grid steps).
        self.block_bucket = block_bucket
        self._graphs: Dict[str, CSRGraph] = {}
        self._keys: Dict[str, tuple] = {}  # graph_id -> plan key (hashed once)
        # serving counters
        self.requests_served = 0
        self.batches_dispatched = 0
        self.rows_served = 0
        self.values_served = 0       # rows * feature columns
        self.total_serve_s = 0.0     # sum of per-DISPATCH kernel wall times
        self.total_request_latency_s = 0.0  # sum of enqueue->answer times
        self.live_blocks = 0         # merged blocks carrying real slabs
        self.padded_blocks = 0       # blocks actually dispatched (bucketed)
        self.backend_dispatches: Dict[str, int] = {
            "resident": 0, "windowed": 0, "hbm": 0, "blocked": 0}
        self.last_decision: Optional[RoutingDecision] = None

    # ------------------------------------------------------------------ admin
    def register_graph(self, graph_id: str, g: CSRGraph,
                       normalize: bool = False) -> PartitionPlan:
        """Register (and warm the plan for) a graph under ``graph_id``.

        Re-registering the same id with identical content is a no-op (cache
        hit); different content replaces the binding.
        """
        if normalize:
            g = gcn_normalize(g)
        self._graphs[graph_id] = g
        plan = self.cache.get_or_build(g, self.config)
        self._keys[graph_id] = plan.key
        return plan

    def graph_ids(self) -> List[str]:
        return list(self._graphs)

    def plan_for(self, graph_id: str) -> PartitionPlan:
        """Resolve a registered graph's plan WITHOUT rehashing its arrays —
        the content hash was paid once at registration; a rebuild only
        happens if the plan was LRU-evicted since."""
        key = self._keys[graph_id]
        return self.cache.get_by_key(
            key, lambda: build_partition_plan(
                self._graphs[graph_id], self.config, graph_hash=key[0]))

    # ------------------------------------------------------------------ serve
    def serve_one(self, graph_id: str, x: jax.Array) -> jax.Array:
        """Convenience single-request path (still goes through the batch code)."""
        return self.serve([GraphRequest(graph_id, x)])[0].out

    def serve(self, requests: Sequence[GraphRequest]) -> List[GraphRequest]:
        """Answer a list of requests, batching as aggressively as possible."""
        t_enqueue = time.perf_counter()   # latency clock for EVERY request
        # Group same-graph requests: their features fuse along the F axis so
        # the slab gather runs once for all of them.
        order: List[str] = []
        groups: Dict[str, List[GraphRequest]] = {}
        for r in requests:
            if r.graph_id not in self._graphs:
                raise KeyError(f"graph {r.graph_id!r} not registered "
                               f"(known: {sorted(self._graphs)})")
            if r.graph_id not in groups:
                groups[r.graph_id] = []
                order.append(r.graph_id)
            groups[r.graph_id].append(r)

        # Validate EVERY request before dispatching ANY batch, so a malformed
        # request cannot leave the call half-served with mutated counters.
        plans = {gid: self.plan_for(gid) for gid in order}
        for gid in order:
            for r in groups[gid]:
                shape = tuple(getattr(r.x, "shape", ()))
                if len(shape) != 2 or shape[0] != plans[gid].n_cols:
                    raise ValueError(
                        f"request for {gid!r} has features {shape}, "
                        f"expected [{plans[gid].n_cols}, F]")

        for start in range(0, len(order), self.max_graphs_per_batch):
            self._dispatch([(gid, groups[gid], plans[gid])
                            for gid in order[start:start + self.max_graphs_per_batch]],
                           t_enqueue)
        return list(requests)

    def _dispatch(self, batch, t_enqueue: float) -> None:
        """One fused kernel call over up to max_graphs_per_batch graphs."""
        t0 = time.perf_counter()
        plans: List[PartitionPlan] = []
        xs: List[jax.Array] = []
        col_splits: List[List[int]] = []
        for gid, reqs, plan in batch:
            feats = [jnp.asarray(r.x, dtype=jnp.float32) for r in reqs]
            plans.append(plan)
            xs.append(feats[0] if len(feats) == 1
                      else jnp.concatenate(feats, axis=1))
            col_splits.append([int(f.shape[1]) for f in feats])

        b_total = sum(p.num_blocks for p in plans)
        pad_to = None
        if self.block_bucket:
            pad_to = bucket_blocks(b_total, self.block_bucket)
        outs, decision = spmm_batched(
            [p.slabs for p in plans], xs, [p.n_rows for p in plans],
            backend=self.backend, interpret=self.interpret,
            pad_blocks_to=pad_to, return_decision=True)
        jax.block_until_ready(outs)
        t_done = time.perf_counter()
        dt = t_done - t0                       # this dispatch's kernel time
        latency = t_done - t_enqueue           # enqueue -> answer, incl. queue

        executed = decision.backend if decision is not None else "blocked"
        self.backend_dispatches[executed] += 1
        self.last_decision = decision
        self.live_blocks += b_total
        self.padded_blocks += pad_to if pad_to else b_total
        if logger.isEnabledFor(logging.DEBUG):
            logger.debug(
                "dispatch: graphs=%d blocks=%d->%d backend=%s (%s) %.1fms",
                len(batch), b_total, pad_to or b_total, executed,
                decision.reason if decision else "jnp twin", dt * 1e3)

        for (gid, reqs, plan), out, widths in zip(batch, outs, col_splits):
            out = out[plan.inv_perm]          # back to original row order
            col = 0
            for r, w in zip(reqs, widths):
                r.out = out[:, col:col + w]
                r.latency_s = latency
                col += w
                self.requests_served += 1
                self.rows_served += plan.n_rows
                self.values_served += plan.n_rows * w
                self.total_request_latency_s += latency
        self.batches_dispatched += 1
        self.total_serve_s += dt

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        s = {f"cache_{k}": v for k, v in self.cache.stats().items()}
        s.update(
            registered_graphs=len(self._graphs),
            requests_served=self.requests_served,
            batches_dispatched=self.batches_dispatched,
            rows_served=self.rows_served,
            values_served=self.values_served,
            total_serve_s=self.total_serve_s,
            requests_per_batch=(self.requests_served / self.batches_dispatched
                                if self.batches_dispatched else 0.0),
            rows_per_s=(self.rows_served / self.total_serve_s
                        if self.total_serve_s else 0.0),
            # routing: which kernel regime each fused dispatch executed on
            routed_resident=self.backend_dispatches["resident"],
            routed_windowed=self.backend_dispatches["windowed"],
            routed_hbm=self.backend_dispatches["hbm"],
            routed_blocked=self.backend_dispatches["blocked"],
            # block bucketing waste: padded/live == 1.0 means no dead steps
            live_blocks=self.live_blocks,
            padded_blocks=self.padded_blocks,
            block_pad_ratio=(self.padded_blocks / self.live_blocks
                             if self.live_blocks else 0.0),
            # latency: per-dispatch kernel time vs per-request wait
            avg_dispatch_s=(self.total_serve_s / self.batches_dispatched
                            if self.batches_dispatched else 0.0),
            avg_request_latency_s=(
                self.total_request_latency_s / self.requests_served
                if self.requests_served else 0.0),
        )
        return s
