"""Graph inference serving: continuous-batched, plan-cached SpMM dispatch.

Serving architecture (scheduler -> flush -> route -> dispatch)::

    callers ----- submit(graph_id, x) -> Future ------.
    threads ----- submit(graph_id, x) -> Future ------+--> BatchScheduler
    serve(reqs) - submit_many (sync wrapper) ---------'    admission queue
                                                               |
                               flush (size >= max_batch_requests, or the
                               oldest request is max_wait_ms old)
                                                               |
                                  _flush: group requests BY PLAN (graph),
                                  fuse same-graph features along the F
                                  axis, chunk distinct graphs into
                                  dispatches of <= max_graphs_per_batch
                                                               |
                                  _dispatch: merge slabs, bucket blocks,
                                  route by VMEM footprint (auto: resident /
                                  windowed / hbm), ONE fused pallas_call
                                                               |
                                  un-permute rows, split feature columns,
                                  item.complete(out) resolves each Future

The background admission queue is what makes batching *cross-caller*: the
old blocking ``serve()`` could only fuse requests its own caller had
already collected, so two concurrent callers never shared a dispatch and
the plan cache was touched from multiple threads without a lock. Now every
entry point funnels into one scheduler ( :mod:`repro.serve.scheduler` ),
requests on recurring graphs coalesce into fused dispatches no matter who
submitted them, and the (thread-safe) plan cache is read from the single
flush thread.

Tuning knobs:

* ``max_batch_requests`` / ``max_wait_ms`` — scheduler flush triggers.
  ``max_wait_ms`` bounds the co-batching wait of a lone request; under
  sustained load flushes are size-triggered and the knob is irrelevant.
* ``max_graphs_per_batch`` — distinct graphs fused into one kernel call
  (a flush larger than this becomes several dispatches, in arrival order).
* ``max_pending`` — admission bound; full queue blocks submitters
  (backpressure) or raises with ``submit(..., block=False)``.

Per-request (enqueue->answer) latency comes from the scheduler's WorkItem
clock; per-dispatch kernel time accumulates separately in ``total_serve_s``.
``stats()`` merges engine counters, plan-cache counters (``cache_*``) and
scheduler counters (``sched_*``).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import CSRGraph, gcn_normalize
from ..core.plan_cache import (
    PartitionConfig, PartitionPlan, PlanCache, _config_tag,
    build_partition_plan, graph_content_hash,
)
from ..core.plan_repair import EdgeDelta, delta_chain_hash, repair_plan
from ..kernels.router import RoutingDecision
from ..kernels.spmm_batched import bucket_blocks, spmm_batched
from ..tuning.tuner import PlanTuner
from ..tuning.search import TuningCandidate
from .scheduler import BatchScheduler, ClassSpec, WorkItem

__all__ = ["GraphRequest", "GraphServeEngine"]

logger = logging.getLogger(__name__)

_BACKENDS = ("auto", "pallas", "windowed", "hbm", "blocked")

# per-plan dispatch timing ring: last N wall times per plan key, bounded
# to the most recently dispatched keys so a graph-churn workload can't
# grow the map without bound
PLAN_TIMING_RING = 64
PLAN_TIMING_KEYS = 256


@dataclasses.dataclass
class GraphRequest:
    """One aggregation request: A'_graph_id @ x, answered in ORIGINAL row order."""

    graph_id: str
    x: jax.Array                       # [n_cols(graph), F]
    out: Optional[jax.Array] = None    # filled by serve()
    latency_s: Optional[float] = None  # enqueue -> answer wall time (includes
    #                                    queue wait behind earlier dispatches)
    klass: str = "default"             # SLO class (must name a ClassSpec)
    tenant: Optional[str] = None       # opaque owner tag (stats only)


class GraphServeEngine:
    """Continuous-batching multi-graph SpMM server over a partition-plan cache.

    ``submit`` is the native entry point (asynchronous, returns a
    ``Future``); ``serve``/``serve_one`` are thin synchronous wrappers that
    submit and wait, kept for backward compatibility — all three share the
    scheduler, so synchronous callers still coalesce with concurrent
    submitters.
    """

    def __init__(
        self,
        *,
        config: Optional[PartitionConfig] = None,
        cache: Optional[PlanCache] = None,
        cache_capacity: int = 32,
        backend: str = "blocked",
        interpret: bool = True,
        max_graphs_per_batch: int = 8,
        block_bucket: Optional[int] = 8,
        max_batch_requests: Optional[int] = None,
        max_wait_ms: float = 2.0,
        max_pending: int = 256,
        feature_bucket: bool = True,
        classes: Optional[Sequence[ClassSpec]] = None,
        repair_churn_threshold: float = 0.25,
        tuner: Optional[PlanTuner] = None,
    ):
        self.config = config or PartitionConfig()
        self.cache = cache if cache is not None else PlanCache(cache_capacity)
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {'|'.join(_BACKENDS)}")
        self.backend = backend
        self.interpret = interpret
        self.max_graphs_per_batch = max_graphs_per_batch
        # min bucket tier: power-of-two tiers from here cap padding waste
        # below 2x the live blocks (the old fixed-256 floor padded a 3-block
        # batch to 256 — 85x dead grid steps).
        self.block_bucket = block_bucket
        # fused feature widths round up to powers of two: the width of a
        # same-graph group is (requests in flush) x F, which varies with
        # flush composition under concurrent traffic — bucketing keeps the
        # compiled-shape set logarithmic instead of one shape per mix
        self.feature_bucket = feature_bucket
        # above this fraction of rows dirtied by a delta, incremental plan
        # repair falls back to a full rebuild (see core.plan_repair)
        self.repair_churn_threshold = repair_churn_threshold
        self._graphs: Dict[str, CSRGraph] = {}
        self._keys: Dict[str, tuple] = {}  # graph_id -> plan key (hashed once)
        self._versions: Dict[str, int] = {}  # graph_id -> published version
        # _bind_lock guards the three maps above as ONE atomic binding:
        # readers (plan_for, _validate-time lookups) must never observe a
        # graph from version v+1 paired with the key of version v
        self._bind_lock = threading.Lock()
        # serializes mutation application + publish per engine; reads never
        # take it (they pin a version instead)
        self._mutate_lock = threading.Lock()
        # one flush absorbs several dispatches' worth of requests so a
        # deadline-triggered flush under load still fills whole batches
        self.scheduler = BatchScheduler(
            self._flush,
            max_batch=max_batch_requests or 4 * max_graphs_per_batch,
            max_wait_ms=max_wait_ms,
            max_queue=max_pending,
            name="graph-serve",
            classes=classes,
        )
        # serving counters. The base engine mutates them only on the
        # scheduler's flush thread; the fleet subclass dispatches from a
        # device pool, so every counter update takes this (uncontended in
        # the single-device case) lock.
        self._counters_lock = threading.Lock()
        self.requests_served = 0
        self.batches_dispatched = 0
        self.graphs_dispatched = 0   # distinct graphs summed over dispatches
        self.rows_served = 0
        self.values_served = 0       # rows * feature columns
        self.total_serve_s = 0.0     # sum of per-DISPATCH kernel wall times
        self.total_request_latency_s = 0.0  # sum of enqueue->answer times
        self.live_blocks = 0         # merged blocks carrying real slabs
        self.padded_blocks = 0       # blocks actually dispatched (bucketed)
        self.backend_dispatches: Dict[str, int] = {
            "resident": 0, "windowed": 0, "hbm": 0, "blocked": 0}
        self.last_decision: Optional[RoutingDecision] = None
        # mutation-path counters (versioned plan lifecycle)
        self.mutations_applied = 0   # mutate() requests resolved
        self.mutation_edges = 0      # edge inserts+deletes applied
        self.plan_repairs = 0        # publishes served by incremental repair
        self.plan_rebuilds = 0       # publishes that fell back to full build
        # per-plan dispatch wall times: key -> deque of (seconds, exact)
        # where exact=True means the dispatch held ONLY this plan (a fused
        # multi-graph dispatch records its per-plan SHARE, flagged inexact).
        # Appended under _counters_lock on the dispatch path; stats() and
        # the tuner's incumbent estimate read it there too.
        self._plan_times: "OrderedDict[tuple, deque]" = OrderedDict()
        # --- online partition autotuning (shadow-measured rollout) -------
        # The tuner only ever acts on COPIES of live work: a shadow
        # duplicates one dispatch onto the candidate plan on a separate
        # single worker thread AFTER the live futures resolved, so the
        # serving path never waits on a candidate (reads never pay for
        # candidates). At most one shadow is in flight per engine; when
        # the worker is busy the opportunity is skipped, never queued.
        self.tuner = tuner
        self._shadow_pool: Optional[ThreadPoolExecutor] = None
        self._shadow_lock = threading.Lock()
        self._shadow_inflight = False
        # tuned dispatch hints by graph id, re-attached to plans rebuilt
        # from scratch after an eviction (the structure comes back via the
        # config in the key; the backend/grid_order hints live here)
        self._tuned_hints: Dict[str, Dict] = {}
        self.shadow_dispatches = 0   # candidate measurements completed
        self.shadow_skipped = 0      # opportunities dropped (worker busy)
        self.shadow_failures = 0     # candidate build/dispatch raised
        self.shadow_time_s = 0.0     # wall time spent in shadow dispatches
        self.tuned_promotions = 0    # tuned configs published

    # ------------------------------------------------------------------ admin
    def register_graph(self, graph_id: str, g: CSRGraph,
                       normalize: bool = False) -> PartitionPlan:
        """Register (and warm the plan for) a graph under ``graph_id``.

        Re-registering the same id with identical content is a no-op (cache
        hit); different content replaces the binding. A same-content
        re-register keeps a TUNED binding (the autotuner may have promoted
        a non-default config for this graph — identical content must not
        silently reset it to ``self.config``).
        """
        if normalize:
            g = gcn_normalize(g)
        h = graph_content_hash(g)
        with self._bind_lock:
            prev_key = self._keys.get(graph_id)
        if prev_key is not None and prev_key[0] == h and \
                prev_key != (h, self.config):
            return self.plan_for(graph_id)  # tuned binding, same content
        key = (h, self.config)
        plan = self.cache.get_by_key(
            key, lambda: build_partition_plan(g, self.config, graph_hash=h))
        with self._bind_lock:
            prev_key = self._keys.get(graph_id)
            prev_ver = self._versions.get(graph_id)
            if prev_key == plan.key and prev_ver is not None:
                version = prev_ver          # idempotent re-register
            elif prev_ver is not None:
                # content replacement continues the id's version chain so
                # directory/version invalidation stays monotone
                version = max(plan.version, prev_ver + 1)
            else:
                version = plan.version
            self._graphs[graph_id] = g
            self._keys[graph_id] = plan.key
            self._versions[graph_id] = version
        return plan

    def register_subgraph(self, g: CSRGraph, prefix: str = "sub",
                          normalize: bool = False) -> str:
        """Register an induced subgraph under a CONTENT-DERIVED id.

        The id is ``f"{prefix}:{content_hash[:16]}"`` — the same frontier
        sampled twice registers under the same id and partitions exactly
        once (registration is idempotent on identical content). This is
        the submission path for sampled-inference frontiers: callers never
        invent ids, so recurring frontiers from different callers share
        one plan-cache entry. Returns the graph id to pass to ``submit``.
        """
        if normalize:
            g = gcn_normalize(g)
        graph_id = f"{prefix}:{graph_content_hash(g)[:16]}"
        self.register_graph(graph_id, g)
        return graph_id

    def unregister_graph(self, graph_id: str) -> bool:
        """Drop a graph's binding (id -> graph/key/version/tuned hints).

        The plan itself stays in the LRU cache until evicted — a later
        ``register_subgraph`` of the same content re-binds without a
        rebuild. The caller must have drained in-flight work for the id
        (the sampling service evicts only after results are gathered);
        the engine does not fence racing submits. Returns whether the id
        was registered.
        """
        with self._bind_lock:
            known = graph_id in self._graphs
            self._graphs.pop(graph_id, None)
            self._keys.pop(graph_id, None)
            self._versions.pop(graph_id, None)
            self._tuned_hints.pop(graph_id, None)
        return known

    def submit_gather(self, graph_id: str, x: jax.Array,
                      rows: np.ndarray, *, block: bool = True,
                      klass: str = "default",
                      tenant: Optional[str] = None) -> Future:
        """``submit`` plus a gather epilogue: the returned ``Future``
        resolves to ``aggregation[rows]`` instead of the full ``[n_rows,
        F]`` output. This is how sampled inference extracts per-seed
        outputs from a frontier subgraph dispatch without shipping the
        whole frontier's activations back to the caller.
        """
        rows = np.asarray(rows, dtype=np.int64)
        inner = self.submit(graph_id, x, block=block, klass=klass,
                            tenant=tenant)
        outer: Future = Future()

        def _chain(f: Future) -> None:
            if f.cancelled():
                outer.cancel()
                return
            exc = f.exception()
            if exc is not None:
                outer.set_exception(exc)
                return
            try:
                outer.set_result(f.result()[rows])
            except BaseException as e:  # noqa: BLE001 — surfaced via future
                outer.set_exception(e)

        inner.add_done_callback(_chain)
        return outer

    def graph_ids(self) -> List[str]:
        with self._bind_lock:
            return list(self._graphs)

    def plan_for(self, graph_id: str) -> PartitionPlan:
        """Resolve a registered graph's plan WITHOUT rehashing its arrays —
        the content hash was paid once at registration; a rebuild only
        happens if the plan was LRU-evicted since. The rebuild uses the
        config EMBEDDED IN THE KEY (not ``self.config``): after the tuner
        promotes a non-default config, an evicted plan must rebuild with
        its tuned structure. Tuned dispatch hints are re-attached from the
        engine's hint map when the rebuild lost them."""
        with self._bind_lock:   # key and graph must be the SAME version
            key = self._keys[graph_id]
            g = self._graphs[graph_id]
        plan = self.cache.get_by_key(
            key, lambda: build_partition_plan(
                g, key[1], graph_hash=key[0]))
        if plan.tuned is None:
            hints = self._tuned_hints.get(graph_id)
            if hints is not None and plan.key[1] == hints["config"]:
                plan.tuned = hints["tuned"]
        return plan

    def graph_version(self, graph_id: str) -> int:
        """Current published version of a registered graph's plan chain."""
        with self._bind_lock:
            return self._versions[graph_id]

    def close(self) -> None:
        """Stop the background scheduler (drains anything still queued)."""
        self.scheduler.stop()
        with self._shadow_lock:
            pool, self._shadow_pool = self._shadow_pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # ------------------------------------------------------------------ serve
    def _validate(self, graph_id: str, x) -> None:
        """Cheap synchronous admission checks: registration + feature shape.

        Deliberately does NOT touch the plan cache — the registered graph
        already knows its n_cols, so validation stays O(1) on the caller
        thread and plan resolution (which can mean an O(n) rebuild after an
        eviction) happens on the flush thread where it belongs.
        """
        with self._bind_lock:
            g = self._graphs.get(graph_id)
            if g is None:
                raise KeyError(f"graph {graph_id!r} not registered "
                               f"(known: {sorted(self._graphs)})")
        shape = tuple(getattr(x, "shape", ()))
        if len(shape) != 2 or shape[0] != g.n_cols:
            raise ValueError(
                f"request for {graph_id!r} has features {shape}, "
                f"expected [{g.n_cols}, F]")

    def submit(self, graph_id: str, x: jax.Array, *,
               block: bool = True, klass: str = "default",
               tenant: Optional[str] = None) -> Future:
        """Admit one request; returns a ``Future`` of the ``[n_rows, F]``
        aggregation in ORIGINAL row order.

        Validation (unknown graph, wrong feature shape, unknown SLO class)
        raises here, synchronously. A full admission queue blocks
        (backpressure) or, with ``block=False``, raises
        :class:`repro.serve.scheduler.QueueFullError`. ``klass`` names one
        of the engine's configured :class:`ClassSpec` entries; ``tenant``
        is an opaque owner tag carried into per-class stats.
        """
        self._validate(graph_id, x)
        return self.scheduler.submit((graph_id, x), block=block,
                                     klass=klass, tenant=tenant).future

    def mutate(self, graph_id: str, delta: EdgeDelta, *,
               block: bool = True, klass: str = "default",
               tenant: Optional[str] = None) -> Future:
        """Admit a batched edge delta against a registered graph.

        Returns a ``Future`` resolving to a dict
        ``{"graph_id", "version", "repaired", "reason", "dirty_rows"}``
        once the new plan version is PUBLISHED — later submits observe the
        mutated graph. Mutations ride the same admission queue as reads:
        a flush dispatches its reads first (against the pre-publish
        version, which they pin for the duration of the kernel call), then
        applies that flush's deltas per graph in arrival order and
        publishes once per graph. In-flight reads are therefore never
        blocked and never torn — every answer is consistent with either
        the pre- or post-publish version.
        """
        with self._bind_lock:
            if graph_id not in self._graphs:
                raise KeyError(f"graph {graph_id!r} not registered "
                               f"(known: {sorted(self._graphs)})")
        if not isinstance(delta, EdgeDelta):
            raise TypeError(f"delta must be an EdgeDelta, got {type(delta)!r}")
        return self.scheduler.submit((graph_id, delta, "mutate"), block=block,
                                     klass=klass, tenant=tenant).future

    def serve_one(self, graph_id: str, x: jax.Array) -> jax.Array:
        """Convenience single-request path (still goes through the batch code)."""
        return self.serve([GraphRequest(graph_id, x)])[0].out

    def serve(self, requests: Sequence[GraphRequest]) -> List[GraphRequest]:
        """Synchronous wrapper: submit every request and wait for all answers.

        Validates EVERY request before admitting ANY, so a malformed
        request cannot leave the call half-served with mutated counters.
        The requests enter the admission queue as one contiguous run and
        typically share flushes (and fused dispatches) — including with
        requests other threads submitted concurrently.
        """
        for r in requests:
            self._validate(r.graph_id, r.x)
        items = self.scheduler.submit_many(
            [(r.graph_id, r.x) for r in requests],
            klass=[r.klass for r in requests],
            tenant=[r.tenant for r in requests])
        first_exc: Optional[BaseException] = None
        for r, item in zip(requests, items):
            try:
                r.out = item.future.result()
                r.latency_s = item.latency_s
            except BaseException as e:  # noqa: BLE001 — re-raised below
                if first_exc is None:
                    first_exc = e
        if first_exc is not None:
            raise first_exc
        return list(requests)

    # ------------------------------------------------------------------ flush
    @staticmethod
    def _group_by_graph(items: List[WorkItem]
                        ) -> Tuple[List[str], Dict[str, List[WorkItem]]]:
        """Group a flush's items by graph id, in order of first appearance
        (shared with the fleet engines' flushes). Payloads are
        ``(graph_id, x, ...)`` — extra elements (the multihost engine's
        pinned-local marker) ride along untouched."""
        order: List[str] = []
        groups: Dict[str, List[WorkItem]] = {}
        for item in items:
            gid = item.payload[0]
            if gid not in groups:
                groups[gid] = []
                order.append(gid)
            groups[gid].append(item)
        return order, groups

    @staticmethod
    def _slice_answers(grp: List[WorkItem], widths: List[int],
                       out: jax.Array, now: float
                       ) -> Tuple[List[Tuple[WorkItem, jax.Array]], float]:
        """Split a fused group's output back per request: feature columns
        sliced by each item's width, plus the summed enqueue->now wait.
        Shared by the local, sharded, and forwarded dispatch paths so the
        fusion/latency semantics cannot diverge between them."""
        answers: List[Tuple[WorkItem, jax.Array]] = []
        col = 0
        wait_s = 0.0
        for item, w in zip(grp, widths):
            answers.append((item, out[:, col:col + w]))
            col += w
            wait_s += now - item.t_enqueue
        return answers, wait_s

    @staticmethod
    def _is_mutation(item: WorkItem) -> bool:
        """Mutation payloads are ``(graph_id, EdgeDelta, "mutate")`` — the
        marker is in slot 2 so read payloads (and the multihost engine's
        ``"pinned-local"`` marker) are never mistaken for deltas."""
        p = item.payload
        return len(p) > 2 and p[2] == "mutate"

    def _flush(self, items: List[WorkItem]) -> None:
        """Scheduler flush callback: reads first, then mutations.

        Reads dispatch against the flush's pre-publish plan versions;
        mutations for the same graph coalesce and publish ONCE at the end
        of the flush, so a mutate never blocks the reads it arrived with.
        A failing read dispatch still lets this flush's mutations publish
        (and vice versa a bad delta fails only its own graph's mutation
        items, never the reads).
        """
        reads = [it for it in items if not self._is_mutation(it)]
        mutations = [it for it in items if self._is_mutation(it)]
        read_exc: Optional[BaseException] = None
        if reads:
            try:
                self._flush_reads(reads)
            except BaseException as e:  # noqa: BLE001 — re-raised below
                read_exc = e
        if mutations:
            order, groups = self._group_by_graph(mutations)
            for gid in order:
                grp = groups[gid]
                try:
                    self._apply_mutation(gid, grp)
                except BaseException as e:  # noqa: BLE001 — isolate per graph
                    for it in grp:
                        it.fail(e)
        if read_exc is not None:
            raise read_exc

    def _flush_reads(self, items: List[WorkItem]) -> None:
        """Group reads by plan, fuse, dispatch in chunks.

        Runs on the scheduler thread. Requests naming the same graph fuse
        along the feature axis (one slab gather serves all of them);
        distinct graphs chunk into fused dispatches of up to
        ``max_graphs_per_batch`` in order of first appearance. Every plan
        used is version-pinned for the duration of its dispatches: a
        concurrent publish retires the old version but cannot reclaim it
        until the last in-flight dispatch unpins.
        """
        order, groups = self._group_by_graph(items)
        plans = {gid: self.plan_for(gid) for gid in order}
        pinned = [p.key for p in plans.values()]
        for k in pinned:
            self.cache.pin_version(k)
        try:
            # a raising dispatch aborts the remaining chunks: their items
            # are failed by the scheduler with the same exception, while
            # items of already-dispatched chunks keep their results
            for start in range(0, len(order), self.max_graphs_per_batch):
                chunk = order[start:start + self.max_graphs_per_batch]
                self._dispatch(
                    [(gid, groups[gid], plans[gid]) for gid in chunk])
        finally:
            for k in pinned:
                self.cache.unpin_version(k)

    # --------------------------------------------------------------- mutation
    def _apply_mutation(self, gid: str, grp: List[WorkItem]) -> None:
        """Apply one flush's coalesced deltas for ``gid`` and publish once.

        Deltas apply SEQUENTIALLY in arrival order (never merged: a delete
        in delta k must see the graph as delta k-1 left it), the plan is
        repaired once against the combined touched-row set, and the new
        version publishes atomically — the old version is retired and
        reclaimed when its last pinned reader drains.
        """
        with self._mutate_lock:
            with self._bind_lock:
                g_old = self._graphs[gid]
                old_key = self._keys[gid]
                cur_ver = self._versions[gid]
            plan_old = self.plan_for(gid)
            g_new = g_old
            touched: List[np.ndarray] = []
            n_edges = 0
            gh = plan_old.graph_hash
            for it in grp:
                delta: EdgeDelta = it.payload[1]
                g_new = delta.apply(g_new)
                touched.append(delta.touched_rows())
                n_edges += delta.size
                gh = delta_chain_hash(gh, delta)
            pv = repair_plan(
                plan_old, g_old, g_new,
                np.unique(np.concatenate(touched)) if touched
                else np.empty(0, np.int64),
                churn_threshold=self.repair_churn_threshold,
                graph_hash=gh)
            # the engine owns the id's version CHAIN; the repair stamp is
            # relative to the plan object, which may have been rebuilt (at
            # version 0) after an eviction
            pv.version = cur_ver + 1
            pv.plan.version = cur_ver + 1
            self._publish_version(gid, g_new, pv.plan, old_key)
            with self._counters_lock:
                self.mutations_applied += len(grp)
                self.mutation_edges += n_edges
                if pv.repaired:
                    self.plan_repairs += 1
                else:
                    self.plan_rebuilds += 1
        result = {"graph_id": gid, "version": pv.version,
                  "repaired": pv.repaired, "reason": pv.reason,
                  "dirty_rows": pv.dirty_rows}
        for it in grp:
            it.complete(dict(result))

    def _publish_version(self, gid: str, g_new: CSRGraph,
                         plan: PartitionPlan, old_key: tuple) -> None:
        """Publish hook: cache publish first (so plan_for never misses),
        THEN atomically re-bind the id. Subclasses extend this to also
        record the version in the placement directory / notify peers."""
        self.cache.publish(plan, retire_key=old_key)
        with self._bind_lock:
            self._graphs[gid] = g_new
            self._keys[gid] = plan.key
            self._versions[gid] = plan.version

    def _dispatch(self, batch: List[Tuple[str, List[WorkItem],
                                          PartitionPlan]]) -> None:
        """One fused kernel call over up to max_graphs_per_batch graphs."""
        t0 = time.perf_counter()
        plans: List[PartitionPlan] = []
        xs: List[jax.Array] = []
        col_splits: List[List[int]] = []
        for _gid, grp, plan in batch:
            feats = [jnp.asarray(it.payload[1], dtype=jnp.float32)
                     for it in grp]
            plans.append(plan)
            x = (feats[0] if len(feats) == 1
                 else jnp.concatenate(feats, axis=1))
            if self.feature_bucket:
                w = int(x.shape[1])
                pad = bucket_blocks(w, 1) - w   # next power of two
                if pad:
                    x = jnp.pad(x, ((0, 0), (0, pad)))
            xs.append(x)
            col_splits.append([int(f.shape[1]) for f in feats])

        b_total = sum(p.num_blocks for p in plans)
        pad_to = None
        if self.block_bucket:
            pad_to = bucket_blocks(b_total, self.block_bucket)
        backend, grid_order = self._effective_launch(plans)
        outs, decision = spmm_batched(
            [p.slabs for p in plans], xs, [p.n_rows for p in plans],
            backend=backend, interpret=self.interpret,
            pad_blocks_to=pad_to, return_decision=True,
            grid_order=grid_order)
        jax.block_until_ready(outs)
        dt = time.perf_counter() - t0         # this dispatch's kernel time

        executed = decision.backend if decision is not None else "blocked"
        share = dt / len(batch)
        with self._counters_lock:
            self.backend_dispatches[executed] += 1
            self.last_decision = decision
            self.live_blocks += b_total
            self.padded_blocks += pad_to if pad_to else b_total
            for _, _, plan in batch:
                self._record_plan_time_locked(plan.key, share,
                                              len(batch) == 1)
        if logger.isEnabledFor(logging.DEBUG):
            logger.debug(
                "dispatch: graphs=%d blocks=%d->%d backend=%s (%s) %.1fms",
                len(batch), b_total, pad_to or b_total, executed,
                decision.reason if decision else "jnp twin", dt * 1e3)

        # update every counter BEFORE resolving any future: a synchronous
        # caller unblocks the moment its future resolves and may read
        # stats() immediately
        now = time.perf_counter()
        answers: List[Tuple[WorkItem, jax.Array]] = []
        n_req = n_rows = n_vals = 0
        wait_s = 0.0
        for (_gid, grp, plan), out, widths in zip(batch, outs, col_splits):
            out = out[plan.inv_perm]          # back to original row order
            sliced, wait = self._slice_answers(grp, widths, out, now)
            answers.extend(sliced)
            n_req += len(grp)
            n_rows += plan.n_rows * len(grp)
            n_vals += plan.n_rows * sum(widths)
            wait_s += wait
        # only the increments sit under the lock (concurrent fleet device
        # launches must not serialize their un-permute/slice work on it)
        with self._counters_lock:
            self.requests_served += n_req
            self.rows_served += n_rows
            self.values_served += n_vals
            self.total_request_latency_s += wait_s
            self.batches_dispatched += 1
            self.graphs_dispatched += len(batch)
            self.total_serve_s += dt
        for item, result in answers:
            item.complete(result)
        # autotuning LAST: every live answer above already resolved, so
        # shadow work can never sit between a request and its result
        if self.tuner is not None:
            self._tuner_tick(batch, xs, dt)

    # ------------------------------------------------------------ autotuning
    def _effective_launch(self, plans: List[PartitionPlan]
                          ) -> Tuple[str, str]:
        """Backend/grid_order for one fused dispatch: a plan's tuned hints
        apply when every plan in the batch agrees on the effective pair
        (trivially true for the single-graph dispatches that dominate hot
        traffic); a mixed batch falls back to the engine defaults."""
        pairs = {(((p.tuned or {}).get("backend")) or self.backend,
                  ((p.tuned or {}).get("grid_order")) or "block_major")
                 for p in plans}
        if len(pairs) == 1:
            return pairs.pop()
        return self.backend, "block_major"

    def _record_plan_time_locked(self, key: tuple, seconds: float,
                                 exact: bool) -> None:
        ring = self._plan_times.get(key)
        if ring is None:
            ring = self._plan_times[key] = deque(maxlen=PLAN_TIMING_RING)
            while len(self._plan_times) > PLAN_TIMING_KEYS:
                self._plan_times.popitem(last=False)
        else:
            self._plan_times.move_to_end(key)
        ring.append((seconds, exact))

    def _tuner_tick(self, batch, xs, dt: float) -> None:
        """Per-dispatch tuner hook (runs AFTER the live futures resolved).

        Feeds the rate tracker, asks the tuner whether any graph in this
        batch is due a shadow measurement, and hands at most one shadow to
        the single worker thread. Multihost engines skip shadowing —
        promotion would re-key the plan under the directory's feet; only
        single-host engines tune (the multihost follow-on needs a version
        broadcast like mutate()'s).
        """
        for gid, grp, _ in batch:
            self.tuner.observe(gid, len(grp))
        if getattr(self, "directory", None) is not None:
            return      # multihost: directory-owned keys don't tune yet
        for (gid, _grp, plan), x in zip(batch, xs):
            cand = self.tuner.next_shadow(gid, plan.config)
            if cand is None:
                continue
            self._submit_shadow(gid, plan, cand, x)

    def _submit_shadow(self, gid: str, plan_i: PartitionPlan,
                       cand: TuningCandidate, x: jax.Array) -> None:
        """Hand one shadow measurement to the worker; skip if it's busy
        (shadows are opportunistic — never queued, never blocking)."""
        with self._shadow_lock:
            if self._shadow_inflight:
                busy = True
            else:
                busy = False
                self._shadow_inflight = True
                if self._shadow_pool is None:
                    self._shadow_pool = ThreadPoolExecutor(
                        max_workers=1, thread_name_prefix="plan-shadow")
                pool = self._shadow_pool
        if busy:
            with self._counters_lock:
                self.shadow_skipped += 1
            return
        pool.submit(self._run_shadow, gid, plan_i, cand, x)

    def _run_shadow(self, gid: str, plan_i: PartitionPlan,
                    cand: TuningCandidate, x: jax.Array) -> None:
        """Worker-thread body: build the candidate plan (single-flight via
        the cache) and run a PAIRED A/B measurement — the incumbent and
        candidate plans dispatch the SAME features back-to-back in this
        thread (1 untimed candidate warmup to absorb compilation, then
        timed runs in ABBA order with the per-side min scored). Pairing is
        what makes the comparison robust: both sides see the same
        background load, so scheduler/GIL contention cancels instead of
        poisoning the candidate's numbers. A promotion signal publishes
        the candidate through the version chain."""
        t_start = time.perf_counter()
        old_key = plan_i.key
        try:
            with self._bind_lock:
                stale = self._keys.get(gid) != old_key
                g = self._graphs.get(gid)
            if stale or g is None:
                return              # graph mutated/replaced since the tick
            key = (old_key[0], cand.config)
            plan_c = self.cache.get_by_key(
                key, lambda: build_partition_plan(
                    g, cand.config, graph_hash=old_key[0]))
            hints_i = plan_i.tuned or {}
            launches = {
                "inc": (plan_i, hints_i.get("backend") or self.backend,
                        hints_i.get("grid_order") or "block_major"),
                "cand": (plan_c, cand.backend or self.backend,
                         cand.grid_order),
            }

            def _once(which: str) -> float:
                plan, backend, grid_order = launches[which]
                pad_to = (bucket_blocks(plan.num_blocks, self.block_bucket)
                          if self.block_bucket else None)
                t0 = time.perf_counter()
                jax.block_until_ready(spmm_batched(
                    [plan.slabs], [x], [plan.n_rows],
                    backend=backend, interpret=self.interpret,
                    pad_blocks_to=pad_to, grid_order=grid_order))
                return time.perf_counter() - t0

            _once("cand")           # warmup: compilation must not score
            # ABBA order de-phases background load: a live dispatch that
            # overlaps the shadow window hits early and late slots alike,
            # so neither side's min is systematically the contended one
            # (short candidate runs otherwise phase-lock into the busy
            # slots while long incumbent runs land in the idle gaps).
            samples = [(w, _once(w)) for w in ("inc", "cand", "cand", "inc")]
            incumbent_s = min(s for w, s in samples if w == "inc")
            candidate_s = min(s for w, s in samples if w == "cand")
            with self._counters_lock:
                self.shadow_dispatches += 1
            winner = self.tuner.record_shadow(gid, cand, incumbent_s,
                                              candidate_s)
            if winner is not None:
                self._promote_tuned(gid, old_key, winner, plan_c)
        except Exception:  # noqa: BLE001 — a broken candidate must not
            logger.exception("shadow measurement failed for %r (%s)",
                             gid, cand.label)        # take down the worker
            with self._counters_lock:
                self.shadow_failures += 1
            self.tuner.candidate_failed(gid, cand)
        finally:
            with self._counters_lock:
                self.shadow_time_s += time.perf_counter() - t_start
            with self._shadow_lock:
                self._shadow_inflight = False

    def _promote_tuned(self, gid: str, old_key: tuple,
                       cand: TuningCandidate, plan_c: PartitionPlan) -> None:
        """Publish a winning candidate as the graph's next plan version.

        Rides the same machinery as mutate(): under the mutation lock the
        binding is re-checked (a racing mutation aborts the promotion —
        the tuner forgets the graph and re-tunes if it stays hot), the
        plan gets its tuned hints + the next chain version, and
        ``_publish_version`` atomically publishes + re-binds. In-flight
        reads keep their pinned incumbent version until they drain.
        """
        with self._mutate_lock:
            with self._bind_lock:
                if self._keys.get(gid) != old_key:
                    aborted = True
                else:
                    aborted = False
                    cur_ver = self._versions[gid]
                    g = self._graphs[gid]
            if aborted:
                self.tuner.reset(gid)
                return
            plan_c.tuned = cand.tuned_hints()
            plan_c.version = cur_ver + 1
            self._publish_version(gid, g, plan_c, old_key)
            self._tuned_hints[gid] = {"config": cand.config,
                                      "tuned": dict(plan_c.tuned)}
            with self._counters_lock:
                self.tuned_promotions += 1
        self.tuner.confirm_promoted(gid)
        logger.info("promoted tuned config for %r: %s (version %d)",
                    gid, cand.label, plan_c.version)

    def plan_timings(self) -> Dict[str, Dict[str, float]]:
        """Per-plan dispatch timing summary from the bounded ring buffers.

        Keyed ``<graph_hash[:12]>:<config_tag[:8]>`` (hash alone is
        ambiguous once the tuner publishes a re-configured plan of the
        same content). ``exact_n`` counts single-graph samples — fused
        multi-graph dispatches contribute their per-plan share only.
        """
        with self._counters_lock:
            snap = {k: list(ring) for k, ring in self._plan_times.items()}
        out: Dict[str, Dict[str, float]] = {}
        for key, samples in snap.items():
            times = [s for s, _ in samples]
            tag = f"{key[0][:12]}:{_config_tag(key[1])[:8]}"
            out[tag] = {
                "n": len(times),
                "exact_n": sum(1 for _, e in samples if e),
                "last_s": times[-1],
                "mean_s": float(np.mean(times)),
                "p50_s": float(np.median(times)),
            }
        return out

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict[str, float]:
        s = {f"cache_{k}": v for k, v in self.cache.stats().items()}
        s.update({f"sched_{k}": v
                  for k, v in self.scheduler.stats().items()})
        if self.tuner is not None:
            s.update({f"tuner_{k}": v
                      for k, v in self.tuner.stats().items()})
        s["plan_timings"] = self.plan_timings()
        # engine counters are one atomic snapshot (same guarantee as
        # PlanCache.stats()); cache/scheduler snapshots above are each
        # internally consistent but taken a moment earlier
        with self._counters_lock:
            return self._stats_locked(s)

    def _stats_locked(self, s: Dict[str, float]) -> Dict[str, float]:
        s.update(
            registered_graphs=len(self._graphs),
            requests_served=self.requests_served,
            batches_dispatched=self.batches_dispatched,
            rows_served=self.rows_served,
            values_served=self.values_served,
            total_serve_s=self.total_serve_s,
            requests_per_batch=(self.requests_served / self.batches_dispatched
                                if self.batches_dispatched else 0.0),
            # cross-caller coalescing: >1 means fused multi-graph dispatches
            graphs_per_dispatch=(self.graphs_dispatched
                                 / self.batches_dispatched
                                 if self.batches_dispatched else 0.0),
            rows_per_s=(self.rows_served / self.total_serve_s
                        if self.total_serve_s else 0.0),
            # routing: which kernel regime each fused dispatch executed on
            routed_resident=self.backend_dispatches["resident"],
            routed_windowed=self.backend_dispatches["windowed"],
            routed_hbm=self.backend_dispatches["hbm"],
            routed_blocked=self.backend_dispatches["blocked"],
            # block bucketing waste: padded/live == 1.0 means no dead steps
            live_blocks=self.live_blocks,
            padded_blocks=self.padded_blocks,
            block_pad_ratio=(self.padded_blocks / self.live_blocks
                             if self.live_blocks else 0.0),
            # latency: per-dispatch kernel time vs per-request wait
            avg_dispatch_s=(self.total_serve_s / self.batches_dispatched
                            if self.batches_dispatched else 0.0),
            avg_request_latency_s=(
                self.total_request_latency_s / self.requests_served
                if self.requests_served else 0.0),
            # versioned plan lifecycle: streaming mutations
            mutations_applied=self.mutations_applied,
            mutation_edges=self.mutation_edges,
            plan_repairs=self.plan_repairs,
            plan_rebuilds=self.plan_rebuilds,
            # online autotuning: shadow measurements + promotions
            shadow_dispatches=self.shadow_dispatches,
            shadow_skipped=self.shadow_skipped,
            shadow_failures=self.shadow_failures,
            shadow_time_s=self.shadow_time_s,
            tuned_promotions=self.tuned_promotions,
            tuned_graphs=len(self._tuned_hints),
        )
        return s

