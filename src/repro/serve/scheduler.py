"""Continuous-batching core shared by the token and graph serving engines.

Serving architecture (both engines)::

    callers --- submit(payload) ----> [ admission queue (bounded) ]
                                             |
                     flush trigger: size >= max_batch OR oldest item
                     older than max_wait_ms OR drain on stop()
                                             |
                                     [ flush callback ]      (engine-owned)
                    graph engine: group by plan -> fuse feature axis
                                  -> route by VMEM -> fused kernel dispatch
                    token engine: admit into decode slots -> step loop,
                                  finished slots refilled via take_ready()
                                             |
                            item.complete(result) resolves the future

The point of the shared core: *cross-caller* batching. A blocking
``serve(requests)`` API can only fuse work the caller already collected;
with an admission queue, requests from N concurrent callers land in one
flush and share a single fused dispatch — the partition-plan amortization
of the paper (degree sort + block partition built once, reused by every
request on that graph) pays off across the whole process, not per call
site. AWB-GCN's runtime-rebalancing argument is the hardware-side version
of the same point: balance whatever work is *in flight*, not per call.

Components:

* :class:`WorkItem` — one admitted request: payload + ``Future`` + enqueue
  timestamp. The flush callback answers items with ``complete(result)`` /
  ``fail(exc)``; the scheduler records enqueue->answer latency at that
  moment. Items a flush leaves unanswered are failed by the scheduler so
  no caller ever blocks forever.
* :class:`BatchScheduler` — the background flush thread. ``submit`` /
  ``submit_many`` enqueue (with backpressure: block, or raise
  :class:`QueueFullError` with ``block=False``); ``take_ready`` lets a
  running flush pull newly-arrived work mid-flight (the token engine's
  slot reuse); ``stats()`` reports queue depth, flush-reason counts and
  latency percentiles — one stats vocabulary for both engines.

Tuning knobs:

* ``max_batch`` — flush as soon as this many items are queued. Bound it by
  what one fused dispatch can absorb (the graph engine separately chunks a
  flush into dispatches of ``max_graphs_per_batch`` distinct graphs).
* ``max_wait_ms`` — deadline flush: the oldest queued item never waits
  longer than this for co-batchable traffic. Raise it to trade tail
  latency for larger fused batches; lower it toward 0 for latency-first
  serving (each flush then carries whatever arrived during the previous
  dispatch — still cross-caller batching under load).
* ``max_queue`` — admission bound. When the queue is full, ``submit``
  blocks (backpressure propagates to callers) or raises.

SLO classes (``classes=[ClassSpec(...)]``): every admitted item carries a
request class (and optionally a tenant tag). Classes add three behaviors on
top of the base FIFO scheduler — which is exactly what a single default
class degenerates to:

* **weighted-fair admission** — each class below the top priority tier gets
  an admission quota proportional to its weight, so a batch-job flood can
  fill at most its share of the queue and an interactive submitter always
  finds room (the top tier is bounded only by ``max_queue``).
* **priority + weighted-fair batch formation** — a flush batch drains the
  highest-priority non-empty tier first; classes sharing a tier interleave
  in proportion to their weights (deficit round-robin), FIFO within each
  class. A deep batch backlog therefore cannot starve interactive items
  that arrived later.
* **early-flush-for-deadline** — a class with ``deadline_ms`` flushes after
  ``min(max_wait_ms, deadline_ms/4)`` instead of the scheduler-wide wait,
  so an SLO-bound request never burns its latency budget waiting for
  co-batchable traffic. Misses are counted per class
  (``class_deadline_missed``) and per-class latency percentiles are
  reported next to the global ones.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

__all__ = ["QueueFullError", "ClassSpec", "WorkItem", "BatchScheduler",
           "percentile"]


class QueueFullError(RuntimeError):
    """Admission rejected: the queue is at ``max_queue`` (backpressure)."""


@dataclasses.dataclass(frozen=True)
class ClassSpec:
    """One request class (SLO tier) of a :class:`BatchScheduler`.

    ``priority`` orders tiers (higher drains first); ``weight`` sets both
    the admission quota and the fair share among classes of the SAME
    priority; ``deadline_ms`` is the class's enqueue->answer SLO target —
    it tightens the co-batching wait (early flush) and drives the
    ``class_deadline_missed`` counter. ``max_wait_ms`` overrides the
    derived co-batching wait outright.
    """

    name: str
    priority: int = 0
    weight: float = 1.0
    deadline_ms: Optional[float] = None
    max_wait_ms: Optional[float] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("class name must be non-empty")
        if self.weight <= 0:
            raise ValueError(f"class {self.name}: weight must be > 0")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(f"class {self.name}: deadline_ms must be > 0")
        if self.max_wait_ms is not None and self.max_wait_ms < 0:
            raise ValueError(f"class {self.name}: max_wait_ms must be >= 0")

    def effective_wait_ms(self, scheduler_wait_ms: float) -> float:
        """Co-batching wait for this class: an explicit override wins;
        otherwise a deadline-bearing class flushes after at most a quarter
        of its SLO budget (leaving the rest for dispatch + compute)."""
        if self.max_wait_ms is not None:
            return self.max_wait_ms
        if self.deadline_ms is not None:
            return min(scheduler_wait_ms, self.deadline_ms / 4.0)
        return scheduler_wait_ms


DEFAULT_CLASS = ClassSpec("default")


def percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted sequence (0 <= q <= 1).

    Nearest-rank index is ``ceil(q * n) - 1`` (clamped): the q-quantile is
    the smallest value with at least ``q * n`` values at or below it, so
    ``percentile([1, 2, 3, 4], 0.5) == 2.0`` (not 3.0 — the old ``int(q*n)``
    index sat one rank high for every q that is not an exact rank boundary).
    ``q=0`` returns the minimum, ``q=1`` the maximum, a singleton its only
    element.
    """
    if not sorted_vals:
        return 0.0
    n = len(sorted_vals)
    idx = min(n - 1, max(0, math.ceil(q * n) - 1))
    return float(sorted_vals[idx])


class WorkItem:
    """One admitted request: payload, future, and latency bookkeeping.

    A caller may ``.cancel()`` the returned future at any moment, including
    while the flush thread is mid-``complete``. Both answer paths therefore
    *claim* the future atomically first (``set_running_or_notify_cancel``,
    which holds the Future's own lock): whoever wins the race settles the
    item exactly once, the loser is a silent no-op, and a lost race against
    a cancel is recorded in the scheduler's ``cancelled`` counter — never an
    ``InvalidStateError`` that would poison the rest of the flush.
    """

    __slots__ = ("payload", "future", "t_enqueue", "t_done", "_sched",
                 "_settled", "klass", "tenant", "flush_at", "deadline_at")

    def __init__(self, payload: Any, sched: "BatchScheduler",
                 klass: str = "default", tenant: Optional[str] = None):
        self.payload = payload
        self.future: Future = Future()
        self.t_enqueue = time.perf_counter()
        self.t_done: Optional[float] = None
        self._sched = sched
        self._settled = False   # some claim attempt already concluded this
        #                         item (fast path only; the Future's own
        #                         lock remains the arbiter)
        self.klass = klass
        self.tenant = tenant
        spec = sched.classes.get(klass, DEFAULT_CLASS)
        self.flush_at = (self.t_enqueue
                         + spec.effective_wait_ms(sched.max_wait_ms) / 1e3)
        self.deadline_at = (None if spec.deadline_ms is None
                            else self.t_enqueue + spec.deadline_ms / 1e3)

    @property
    def deadline_missed(self) -> bool:
        """True once the item resolved later than its class SLO deadline."""
        return (self.deadline_at is not None and self.t_done is not None
                and self.t_done > self.deadline_at)

    @property
    def done(self) -> bool:
        return self.future.done()

    @property
    def latency_s(self) -> Optional[float]:
        """Enqueue -> answer wall time (queue wait included); None until done."""
        return None if self.t_done is None else self.t_done - self.t_enqueue

    def _claim(self) -> bool:
        """Atomically win (or lose) the settle race against ``Future.cancel``.

        Returns True when this thread now owns the only right to settle the
        future (``cancel()`` can no longer succeed). Returns False when the
        item is already settled/claimed, or when the caller's cancel won —
        the latter is counted exactly once (the CANCELLED -> NOTIFIED
        transition happens on one thread only).
        """
        # fast path: an already-concluded item (answered, or a cancel we
        # already recorded) — skips the stdlib's CRITICAL "unexpected
        # state" log that set_running_or_notify_cancel emits on settled
        # futures; pure optimization, the Future's lock decides below
        if self._settled or (self.future.done()
                             and not self.future.cancelled()):
            return False
        try:
            claimed = self.future.set_running_or_notify_cancel()
        except RuntimeError:
            self._settled = True
            return False            # already answered (double complete/fail)
        if not claimed:             # caller's cancel() won the race
            self._settled = True
            self._sched._record_cancelled(self)
            return False
        self._settled = True
        return True

    def complete(self, result: Any) -> None:
        """Resolve the item's future and record its latency (idempotent;
        swallows a lost race against a caller-side ``cancel()``)."""
        if not self._claim():
            return
        self.t_done = time.perf_counter()
        self._sched._record_done(self, failed=False)
        self.future.set_result(result)

    def fail(self, exc: BaseException) -> None:
        if not self._claim():
            return
        self.t_done = time.perf_counter()
        self._sched._record_done(self, failed=True)
        self.future.set_exception(exc)


class BatchScheduler:
    """Background-thread continuous batcher with size/deadline flush triggers.

    ``flush_fn(items)`` runs on the scheduler thread with a batch of up to
    ``max_batch`` :class:`WorkItem`; it must answer every item (via
    ``complete``/``fail``) — stragglers are failed by the scheduler, and a
    raising flush fails every unanswered item of that flush with the raised
    exception. ``flush_fn`` may call :meth:`take_ready` to pull extra
    queued items into the running flush (slot reuse); those pulled items
    join the flush's failure scope.

    The worker thread is a daemon and starts lazily on first submit, so
    constructing an engine never spawns a thread it won't use.
    """

    # latency ring size: enough for stable p99 without unbounded growth
    _LAT_WINDOW = 4096

    def __init__(
        self,
        flush_fn: Callable[[List[WorkItem]], None],
        *,
        max_batch: int = 8,
        max_wait_ms: float = 5.0,
        max_queue: int = 256,
        name: str = "batch-scheduler",
        classes: Optional[Sequence[ClassSpec]] = None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        self.flush_fn = flush_fn
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.name = name

        # request classes: always at least "default" (pure FIFO semantics
        # when it is the only one). Listed specs may override "default".
        self.classes: Dict[str, ClassSpec] = {"default": DEFAULT_CLASS}
        for spec in classes or ():
            self.classes[spec.name] = spec
        self._quota = self._admission_quotas()

        self._cond = threading.Condition()
        self._queues: Dict[str, "deque[WorkItem]"] = {
            name: deque() for name in self.classes}
        # deficit-round-robin credits for weighted interleave inside one
        # priority tier (guarded by _cond; reset when a class drains)
        self._credits: Dict[str, float] = {name: 0.0 for name in self.classes}
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._closing = False     # stop() in progress: admissions raise
        self._current_extra: List[WorkItem] = []  # take_ready pulls, per flush

        # counters (guarded by _cond; all monotone)
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0           # caller-side Future.cancel() wins;
        #                              completed + failed + cancelled
        #                              == settled submissions
        self.rejected = 0            # QueueFullError admissions (per ITEM)
        self.flushes = 0
        self.items_flushed = 0
        self.mid_flush_admissions = 0  # items pulled by take_ready
        self.flush_reasons: Dict[str, int] = {
            "size": 0, "deadline": 0, "drain": 0, "slo": 0}
        self.peak_queue_depth = 0
        self._latencies: "deque[float]" = deque(maxlen=self._LAT_WINDOW)
        self._total_latency_s = 0.0
        # per-class accounting (same lock): latency windows + SLO misses
        self._class_latencies: Dict[str, "deque[float]"] = {
            name: deque(maxlen=self._LAT_WINDOW) for name in self.classes}
        self.class_completed: Dict[str, int] = {n: 0 for n in self.classes}
        self.class_deadline_missed: Dict[str, int] = {
            n: 0 for n in self.classes}

    def _admission_quotas(self) -> Dict[str, int]:
        """Per-class admission bound. Top-priority classes may use the whole
        queue; every lower tier is capped at its weighted share, so a
        lower-priority flood can never fill the queue against the top tier
        (weighted-fair admission)."""
        top = max(spec.priority for spec in self.classes.values())
        total_w = sum(spec.weight for spec in self.classes.values())
        quotas = {}
        for name, spec in self.classes.items():
            if spec.priority >= top:
                quotas[name] = self.max_queue
            else:
                quotas[name] = max(1, int(self.max_queue
                                          * spec.weight / total_w))
        return quotas

    # ---------------------------------------------------------- queue helpers
    def _qsize_locked(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _class_of_locked(self, klass: str) -> "deque[WorkItem]":
        q = self._queues.get(klass)
        if q is None:
            raise KeyError(
                f"{self.name}: unknown request class {klass!r} "
                f"(known: {sorted(self.classes)})")
        return q

    def _admission_full_locked(self, klass: str, need: int = 1) -> bool:
        if self._qsize_locked() + need > self.max_queue:
            return True
        return len(self._queues[klass]) + need > self._quota[klass]

    def _pop_next_locked(self) -> Optional[WorkItem]:
        """Pop the next item under priority + weighted-fair (DRR) order:
        highest non-empty priority tier first; classes sharing that tier
        interleave proportionally to their weights; FIFO within a class."""
        active = [n for n, q in self._queues.items() if q]
        if not active:
            return None
        if len(active) == 1:
            return self._queues[active[0]].popleft()
        top = max(self.classes[n].priority for n in active)
        tier = [n for n in active if self.classes[n].priority == top]
        if len(tier) == 1:
            return self._queues[tier[0]].popleft()
        for n in tier:
            self._credits[n] += self.classes[n].weight
        pick = max(tier, key=lambda n: self._credits[n])
        self._credits[pick] -= sum(self.classes[n].weight for n in tier)
        return self._queues[pick].popleft()

    def _take_batch_locked(self, k: int) -> List[WorkItem]:
        items: List[WorkItem] = []
        while len(items) < k:
            item = self._pop_next_locked()
            if item is None:
                break
            items.append(item)
        # drained classes reset their credit so an idle class cannot bank
        # an unbounded claim on future flushes
        for n, q in self._queues.items():
            if not q:
                self._credits[n] = 0.0
        return items

    def _next_flush_at_locked(self) -> Optional[float]:
        """Earliest flush deadline over queued items. FIFO within a class
        and a constant per-class wait make each queue head the earliest of
        its class, so the scan is O(classes)."""
        heads = [q[0].flush_at for q in self._queues.values() if q]
        return min(heads) if heads else None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        with self._cond:
            self._ensure_started_locked()

    def _ensure_started_locked(self) -> None:
        """Guarantee a live worker exists for subsequently-enqueued items.

        Called (under the lock) immediately before EVERY enqueue — including
        after a backpressure wait, during which the scheduler may have been
        stopped — so no item can enter a queue nothing will drain. While a
        ``stop()`` is in progress admissions raise instead of resurrecting
        the worker out from under the join.
        """
        if self._closing:
            raise RuntimeError(f"{self.name}: scheduler is stopping")
        self._running = True
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._worker, name=self.name, daemon=True)
            self._thread.start()

    @property
    def running(self) -> bool:
        return self._running

    def stop(self, timeout: Optional[float] = None) -> None:
        """Stop the worker, draining (flushing) everything still queued.

        Concurrent ``submit`` calls racing a stop get ``RuntimeError``;
        after stop returns, a new submit restarts the scheduler cleanly.
        """
        with self._cond:
            self._running = False
            self._closing = True
            self._cond.notify_all()
            thread = self._thread
        try:
            if thread is not None:
                thread.join(timeout)
        finally:
            with self._cond:
                self._closing = False

    def __enter__(self) -> "BatchScheduler":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ admission
    def submit(self, payload: Any, *, block: bool = True,
               timeout: Optional[float] = None, klass: str = "default",
               tenant: Optional[str] = None) -> WorkItem:
        """Admit one payload; returns its :class:`WorkItem` (with ``.future``).

        A full queue blocks (backpressure) until a flush drains it, or
        raises :class:`QueueFullError` when ``block=False`` or ``timeout``
        expires. ``klass`` must name a configured :class:`ClassSpec`; a
        class at its weighted admission quota backpressures exactly like a
        full queue (other classes are unaffected).
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            self._class_of_locked(klass)
            self._ensure_started_locked()
            while self._admission_full_locked(klass):
                if not block:
                    self.rejected += 1
                    raise QueueFullError(
                        f"{self.name}: queue full for class {klass!r} "
                        f"({len(self._queues[klass])}/{self._quota[klass]}, "
                        f"total {self._qsize_locked()}/{self.max_queue})")
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    self.rejected += 1
                    raise QueueFullError(
                        f"{self.name}: queue full for class {klass!r} "
                        f"after {timeout}s")
                self._cond.wait(remaining)
            # the wait may have outlived a stop(): re-ensure a live worker
            self._ensure_started_locked()
            return self._enqueue_locked(payload, klass, tenant)

    def submit_many(self, payloads: Sequence[Any], *, block: bool = True,
                    timeout: Optional[float] = None,
                    klass: Union[str, Sequence[str]] = "default",
                    tenant: Union[None, str, Sequence[Optional[str]]] = None,
                    ) -> List[WorkItem]:
        """Atomically admit several payloads: they enter the queue as one
        contiguous run, so a single flush sees them together (this is what
        keeps the synchronous ``serve(requests)`` wrapper's batching
        semantics). Blocks until the whole run fits — or, when the run is
        larger than ``max_queue``, until the queue is empty (the run is
        then admitted as an oversized burst rather than deadlocking).

        A rejection (``block=False`` or an expired ``timeout``, matching
        :meth:`submit`) rejects the whole run and counts EVERY item of it in
        ``rejected`` — the counter tracks items, not calls, so it stays
        comparable with ``submitted`` no matter how admissions were batched.
        """
        payloads = list(payloads)
        if not payloads:
            return []
        klasses = ([klass] * len(payloads) if isinstance(klass, str)
                   else list(klass))
        if len(klasses) != len(payloads):
            raise ValueError(
                f"{len(klasses)} classes for {len(payloads)} payloads")
        tenants = ([tenant] * len(payloads)
                   if tenant is None or isinstance(tenant, str)
                   else list(tenant))
        if len(tenants) != len(payloads):
            raise ValueError(
                f"{len(tenants)} tenants for {len(payloads)} payloads")
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            for k in set(klasses):
                self._class_of_locked(k)
            self._ensure_started_locked()
            need = len(payloads)
            while (self._qsize_locked() + need > self.max_queue
                   and self._qsize_locked() > 0):
                if not block:
                    self.rejected += need
                    raise QueueFullError(
                        f"{self.name}: no room for {need} items "
                        f"(queue {self._qsize_locked()}/{self.max_queue})")
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    self.rejected += need
                    raise QueueFullError(
                        f"{self.name}: no room for {need} items "
                        f"(queue {self._qsize_locked()}/{self.max_queue}) "
                        f"after {timeout}s")
                self._cond.wait(remaining)
            # the wait may have outlived a stop(): re-ensure a live worker
            self._ensure_started_locked()
            return [self._enqueue_locked(p, k, t)
                    for p, k, t in zip(payloads, klasses, tenants)]

    def _enqueue_locked(self, payload: Any, klass: str = "default",
                        tenant: Optional[str] = None) -> WorkItem:
        item = WorkItem(payload, self, klass, tenant)
        self._queues[klass].append(item)
        self.submitted += 1
        self.peak_queue_depth = max(self.peak_queue_depth,
                                    self._qsize_locked())
        self._cond.notify_all()
        return item

    def adopt(self, payload: Any, klass: str = "default",
              tenant: Optional[str] = None) -> WorkItem:
        """Create an item counted as submitted but NOT enqueued — the
        caller dispatches it directly on its own thread.

        This exists for work that must not wait behind the single flush
        worker: the multihost peer handler executes forwarded groups
        inline, because host A's worker blocks on B's answer while B's
        worker may be blocked on A's — two single-worker schedulers
        queueing each other's forwards through the data plane is a
        deadlock. Adopted items feed the same counters through
        ``complete``/``fail``/cancel, so ``completed + failed + cancelled
        == submitted`` still holds.
        """
        with self._cond:
            item = WorkItem(payload, self, klass, tenant)
            self.submitted += 1
            return item

    def take_ready(self, k: int) -> List[WorkItem]:
        """Non-blocking pop of up to ``k`` queued items into the RUNNING
        flush (call only from ``flush_fn``). Enables slot reuse: a decode
        loop refills freed slots with work that arrived after the flush
        started, instead of waiting for the next flush boundary. Items come
        out in the same priority/weighted-fair order a flush batch uses.
        """
        if k <= 0:
            return []
        with self._cond:
            items = self._take_batch_locked(k)
            if items:
                self.mid_flush_admissions += len(items)
                self._current_extra.extend(items)
                self._cond.notify_all()   # wake backpressured submitters
            return items

    # ------------------------------------------------------------ worker
    def _worker(self) -> None:
        while True:
            with self._cond:
                while self._running and not self._qsize_locked():
                    self._cond.wait()
                if not self._qsize_locked():
                    if not self._running:
                        # clear the handle under the SAME lock hold as the
                        # exit decision, so _ensure_started_locked can never
                        # see a live-but-doomed worker and skip the restart
                        self._thread = None
                        return
                    continue
                now = time.perf_counter()
                next_flush = self._next_flush_at_locked()
                if not self._running:
                    reason = "drain"
                elif self._qsize_locked() >= self.max_batch:
                    reason = "size"
                elif now >= next_flush:
                    # "slo": a deadline-bearing class tightened the wait
                    # below the scheduler-wide max_wait_ms (early flush)
                    plain = min(q[0].t_enqueue
                                for q in self._queues.values()
                                if q) + self.max_wait_ms / 1e3
                    reason = "slo" if next_flush < plain - 1e-9 else "deadline"
                else:
                    self._cond.wait(next_flush - now)
                    continue
                batch = self._take_batch_locked(self.max_batch)
                self.flushes += 1
                self.flush_reasons[reason] += 1
                self.items_flushed += len(batch)
                self._current_extra = []
                self._cond.notify_all()   # queue drained: wake submitters
            try:
                self.flush_fn(batch)
                exc: Optional[BaseException] = None
            except BaseException as e:     # noqa: BLE001 — must not kill the
                exc = e                    # worker; every waiter gets the exc
            fallback = exc or RuntimeError(
                f"{self.name}: flush returned without answering item")
            # unconditional fail (no done-check): fail() itself settles the
            # check-then-settle race atomically, so a cancel landing between
            # a guard and the settle can no longer raise InvalidStateError
            # here and kill the worker thread; already-answered items are
            # no-ops, cancelled-but-unanswered items are counted as such
            for item in batch + self._current_extra:
                item.fail(fallback)

    # ------------------------------------------------------------ stats
    def _record_done(self, item: WorkItem, *, failed: bool) -> None:
        with self._cond:
            if failed:
                self.failed += 1
            else:
                self.completed += 1
                self.class_completed[item.klass] = \
                    self.class_completed.get(item.klass, 0) + 1
            if item.latency_s is not None:
                self._latencies.append(item.latency_s)
                self._total_latency_s += item.latency_s
                self._class_latencies.setdefault(
                    item.klass, deque(maxlen=self._LAT_WINDOW)
                ).append(item.latency_s)
            if item.deadline_missed:
                self.class_deadline_missed[item.klass] = \
                    self.class_deadline_missed.get(item.klass, 0) + 1

    def _record_cancelled(self, item: WorkItem) -> None:
        """A caller's ``Future.cancel()`` beat the flush to this item.

        Called exactly once per cancelled item — from the one thread that
        observed the CANCELLED -> CANCELLED_AND_NOTIFIED transition — so
        ``completed + failed + cancelled`` accounts for every item a flush
        attempted to answer, without double counting.
        """
        with self._cond:
            self.cancelled += 1

    def queue_depth(self) -> int:
        with self._cond:
            return self._qsize_locked()

    def stats(self) -> Dict[str, float]:
        """Snapshot of the scheduling counters (shared engine vocabulary)."""
        with self._cond:
            lats = sorted(self._latencies)
            answered = self.completed + self.failed
            per_class_p50 = {}
            per_class_p99 = {}
            for name, window in self._class_latencies.items():
                cl = sorted(window)
                per_class_p50[name] = percentile(cl, 0.50)
                per_class_p99[name] = percentile(cl, 0.99)
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "rejected": self.rejected,
                "flushes": self.flushes,
                "items_flushed": self.items_flushed,
                "items_per_flush": (self.items_flushed / self.flushes
                                    if self.flushes else 0.0),
                "mid_flush_admissions": self.mid_flush_admissions,
                "flush_size": self.flush_reasons["size"],
                "flush_deadline": self.flush_reasons["deadline"],
                "flush_drain": self.flush_reasons["drain"],
                "flush_slo": self.flush_reasons["slo"],
                "queue_depth": self._qsize_locked(),
                "peak_queue_depth": self.peak_queue_depth,
                "class_queue_depth": {n: len(q)
                                      for n, q in self._queues.items()},
                "class_completed": dict(self.class_completed),
                "class_deadline_missed": dict(self.class_deadline_missed),
                "per_class_p50": per_class_p50,
                "per_class_p99": per_class_p99,
                "avg_latency_s": (self._total_latency_s / answered
                                  if answered else 0.0),
                "p50_latency_s": percentile(lats, 0.50),
                "p90_latency_s": percentile(lats, 0.90),
                "p99_latency_s": percentile(lats, 0.99),
            }
