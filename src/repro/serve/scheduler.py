"""Continuous-batching core shared by the token and graph serving engines.

Serving architecture (both engines)::

    callers --- submit(payload) ----> [ admission queue (bounded) ]
                                             |
                     flush trigger: size >= max_batch OR oldest item
                     older than max_wait_ms OR drain on stop()
                                             |
                                     [ flush callback ]      (engine-owned)
                    graph engine: group by plan -> fuse feature axis
                                  -> route by VMEM -> fused kernel dispatch
                    token engine: admit into decode slots -> step loop,
                                  finished slots refilled via take_ready()
                                             |
                            item.complete(result) resolves the future

The point of the shared core: *cross-caller* batching. A blocking
``serve(requests)`` API can only fuse work the caller already collected;
with an admission queue, requests from N concurrent callers land in one
flush and share a single fused dispatch — the partition-plan amortization
of the paper (degree sort + block partition built once, reused by every
request on that graph) pays off across the whole process, not per call
site. AWB-GCN's runtime-rebalancing argument is the hardware-side version
of the same point: balance whatever work is *in flight*, not per call.

Components:

* :class:`WorkItem` — one admitted request: payload + ``Future`` + enqueue
  timestamp. The flush callback answers items with ``complete(result)`` /
  ``fail(exc)``; the scheduler records enqueue->answer latency at that
  moment. Items a flush leaves unanswered are failed by the scheduler so
  no caller ever blocks forever.
* :class:`BatchScheduler` — the background flush thread. ``submit`` /
  ``submit_many`` enqueue (with backpressure: block, or raise
  :class:`QueueFullError` with ``block=False``); ``take_ready`` lets a
  running flush pull newly-arrived work mid-flight (the token engine's
  slot reuse); ``stats()`` reports queue depth, flush-reason counts and
  latency percentiles — one stats vocabulary for both engines.

Tuning knobs:

* ``max_batch`` — flush as soon as this many items are queued. Bound it by
  what one fused dispatch can absorb (the graph engine separately chunks a
  flush into dispatches of ``max_graphs_per_batch`` distinct graphs).
* ``max_wait_ms`` — deadline flush: the oldest queued item never waits
  longer than this for co-batchable traffic. Raise it to trade tail
  latency for larger fused batches; lower it toward 0 for latency-first
  serving (each flush then carries whatever arrived during the previous
  dispatch — still cross-caller batching under load).
* ``max_queue`` — admission bound. When the queue is full, ``submit``
  blocks (backpressure propagates to callers) or raises.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence

__all__ = ["QueueFullError", "WorkItem", "BatchScheduler", "percentile"]


class QueueFullError(RuntimeError):
    """Admission rejected: the queue is at ``max_queue`` (backpressure)."""


def percentile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted sequence (0 <= q <= 1)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals))))
    return float(sorted_vals[idx])


class WorkItem:
    """One admitted request: payload, future, and latency bookkeeping."""

    __slots__ = ("payload", "future", "t_enqueue", "t_done", "_sched")

    def __init__(self, payload: Any, sched: "BatchScheduler"):
        self.payload = payload
        self.future: Future = Future()
        self.t_enqueue = time.perf_counter()
        self.t_done: Optional[float] = None
        self._sched = sched

    @property
    def done(self) -> bool:
        return self.future.done()

    @property
    def latency_s(self) -> Optional[float]:
        """Enqueue -> answer wall time (queue wait included); None until done."""
        return None if self.t_done is None else self.t_done - self.t_enqueue

    def complete(self, result: Any) -> None:
        """Resolve the item's future and record its latency."""
        if self.future.done():
            return
        self.t_done = time.perf_counter()
        self._sched._record_done(self, failed=False)
        self.future.set_result(result)

    def fail(self, exc: BaseException) -> None:
        if self.future.done():
            return
        self.t_done = time.perf_counter()
        self._sched._record_done(self, failed=True)
        self.future.set_exception(exc)


class BatchScheduler:
    """Background-thread continuous batcher with size/deadline flush triggers.

    ``flush_fn(items)`` runs on the scheduler thread with a batch of up to
    ``max_batch`` :class:`WorkItem`; it must answer every item (via
    ``complete``/``fail``) — stragglers are failed by the scheduler, and a
    raising flush fails every unanswered item of that flush with the raised
    exception. ``flush_fn`` may call :meth:`take_ready` to pull extra
    queued items into the running flush (slot reuse); those pulled items
    join the flush's failure scope.

    The worker thread is a daemon and starts lazily on first submit, so
    constructing an engine never spawns a thread it won't use.
    """

    # latency ring size: enough for stable p99 without unbounded growth
    _LAT_WINDOW = 4096

    def __init__(
        self,
        flush_fn: Callable[[List[WorkItem]], None],
        *,
        max_batch: int = 8,
        max_wait_ms: float = 5.0,
        max_queue: int = 256,
        name: str = "batch-scheduler",
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        self.flush_fn = flush_fn
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.name = name

        self._cond = threading.Condition()
        self._queue: "deque[WorkItem]" = deque()
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._closing = False     # stop() in progress: admissions raise
        self._current_extra: List[WorkItem] = []  # take_ready pulls, per flush

        # counters (guarded by _cond; all monotone)
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected = 0            # QueueFullError admissions
        self.flushes = 0
        self.items_flushed = 0
        self.mid_flush_admissions = 0  # items pulled by take_ready
        self.flush_reasons: Dict[str, int] = {
            "size": 0, "deadline": 0, "drain": 0}
        self.peak_queue_depth = 0
        self._latencies: "deque[float]" = deque(maxlen=self._LAT_WINDOW)
        self._total_latency_s = 0.0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        with self._cond:
            self._ensure_started_locked()

    def _ensure_started_locked(self) -> None:
        """Guarantee a live worker exists for subsequently-enqueued items.

        Called (under the lock) immediately before EVERY enqueue — including
        after a backpressure wait, during which the scheduler may have been
        stopped — so no item can enter a queue nothing will drain. While a
        ``stop()`` is in progress admissions raise instead of resurrecting
        the worker out from under the join.
        """
        if self._closing:
            raise RuntimeError(f"{self.name}: scheduler is stopping")
        self._running = True
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._worker, name=self.name, daemon=True)
            self._thread.start()

    @property
    def running(self) -> bool:
        return self._running

    def stop(self, timeout: Optional[float] = None) -> None:
        """Stop the worker, draining (flushing) everything still queued.

        Concurrent ``submit`` calls racing a stop get ``RuntimeError``;
        after stop returns, a new submit restarts the scheduler cleanly.
        """
        with self._cond:
            self._running = False
            self._closing = True
            self._cond.notify_all()
            thread = self._thread
        try:
            if thread is not None:
                thread.join(timeout)
        finally:
            with self._cond:
                self._closing = False

    def __enter__(self) -> "BatchScheduler":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ admission
    def submit(self, payload: Any, *, block: bool = True,
               timeout: Optional[float] = None) -> WorkItem:
        """Admit one payload; returns its :class:`WorkItem` (with ``.future``).

        A full queue blocks (backpressure) until a flush drains it, or
        raises :class:`QueueFullError` when ``block=False`` or ``timeout``
        expires.
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            self._ensure_started_locked()
            while len(self._queue) >= self.max_queue:
                if not block:
                    self.rejected += 1
                    raise QueueFullError(
                        f"{self.name}: queue full ({self.max_queue})")
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    self.rejected += 1
                    raise QueueFullError(
                        f"{self.name}: queue full ({self.max_queue}) "
                        f"after {timeout}s")
                self._cond.wait(remaining)
            # the wait may have outlived a stop(): re-ensure a live worker
            self._ensure_started_locked()
            return self._enqueue_locked(payload)

    def submit_many(self, payloads: Sequence[Any], *,
                    block: bool = True) -> List[WorkItem]:
        """Atomically admit several payloads: they enter the queue as one
        contiguous run, so a single flush sees them together (this is what
        keeps the synchronous ``serve(requests)`` wrapper's batching
        semantics). Blocks until the whole run fits — or, when the run is
        larger than ``max_queue``, until the queue is empty (the run is
        then admitted as an oversized burst rather than deadlocking).
        """
        payloads = list(payloads)
        if not payloads:
            return []
        with self._cond:
            self._ensure_started_locked()
            need = len(payloads)
            while (len(self._queue) + need > self.max_queue
                   and len(self._queue) > 0):
                if not block:
                    self.rejected += 1
                    raise QueueFullError(
                        f"{self.name}: no room for {need} items "
                        f"(queue {len(self._queue)}/{self.max_queue})")
                self._cond.wait()
            # the wait may have outlived a stop(): re-ensure a live worker
            self._ensure_started_locked()
            return [self._enqueue_locked(p) for p in payloads]

    def _enqueue_locked(self, payload: Any) -> WorkItem:
        item = WorkItem(payload, self)
        self._queue.append(item)
        self.submitted += 1
        self.peak_queue_depth = max(self.peak_queue_depth, len(self._queue))
        self._cond.notify_all()
        return item

    def take_ready(self, k: int) -> List[WorkItem]:
        """Non-blocking pop of up to ``k`` queued items into the RUNNING
        flush (call only from ``flush_fn``). Enables slot reuse: a decode
        loop refills freed slots with work that arrived after the flush
        started, instead of waiting for the next flush boundary.
        """
        if k <= 0:
            return []
        with self._cond:
            items = []
            while self._queue and len(items) < k:
                items.append(self._queue.popleft())
            if items:
                self.mid_flush_admissions += len(items)
                self._current_extra.extend(items)
                self._cond.notify_all()   # wake backpressured submitters
            return items

    # ------------------------------------------------------------ worker
    def _worker(self) -> None:
        while True:
            with self._cond:
                while self._running and not self._queue:
                    self._cond.wait()
                if not self._queue:
                    if not self._running:
                        # clear the handle under the SAME lock hold as the
                        # exit decision, so _ensure_started_locked can never
                        # see a live-but-doomed worker and skip the restart
                        self._thread = None
                        return
                    continue
                now = time.perf_counter()
                oldest_deadline = (self._queue[0].t_enqueue
                                   + self.max_wait_ms / 1e3)
                if not self._running:
                    reason = "drain"
                elif len(self._queue) >= self.max_batch:
                    reason = "size"
                elif now >= oldest_deadline:
                    reason = "deadline"
                else:
                    self._cond.wait(oldest_deadline - now)
                    continue
                batch = [self._queue.popleft()
                         for _ in range(min(self.max_batch,
                                            len(self._queue)))]
                self.flushes += 1
                self.flush_reasons[reason] += 1
                self.items_flushed += len(batch)
                self._current_extra = []
                self._cond.notify_all()   # queue drained: wake submitters
            try:
                self.flush_fn(batch)
                exc: Optional[BaseException] = None
            except BaseException as e:     # noqa: BLE001 — must not kill the
                exc = e                    # worker; every waiter gets the exc
            fallback = exc or RuntimeError(
                f"{self.name}: flush returned without answering item")
            for item in batch + self._current_extra:
                if not item.done:
                    item.fail(fallback)

    # ------------------------------------------------------------ stats
    def _record_done(self, item: WorkItem, *, failed: bool) -> None:
        with self._cond:
            if failed:
                self.failed += 1
            else:
                self.completed += 1
            if item.latency_s is not None:
                self._latencies.append(item.latency_s)
                self._total_latency_s += item.latency_s

    def queue_depth(self) -> int:
        with self._cond:
            return len(self._queue)

    def stats(self) -> Dict[str, float]:
        """Snapshot of the scheduling counters (shared engine vocabulary)."""
        with self._cond:
            lats = sorted(self._latencies)
            answered = self.completed + self.failed
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "rejected": self.rejected,
                "flushes": self.flushes,
                "items_flushed": self.items_flushed,
                "items_per_flush": (self.items_flushed / self.flushes
                                    if self.flushes else 0.0),
                "mid_flush_admissions": self.mid_flush_admissions,
                "flush_size": self.flush_reasons["size"],
                "flush_deadline": self.flush_reasons["deadline"],
                "flush_drain": self.flush_reasons["drain"],
                "queue_depth": len(self._queue),
                "peak_queue_depth": self.peak_queue_depth,
                "avg_latency_s": (self._total_latency_s / answered
                                  if answered else 0.0),
                "p50_latency_s": percentile(lats, 0.50),
                "p90_latency_s": percentile(lats, 0.90),
                "p99_latency_s": percentile(lats, 0.99),
            }
