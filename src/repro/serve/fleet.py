"""Multi-device fleet serving: per-device dispatch groups + sharded SpMM.

:class:`FleetGraphEngine` is the multi-device :class:`GraphServeEngine`.
Same admission path (the continuous-batching :class:`BatchScheduler`), same
request semantics (``submit(graph_id, x) -> Future`` answered in ORIGINAL
row order) — what changes is the flush:

1. requests group by graph (feature-axis fusion), exactly as before;
2. each graph group is routed by :func:`repro.kernels.router.route_fleet`:

   * ``single``  — the graph's plan lives on ONE device (consistent-hash
     placement via :class:`~repro.distributed.placement.FleetPlanCache`);
     its group joins that device's fused dispatch. Distinct devices'
     dispatches launch CONCURRENTLY from a device pool — the fleet analogue
     of the paper's block-level balancing: independent work never queues
     behind an unrelated device's kernel.
   * ``feature`` — wide-feature dispatches split column-wise over the whole
     mesh (zero-communication, the combined-warp column parallelism at
     device granularity).
   * ``block``   — one giant narrow graph round-robins its partition blocks
     across the mesh (X replicated, per-device row slabs psum'd back).

3. one flush == one *fleet round* of concurrent launches. ``stats()``
   reports per-device dispatch/request/busy-time balance and the
   block-shard live-block counts next to the inherited ``sched_*`` /
   ``cache_*`` counters.

**Hot-plan replication** (``replicate_hot=True``): a per-plan EWMA request
rate (:class:`~repro.distributed.replication.ReplicaManager`) promotes hot
plans onto the least-loaded devices and demotes cold replicas at flush
boundaries. A flush then (a) routes each single-device group to the
least-loaded REPLICA of its plan and (b) SPLITS a hot fused group's
requests across all its replicas — the one-device popularity ceiling that
zipf traffic otherwise hits (one hot graph pins one device at 100% while
the rest idle) becomes per-round parallelism. ``hedge_ms`` optionally
re-dispatches a still-pending group on a second replica after that many
milliseconds (tail-latency hedging; answers are idempotent so the first
result wins).

Validated on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see ``tests/test_fleet.py`` and the CI device matrix) — real multi-device
semantics, no hardware required. On one device everything degrades to the
single-device engine (the pool has one worker, sharding never triggers).

:class:`MultihostGraphEngine` lifts the same structure one level: a flush
first splits work by owning HOST (the distributed
:class:`~repro.distributed.directory.PlacementDirectory`), forwards
remote-owned groups to their owner over the peer data plane, and runs the
locally-owned share through the per-device path above. Validated with REAL
multi-process JAX (two CPU subprocesses, ``jax.distributed`` rendezvous)
in ``tests/test_multihost.py`` and the CI multi-process smoke job.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from functools import partial
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..core.graph import CSRGraph, gcn_normalize
from ..core.plan_cache import (
    PartitionConfig, PartitionPlan, build_partition_plan, graph_content_hash,
)
from ..core.plan_repair import EdgeDelta, delta_chain_hash, repair_plan
from ..distributed.directory import HostInfo, PlacementDirectory
from ..distributed.multihost import (
    MultihostContext, PeerClient, PeerServer, peer_ports,
)
from ..distributed.placement import FleetPlanCache
from ..distributed.replication import ReplicaManager
from ..distributed.shard_spmm import (
    commit_block_shards_global, prepare_block_shards,
    prepare_feature_shards, spmm_block_sharded, spmm_feature_sharded,
)
from ..kernels.router import FleetDecision, route_fleet
from ..kernels.spmm_batched import spmm_batched
from ..launch.mesh import graph_mesh, multihost_graph_mesh
from .graph_engine import GraphServeEngine
from .scheduler import WorkItem

__all__ = ["FleetGraphEngine", "MultihostGraphEngine"]


class FleetGraphEngine(GraphServeEngine):
    """Continuous-batching graph server over a device mesh.

    ``n_devices=None`` takes every visible device. ``capacity_per_device``
    bounds each device's plan-cache shard, so fleet plan capacity (and HBM
    residency) scales with device count — the ROADMAP's "serve more graphs
    than one host's HBM holds" axis.
    """

    def __init__(
        self,
        *,
        n_devices: Optional[int] = None,
        devices: Optional[Sequence] = None,
        capacity_per_device: int = 32,
        load_spread: int = 4,
        save_dir: Optional[str] = None,
        min_blocks_per_device: int = 4,
        config: Optional[PartitionConfig] = None,
        replicate_hot: bool = True,
        rate_per_replica: float = 200.0,
        max_replicas: int = 4,
        replica_halflife_s: float = 2.0,
        replication_interval_s: float = 0.05,
        split_min_requests: int = 2,
        hedge_ms: Optional[float] = None,
        **engine_kw,
    ):
        if devices is not None:
            # explicit device set (the multihost engine passes its process's
            # LOCAL devices — jax.devices() is the whole fleet there)
            if n_devices is not None:
                raise ValueError("pass n_devices or devices, not both")
            self.mesh = Mesh(np.asarray(list(devices)), ("dev",))
        else:
            self.mesh = graph_mesh(n_devices)
        self.devices = list(self.mesh.devices.flat)
        self.n_devices = len(self.devices)
        cache = engine_kw.pop("cache", None)
        if cache is None:
            cache = FleetPlanCache(self.devices,
                                   capacity_per_device=capacity_per_device,
                                   load_spread=load_spread,
                                   save_dir=save_dir)
        elif not hasattr(cache, "device_index_of"):
            # fail at construction, not with an AttributeError on the
            # scheduler thread at first flush
            raise TypeError(
                f"FleetGraphEngine needs a device-partitioned cache "
                f"(FleetPlanCache), got {type(cache).__name__}")
        super().__init__(config=config, cache=cache, **engine_kw)
        self.min_blocks_per_device = min_blocks_per_device
        self._pool = ThreadPoolExecutor(max_workers=self.n_devices,
                                        thread_name_prefix="fleet-dev")
        # memoized sharded-dispatch preparations (slab copies / round-robin
        # reorders + host inv_perm), keyed by (plan key, strategy): a
        # recurring sharded graph pays the O(B*C) host prep once, not per
        # request. Small LRU — entries are per GIANT/wide graph only.
        self._shard_prep: "OrderedDict[Tuple, Dict]" = OrderedDict()
        self._shard_prep_cap = 16
        self._prep_lock = threading.Lock()
        # fleet counters (all under the inherited _counters_lock)
        self.fleet_rounds = 0
        self.device_dispatches = [0] * self.n_devices
        self.device_requests = [0] * self.n_devices
        self.device_busy_s = [0.0] * self.n_devices
        self.sharded_dispatches = {"feature": 0, "block": 0}
        self.sharded_busy_s = 0.0    # whole-mesh launch time, kept separate
        #                              from the per-device busy clocks
        self.last_fleet_decision: Optional[FleetDecision] = None
        self.last_block_counts: Optional[List[int]] = None
        self._t_first_launch: Optional[float] = None
        self._t_last_done: Optional[float] = None
        # hot-plan replication: EWMA rates -> promote/demote at flush
        # boundaries (a custom cache without the replica API disables it)
        self.hedge_ms = hedge_ms
        # a split sub-group below this many requests costs more in fixed
        # dispatch overhead than its replica parallelism buys back
        self.split_min_requests = max(1, split_min_requests)
        self.hedged_dispatches = 0
        self.hedge_wins = 0
        self.replicas: Optional[ReplicaManager] = None
        if (replicate_hot and self.n_devices > 1
                and hasattr(self.cache, "add_replica")):
            self.replicas = ReplicaManager(
                replicas_fn=self.cache.replica_devices,
                add_fn=self._add_replica,
                drop_fn=self._drop_replica,
                device_load_fn=self._device_loads,
                rate_per_replica=rate_per_replica,
                max_replicas=min(max_replicas, self.n_devices),
                halflife_s=replica_halflife_s,
                interval_s=replication_interval_s)

    def close(self) -> None:
        super().close()
        self._pool.shutdown(wait=True)

    # -------------------------------------------------------------- replicas
    def _add_replica(self, key, dev: int) -> bool:
        """ReplicaManager promotion hook: stage a copy locally and, when a
        placement directory is attached (the multihost engine), record the
        new ``(host, device)`` replica fleet-wide."""
        if not self.cache.add_replica(key, dev):
            return False
        directory = getattr(self, "directory", None)
        if directory is not None:
            try:
                directory.add_replica(
                    key, getattr(self, "process_index", 0), dev)
            except (KeyError, ValueError):
                pass    # directory host table lags (mid-rejoin): local
                #         replica still serves, directory catches up later
        return True

    def _drop_replica(self, key, dev: int) -> bool:
        """ReplicaManager demotion hook (mirror of :meth:`_add_replica`)."""
        if not self.cache.drop_replica(key, dev):
            return False
        directory = getattr(self, "directory", None)
        if directory is not None:
            directory.remove_replica(
                key, getattr(self, "process_index", 0), dev)
        return True

    def _device_loads(self) -> List[float]:
        with self._counters_lock:
            return list(self.device_busy_s)

    def reset_stats(self) -> None:
        """Zero the fleet counters (busy clocks, dispatch/request tallies,
        round count, occupancy window) WITHOUT touching placements,
        replicas, or learned request rates. Benchmarks use this to measure
        steady-state occupancy: warm the engine until the hot set is
        replicated, reset, then measure only the warmed rounds."""
        with self._counters_lock:
            self.fleet_rounds = 0
            self.device_dispatches = [0] * self.n_devices
            self.device_requests = [0] * self.n_devices
            self.device_busy_s = [0.0] * self.n_devices
            self.sharded_dispatches = {"feature": 0, "block": 0}
            self.sharded_busy_s = 0.0
            self.hedged_dispatches = 0
            self.hedge_wins = 0
            self._t_first_launch = None
            self._t_last_done = None

    # ------------------------------------------------------------------ flush
    def _flush_reads(self, items: List[WorkItem]) -> None:
        """Group by graph, route each group, launch per-device CONCURRENTLY.

        Runs on the scheduler thread; per-device and sharded launches run on
        the device pool. A raising launch does not abort its siblings —
        every launch completes or fails its own items, then the first
        exception re-raises so the scheduler fails any stragglers.

        With replication on, a single-device group goes to the least-loaded
        replica of its plan (round-local load first, busy clock as the
        tie-break), and a multi-request group on a replicated plan SPLITS
        across its replicas — each sub-group fuses and dispatches on its
        own device, concurrently.
        """
        order, groups = self._group_by_graph(items)
        plans = {gid: self.plan_for(gid) for gid in order}
        # version-pin each plan for the round: a concurrent publish retires
        # the superseded version but cannot reclaim it under a dispatch
        pinned = [p.key for p in plans.values()]
        for k in pinned:
            self.cache.pin_version(k)
        try:
            self._flush_routed(order, groups, plans)
        finally:
            for k in pinned:
                self.cache.unpin_version(k)

    def _flush_routed(self, order: List[str],
                      groups: Dict[str, List[WorkItem]],
                      plans: Dict[str, PartitionPlan]) -> None:
        """Route + launch one round of already-grouped read work."""
        # counted at flush start so a stats() read racing the final
        # future resolution never sees requests from an uncounted round
        with self._counters_lock:
            self.fleet_rounds += 1
            busy = list(self.device_busy_s)

        sharded: List[Tuple[FleetDecision, str]] = []
        per_dev: Dict[int, List[Tuple[str, List[WorkItem],
                                      PartitionPlan]]] = {}
        round_load: Dict[int, int] = {}
        hedges: List[Tuple[int, str, List[WorkItem], PartitionPlan]] = []

        def load_key(d: int) -> Tuple[int, float]:
            return (round_load.get(d, 0), busy[d])

        def assign(dev: int, gid: str, grp: List[WorkItem],
                   plan: PartitionPlan) -> None:
            per_dev.setdefault(dev, []).append((gid, grp, plan))
            round_load[dev] = round_load.get(dev, 0) + len(grp)

        with self._bind_lock:   # snapshot: gid -> current chained key
            keys = {gid: self._keys[gid] for gid in order}
        for gid in order:
            plan = plans[gid]
            grp = groups[gid]
            key = keys[gid]
            devs: List[int] = []
            if self.replicas is not None:
                # every request counts toward the rate estimate, whatever
                # path the group ends up on — otherwise hot graphs that
                # route to whole-mesh sharding never look hot
                self.replicas.observe(key, len(grp))
                devs = self.cache.replica_devices(key)
            if len(devs) <= 1 or len(grp) == 1:
                # unreplicated (or single-request) groups keep the PR-5
                # routing: whole-mesh shard when the fused dispatch is big
                # enough to warrant it. A replicated multi-request group
                # skips this — splitting over its replicas runs the same
                # work without any cross-device psum/gather.
                fused_f = sum(int(it.payload[1].shape[1]) for it in grp)
                fd = route_fleet(
                    plan.n_cols, fused_f, int(plan.slabs["C"]),
                    int(plan.slabs["R"]), plan.num_blocks, self.n_devices,
                    min_blocks_per_device=self.min_blocks_per_device)
                if fd.strategy in ("feature", "block"):
                    sharded.append((fd, gid))
                    continue
            if not devs:
                devs = [self.cache.device_index_of(key)]
            primary = devs[0]

            def replica_plan(dev: int) -> Optional[PartitionPlan]:
                return plan if dev == primary else self.cache.plan_on(
                    key, dev)

            if len(devs) == 1 or len(grp) == 1:
                dev = min(devs, key=load_key)
                p = replica_plan(dev)
                if p is None:           # replica copy LRU-evicted meanwhile
                    dev, p = primary, plan
                assign(dev, gid, grp, p)
                if self.hedge_ms is not None and len(devs) > 1:
                    alts = [d for d in devs if d != dev]
                    hp = replica_plan(min(alts, key=load_key))
                    if hp is not None:
                        hedges.append(
                            (min(alts, key=load_key), gid, grp, hp))
            else:
                # hot-group split: the fused group's requests spread over
                # its replicas, least-loaded first — but never into
                # sub-groups smaller than split_min_requests (fixed
                # dispatch overhead would eat the parallelism win). Up to
                # 4 sub-groups PER replica: several back-to-back dispatches
                # per device keep every device busy until the round ends
                # instead of early finishers idling behind the stragglers.
                by_load = sorted(devs, key=load_key)
                n_sub = max(1, min(len(grp) // self.split_min_requests,
                                   4 * len(by_load)))
                buckets: List[List[WorkItem]] = [[] for _ in range(n_sub)]
                for i, it in enumerate(grp):
                    buckets[i % n_sub].append(it)
                for j, sub_grp in enumerate(buckets):
                    dev = by_load[j % len(by_load)]
                    p = replica_plan(dev)
                    if p is None:
                        dev, p = primary, plan
                    assign(dev, gid, sub_grp, p)

        # ONE pool task per device (its chunks run back to back, so the
        # per-device busy clock never double-bills overlapping launches);
        # sharded whole-mesh dispatches get their own tasks
        launches = []
        for dev, work in sorted(per_dev.items()):
            launches.append(partial(self._launch_device, dev, work))
        for fd, gid in sharded:
            launches.append(
                partial(self._launch_sharded, fd, gid, groups, plans))
        for hedge in hedges:
            timer = threading.Timer(self.hedge_ms / 1e3, self._run_hedge,
                                    args=hedge)
            timer.daemon = True
            timer.start()

        first_exc: Optional[BaseException] = None
        n_ok = 0
        if len(launches) == 1:          # common case: skip the pool hop
            try:
                launches[0]()
                n_ok = 1
            except BaseException as e:  # noqa: BLE001 — re-raised below
                first_exc = e
        else:
            futs = [self._pool.submit(fn) for fn in launches]
            for f in futs:
                try:
                    f.result()
                    n_ok += 1
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    if first_exc is None:
                        first_exc = e
        if first_exc is not None:
            if n_ok == 0:
                # nothing dispatched: don't let an all-failed flush deflate
                # fleet_graphs_per_round (the nightly acceptance metric)
                with self._counters_lock:
                    self.fleet_rounds -= 1
            raise first_exc
        if self.replicas is not None:
            # "background" promotion/demotion without a dedicated thread:
            # tick at flush boundaries, rate-limited by interval_s (one
            # sweep stages at most a few plan copies)
            self.replicas.maybe_step()

    # ---------------------------------------------------------------- device
    def _launch_device(self, dev: int,
                       work: List[Tuple[str, List[WorkItem],
                                        PartitionPlan]]) -> None:
        """One device's dispatches for this round, back to back: each work
        tuple's plan copy is already resident on ``devices[dev]`` (the
        primary committed by the fleet cache, replicas staged by the
        ReplicaManager), so running the inherited dispatch under that
        default device keeps every intermediate local. Chunking by
        ``max_graphs_per_batch`` matches the single-device engine."""
        t0 = time.perf_counter()
        with jax.default_device(self.devices[dev]):
            for start in range(0, len(work), self.max_graphs_per_batch):
                chunk = work[start:start + self.max_graphs_per_batch]
                # count BEFORE the dispatch resolves its futures: a caller
                # whose serve() unblocks on the last future must see these
                # requests in the per-device stats (rolled back on failure,
                # mirroring the base counters never advancing)
                n_req = sum(len(grp) for _, grp, _ in chunk)
                with self._counters_lock:
                    self.device_dispatches[dev] += 1
                    self.device_requests[dev] += n_req
                try:
                    self._dispatch(chunk)
                except BaseException:
                    with self._counters_lock:
                        self.device_dispatches[dev] -= 1
                        self.device_requests[dev] -= n_req
                    raise
        dt = time.perf_counter() - t0
        with self._counters_lock:
            self.device_busy_s[dev] += dt
            self._note_window_locked(t0, dt)

    def _run_hedge(self, dev: int, gid: str, grp: List[WorkItem],
                   plan: PartitionPlan) -> None:
        """Tail-latency hedge: ``hedge_ms`` after the flush, re-dispatch a
        group's still-pending requests on another replica. Answers settle
        idempotently (``WorkItem.complete`` is first-wins), so a duplicate
        result is harmless; a hedge failure is swallowed — the primary
        dispatch owns the items. Hedges do NOT count as served requests
        (only the hedge counters move), keeping the per-device request
        balance exact."""
        pending = [it for it in grp if not it.done]
        if not pending:
            return
        try:
            feats = [jnp.asarray(it.payload[1], dtype=jnp.float32)
                     for it in pending]
            widths = [int(f.shape[1]) for f in feats]
            x = (feats[0] if len(feats) == 1
                 else jnp.concatenate(feats, axis=1))
            with jax.default_device(self.devices[dev]):
                outs = spmm_batched([plan.slabs], [x], [plan.n_rows],
                                    backend=self.backend,
                                    interpret=self.interpret)
            out = outs[0][plan.inv_perm]
            answers, _ = self._slice_answers(pending, widths, out,
                                             time.perf_counter())
            wins = 0
            for item, result in answers:
                if not item.done:
                    item.complete(result)
                    wins += 1
            with self._counters_lock:
                self.hedged_dispatches += 1
                self.hedge_wins += wins
        except Exception:   # noqa: BLE001 — best-effort duplicate work
            pass

    # --------------------------------------------------------------- sharded
    def _launch_sharded(self, fd: FleetDecision, gid: str,
                        groups: Dict[str, List[WorkItem]],
                        plans: Dict[str, PartitionPlan]) -> None:
        """Whole-mesh dispatch of ONE graph group (feature- or block-shard)."""
        t0 = time.perf_counter()
        grp = groups[gid]
        plan = plans[gid]
        feats = [jnp.asarray(it.payload[1], dtype=jnp.float32) for it in grp]
        x = feats[0] if len(feats) == 1 else jnp.concatenate(feats, axis=1)
        widths = [int(f.shape[1]) for f in feats]

        prep = self._shard_prepared(fd.strategy, plan)
        live_counts: Optional[np.ndarray] = None
        if fd.strategy == "feature":
            out = spmm_feature_sharded(plan.slabs, x, plan.n_rows, self.mesh,
                                       prepared=prep["args"])
        else:
            out, live_counts = spmm_block_sharded(
                plan.slabs, x, plan.n_rows, self.mesh,
                prepared=(prep["args"], prep["live"]))
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0

        now = time.perf_counter()
        # the mesh-sharded result cannot gather against the owner-committed
        # inv_perm (incompatible devices) — un-permute on host, re-upload
        # uncommitted so answers behave like every other engine output
        out = jnp.asarray(np.asarray(out)[prep["inv_np"]])
        # slice outside the lock (same rule as the base dispatch: concurrent
        # launches must not serialize compute on the counter lock)
        answers, wait_s = self._slice_answers(grp, widths, out, now)
        with self._counters_lock:
            self.requests_served += len(grp)
            self.rows_served += plan.n_rows * len(grp)
            self.values_served += plan.n_rows * sum(widths)
            self.total_request_latency_s += wait_s
            self.batches_dispatched += 1
            self.graphs_dispatched += 1
            self.total_serve_s += dt
            self.live_blocks += plan.num_blocks
            self.padded_blocks += plan.num_blocks
            # what actually executed inside shard_map is the jnp slab twin,
            # so the routed_* invariant (sums to batches_dispatched) holds
            self.backend_dispatches["blocked"] += 1
            self.sharded_dispatches[fd.strategy] += 1
            self.sharded_busy_s += dt
            self.last_fleet_decision = fd
            if live_counts is not None:
                self.last_block_counts = [int(c) for c in live_counts]
            self._note_window_locked(t0, dt)
        for item, result in answers:
            item.complete(result)

    def _shard_prepared(self, strategy: str, plan: PartitionPlan,
                        n_devices: Optional[int] = None) -> Dict:
        """Memoized per-(plan, strategy, device-count) sharded-dispatch
        preparation (the multihost engine preps block shards for the
        GLOBAL device count, the local paths for the local one)."""
        n = n_devices if n_devices is not None else self.n_devices
        key = (plan.key, strategy, n)
        with self._prep_lock:
            ent = self._shard_prep.get(key)
            if ent is not None:
                self._shard_prep.move_to_end(key)
                return ent
        if strategy == "feature":
            ent = {"args": prepare_feature_shards(plan.slabs), "live": None}
        else:
            args, live = prepare_block_shards(plan.slabs, plan.n_rows, n)
            ent = {"args": args, "live": live}
        ent["inv_np"] = np.asarray(plan.inv_perm)
        with self._prep_lock:
            self._shard_prep[key] = ent
            while len(self._shard_prep) > self._shard_prep_cap:
                self._shard_prep.popitem(last=False)
        return ent

    def _note_window_locked(self, t0: float, dt: float) -> None:
        if self._t_first_launch is None:
            self._t_first_launch = t0
        self._t_last_done = max(self._t_last_done or 0.0, t0 + dt)

    # the multihost subclass keeps per-graph flush groups intact; factoring
    # the split point here keeps ONE grouping implementation
    def _flush_items_locally(self, items: List[WorkItem]) -> None:
        """Serve a subset of a flush (always READ items — mutations are
        never forwarded or failed over) entirely on this host's devices."""
        FleetGraphEngine._flush_reads(self, items)

    # ------------------------------------------------------------------ stats
    def _stats_locked(self, s: Dict[str, float]) -> Dict[str, float]:
        """Extends the base under-lock snapshot, so base and fleet counters
        come from the SAME instant (one atomic snapshot, one lock hold)."""
        s = super()._stats_locked(s)
        wall = ((self._t_last_done - self._t_first_launch)
                if self._t_first_launch is not None
                and self._t_last_done is not None else 0.0)
        counts = self.last_block_counts
        s.update(
            fleet_devices=self.n_devices,
            fleet_rounds=self.fleet_rounds,
            # scheduler-level coalescing per synchronized launch wave — the
            # fleet analogue of the single engine's graphs_per_dispatch
            # (device launches in one round run concurrently, not back to
            # back)
            fleet_graphs_per_round=(self.graphs_dispatched
                                    / self.fleet_rounds
                                    if self.fleet_rounds else 0.0),
            fleet_device_dispatches=list(self.device_dispatches),
            fleet_device_requests=list(self.device_requests),
            fleet_device_busy_s=list(self.device_busy_s),
            fleet_sharded_busy_s=self.sharded_busy_s,
            fleet_wall_s=wall,
            # mean busy fraction across devices over the serving window,
            # from the per-device clocks only (per-device launches never
            # overlap on one device, so this stays <= 1; whole-mesh sharded
            # launches are reported separately as fleet_sharded_busy_s)
            fleet_occupancy=(sum(self.device_busy_s)
                             / (wall * self.n_devices)
                             if wall > 0 else 0.0),
            fleet_feature_sharded=self.sharded_dispatches["feature"],
            fleet_block_sharded=self.sharded_dispatches["block"],
            fleet_block_counts=list(counts) if counts else [],
            # balance of the last block-sharded dispatch: max/mean live
            # blocks per device (1.0 == perfectly balanced)
            fleet_block_balance=(max(counts) * len(counts) / sum(counts)
                                 if counts and sum(counts) else 0.0),
            # tail-latency hedging (0 unless hedge_ms is set)
            fleet_hedged=self.hedged_dispatches,
            fleet_hedge_wins=self.hedge_wins,
        )
        # hot-plan replication activity (replica_* residency counts arrive
        # via the cache_* prefix: cache_replicated_keys, cache_replica_copies)
        if self.replicas is not None:
            s.update({f"fleet_{k}": v
                      for k, v in self.replicas.stats().items()})
        else:
            s.update(fleet_promotions=0, fleet_demotions=0,
                     fleet_replication_steps=0)
        return s


class MultihostGraphEngine(FleetGraphEngine):
    """Cross-host fleet serving: one engine per process, one shared
    placement directory, a TCP forwarding data plane between hosts.

    The flush pipeline grows exactly one stage over the single-host fleet::

        flush -> group by graph
              -> split groups by OWNING HOST (placement directory)
                   local groups  -> the inherited per-device concurrent path
                   remote groups -> fused request forwarded to the owner
                                    host over its peer channel; the owner
                                    dispatches it INLINE on the connection
                                    thread (never through its scheduler
                                    queue — two hosts forwarding to each
                                    other through single flush workers
                                    would deadlock), the answer travels
                                    back and resolves the ingress futures

    Ownership: :class:`~repro.distributed.directory.PlacementDirectory`
    maps each plan key to a ``(host, device)`` slot; the owning host pins
    the slot's device into its local :class:`FleetPlanCache`
    (:meth:`FleetPlanCache.pin`), so what the fleet believes and where the
    slabs actually sit agree. Registration is symmetric (every host
    registers every graph — the bytes come from shared storage) but only
    the OWNER builds and stages the plan: fleet plan capacity is the sum
    over hosts, which is the whole point.

    Failure handling: a dead peer channel fails over — the affected items
    are served locally from a freshly-built plan, and after
    ``evict_after_failures`` CONSECUTIVE transport failures the owner is
    evicted from the directory (its keys re-place onto survivors; a
    recovered host rejoins via :meth:`connect_peers`). Remote EXECUTION
    errors do not fail over; they propagate to the submitting caller like
    any local dispatch error.

    ``serve_global`` is the explicitly-COLLECTIVE path for graphs too big
    for any single host: every process must call it with identical
    arguments; the plan's blocks round-robin over the global mesh
    (:func:`repro.launch.mesh.multihost_graph_mesh`) and a cross-host psum
    combines the row partials. The continuous-batching submit path never
    triggers it implicitly — collective execution cannot hide behind a
    per-host scheduler.

    Operational rule: a host PARKED INSIDE A COLLECTIVE cannot answer the
    data plane — the pending collective occupies its device queue, so a
    forwarded dispatch queues behind it and the ingress times out (then
    fails over). Sequence phase changes over the data plane (a peer-server
    op setting an Event, as the two-process test does), and only enter
    collective phases once forwarding traffic has drained.
    """

    def __init__(
        self,
        *,
        context: Optional[MultihostContext] = None,
        directory: Optional[PlacementDirectory] = None,
        peer_addresses: Optional[Mapping[int, Tuple[str, int]]] = None,
        serve_port: Optional[int] = None,
        peer_timeout_s: float = 120.0,
        evict_after_failures: int = 3,
        **engine_kw,
    ):
        if context is None:
            context = MultihostContext(
                process_index=0, process_count=1, coordinator=None,
                local_devices=list(jax.local_devices()),
                global_devices=list(jax.devices()))
        self.context = context
        self.process_index = context.process_index
        self.process_count = context.process_count
        if directory is None:
            # homogeneous-fleet default: every rank assumed to carry this
            # rank's device count (peer handshakes correct the table)
            directory = PlacementDirectory([
                HostInfo(p, context.n_local_devices, 0)
                for p in range(context.process_count)])
        self.directory = directory

        super().__init__(devices=context.local_devices, **engine_kw)
        # the inherited pool is sized for per-device launches; forwards to
        # remote owners block on the network, so give them their own slots
        self._pool.shutdown(wait=True)
        self._pool = ThreadPoolExecutor(
            max_workers=self.n_devices + max(1, self.process_count - 1),
            thread_name_prefix="fleet-dev")

        ports = peer_ports()
        if serve_port is None:
            serve_port = ports.get(self.process_index, 0)
        self.server = PeerServer(serve_port,
                                 process_index=self.process_index,
                                 epoch=context.epoch,
                                 n_devices=context.n_local_devices)
        self.server.register("serve", self._handle_peer_serve)
        self.server.register("mutate", self._handle_peer_mutate)
        if peer_addresses is None:
            peer_addresses = {r: ("127.0.0.1", p) for r, p in ports.items()
                              if r != self.process_index}
        self.peers: Dict[int, PeerClient] = {
            int(r): PeerClient(tuple(addr), process_index=self.process_index,
                               epoch=context.epoch, timeout_s=peer_timeout_s)
            for r, addr in peer_addresses.items()
            if int(r) != self.process_index}

        # multihost counters (under the inherited _counters_lock)
        self.forwarded_requests = 0
        self.host_forwarded = [0] * self.process_count
        self.remote_served = 0        # peer groups answered on their behalf
        self.forward_busy_s = 0.0
        self.host_failovers = 0
        self.global_dispatches = 0
        self.mutation_broadcasts = 0          # peer deliveries of a mutation
        self.mutation_broadcast_failures = 0  # peers a broadcast missed
        self.remote_mutations = 0             # mutations applied for a peer
        # consecutive transport failures per peer: a single slow request
        # (socket timeout on a busy owner) serves locally but keeps the
        # placements — only a PERSISTENT failure evicts the host
        self.evict_after_failures = evict_after_failures
        self._peer_failures: Dict[int, int] = {}
        # graph ids registered via register_subgraph: frontier subgraphs
        # are sampled near the data, so they serve from THIS host and
        # never enter the placement directory (guarded by _bind_lock)
        self._local_only: set = set()

    # ----------------------------------------------------------------- peers
    def connect_peers(self) -> Dict[int, int]:
        """Handshake every peer channel; the learned ``(rank, epoch,
        n_devices)`` feed the directory (a bumped epoch invalidates the
        restarted host's stale placements). Returns ``{rank: epoch}``.

        Also the REJOIN path: calling it again after a peer was evicted
        (persistent transport failure) re-announces the recovered host to
        the directory — its ring arcs come back and its failure counter
        resets. Note the rejoin is forward-looking: keys re-placed onto
        survivors during the outage are STICKY there (their plans are
        already resident); only unseen/invalidated keys land on the
        recovered host's arcs again.
        """
        epochs: Dict[int, int] = {}
        for _rank, client in sorted(self.peers.items()):
            peer_rank, peer_epoch = client.handshake()
            epochs[peer_rank] = peer_epoch
            self.directory.update_host(HostInfo(
                peer_rank, client.peer_devices or self.n_devices,
                peer_epoch))
            with self._counters_lock:
                self._peer_failures[peer_rank] = 0
        return epochs

    def _handle_peer_serve(self, payload: Dict) -> np.ndarray:
        """Data-plane handler: a peer forwarded a fused request group we
        own. It executes INLINE on this connection thread (an adopted,
        never-enqueued work item) — queueing it behind our single flush
        worker would deadlock two hosts forwarding to each other: A's
        worker blocks on B's answer while B's worker blocks on A's. The
        pinned-local marker keeps the item off the forwarding split even
        if it ever re-enters a flush path."""
        gid = payload["graph_id"]
        x = jnp.asarray(payload["x"], dtype=jnp.float32)
        self._validate(gid, x)
        item = self.scheduler.adopt((gid, x, "pinned-local"))
        try:
            self._flush_items_locally([item])
        finally:
            if not item.done:   # dispatch raised (or forgot the item):
                item.fail(RuntimeError(   # never leave the peer hanging
                    f"peer dispatch left {gid!r} unanswered"))
        out = np.asarray(item.future.result(timeout=0))
        with self._counters_lock:
            self.remote_served += 1
        return out

    def close(self) -> None:
        super().close()               # drain the scheduler (may still forward)
        for client in self.peers.values():
            client.close()
        self.server.close()

    # ------------------------------------------------------------------ admin
    def register_graph(self, graph_id: str, g: CSRGraph,
                       normalize: bool = False) -> Optional[PartitionPlan]:
        """Register a graph fleet-wide (call on EVERY host with the same
        content — registration is symmetric, plan residency is not).

        Only the directory-designated owner builds and stages the plan (on
        the directory's device, pinned into the local cache); other hosts
        record the binding and forward at serve time. Returns the plan on
        the owner, None elsewhere.
        """
        if normalize:
            g = gcn_normalize(g)
        key = (graph_content_hash(g), self.config)
        with self._bind_lock:
            prev_key = self._keys.get(graph_id)
            prev_ver = self._versions.get(graph_id)
            if prev_key == key and prev_ver is not None:
                version = prev_ver      # idempotent re-register
            elif prev_ver is not None:
                version = prev_ver + 1  # content replacement: chain advances
            else:
                version = 0
            self._graphs[graph_id] = g
            self._keys[graph_id] = key
            self._versions[graph_id] = version
        # seed the version chain fleet-wide: deterministic on every host,
        # so the first mutate's record_version(v+1) invalidates this key
        # everywhere without coordination
        self.directory.record_version(graph_id, key, version)
        placement = self.directory.place(key)
        if placement.host != self.process_index:
            return None
        self.cache.pin(key, placement.device)
        return self.cache.get_by_key(
            key, lambda: build_partition_plan(g, self.config,
                                              graph_hash=key[0]))

    def register_subgraph(self, g: CSRGraph, prefix: str = "sub",
                          normalize: bool = False) -> str:
        """Register a frontier subgraph LOCALLY — sampling happens near
        the data, so the induced subgraph must serve from this host, not
        wherever the directory's consistent hash would place its key.
        Uses the single-host fleet path (local device placement via
        ``FleetPlanCache``) and marks the id so ``_flush_reads`` never
        consults the directory or forwards it to a peer.
        """
        if normalize:
            g = gcn_normalize(g)
        graph_id = f"{prefix}:{graph_content_hash(g)[:16]}"
        with self._bind_lock:
            self._local_only.add(graph_id)
        FleetGraphEngine.register_graph(self, graph_id, g)
        return graph_id

    def unregister_graph(self, graph_id: str) -> bool:
        with self._bind_lock:
            self._local_only.discard(graph_id)
        return super().unregister_graph(graph_id)

    # ------------------------------------------------------------------ flush
    def _flush_reads(self, items: List[WorkItem]) -> None:
        """Split the read share of a flush by owning host FIRST; the local
        share then runs the inherited per-device concurrent path while
        remote shares forward concurrently from the pool (one task per
        owner host). Mutations never reach here — the base ``_flush``
        wrapper splits them out and routes them via ``_apply_mutation``."""
        if self.process_count <= 1 or not self.peers:
            return super()._flush_reads(items)
        order, groups = self._group_by_graph(items)
        local: List[WorkItem] = []
        by_host: Dict[int, List[Tuple[str, List[WorkItem]]]] = {}
        with self._bind_lock:   # snapshot: gid -> current chained key
            keys = dict(self._keys)
        for gid in order:
            grp = groups[gid]
            if any(len(it.payload) > 2 for it in grp):
                local.extend(grp)     # pinned by a peer forward: never bounce
                continue
            if gid in self._local_only:
                local.extend(grp)     # frontier subgraph: sampled near the
                continue              # data, never directory-placed
            # consult the full replica set: a plan replicated ONTO this
            # host serves locally even when another host owns the primary
            reps = self.directory.replicas(keys[gid])
            owner = reps[0]
            if (any(r.host == self.process_index for r in reps)
                    or owner.host not in self.peers):
                local.extend(grp)
            else:
                by_host.setdefault(owner.host, []).append((gid, grp))

        futs = [self._pool.submit(self._forward_host, host, host_groups)
                for host, host_groups in sorted(by_host.items())]
        first_exc: Optional[BaseException] = None
        if local:
            try:
                super()._flush_reads(local)
            except BaseException as e:  # noqa: BLE001 — re-raised below
                first_exc = e
        for f in futs:
            try:
                f.result()
            except BaseException as e:  # noqa: BLE001 — re-raised below
                if first_exc is None:
                    first_exc = e
        if first_exc is not None:
            raise first_exc

    def _forward_host(self, host: int,
                      host_groups: List[Tuple[str, List[WorkItem]]]) -> None:
        """Forward one owner host's graph groups over its peer channel.

        Same fusion as a local dispatch: one request per graph group, the
        feature axis concatenated, the answer sliced back per item. A
        TRANSPORT failure serves the unanswered items locally (failover)
        and, only after ``evict_after_failures`` CONSECUTIVE failures,
        evicts the host from the directory (stale-host eviction;
        survivors inherit its keys — ``connect_peers`` re-admits a
        recovered host). A remote execution error propagates as-is.
        """
        t0 = time.perf_counter()
        client = self.peers[host]
        try:
            for gid, grp in host_groups:
                feats = [np.asarray(it.payload[1], dtype=np.float32)
                         for it in grp]
                widths = [int(f.shape[1]) for f in feats]
                fused = (feats[0] if len(feats) == 1
                         else np.concatenate(feats, axis=1))
                out = jnp.asarray(client.request(
                    "serve", {"graph_id": gid, "x": fused}))
                with self._counters_lock:
                    self._peer_failures[host] = 0
                answers, wait_s = self._slice_answers(
                    grp, widths, out, time.perf_counter())
                n_rows = int(out.shape[0])
                with self._counters_lock:
                    self.forwarded_requests += len(grp)
                    self.host_forwarded[host] += len(grp)
                    self.requests_served += len(grp)
                    self.rows_served += n_rows * len(grp)
                    self.values_served += n_rows * sum(widths)
                    self.total_request_latency_s += wait_s
                for item, result in answers:
                    item.complete(result)
        except ConnectionError:
            # serve the stragglers here either way; only a PERSISTENT
            # failure drops the host from the ring (one slow answer must
            # not permanently split the fleet — the placements stay, so
            # the next flush retries the forward)
            with self._counters_lock:
                self.host_failovers += 1
                n_fail = self._peer_failures.get(host, 0) + 1
                self._peer_failures[host] = n_fail
            if n_fail >= self.evict_after_failures:
                try:
                    self.directory.evict_host(host)
                except ValueError:
                    pass               # already the last host standing
            stragglers = [it for _, grp in host_groups for it in grp
                          if not it.done]
            if stragglers:
                self._flush_items_locally(stragglers)
        finally:
            dt = time.perf_counter() - t0
            with self._counters_lock:
                self.forward_busy_s += dt

    # --------------------------------------------------------------- mutation
    def _apply_mutation(self, gid: str, grp: List[WorkItem]) -> None:
        """Fleet-wide mutation: apply + publish locally, then broadcast the
        SAME delta sequence to every peer over the data plane.

        Every host runs the identical deterministic transition
        (:meth:`_apply_deltas_local`), so the fleet converges without a
        coordinator: same deltas -> same new graph -> same content-hash key
        -> same directory record. Writer discipline is SINGLE WRITER PER
        GRAPH (any host may be that writer): two hosts mutating one graph
        concurrently race their broadcasts and the version-fork guard on
        the receiving side fails the later one rather than silently
        diverging. A peer the broadcast cannot reach keeps serving its old
        binding until it rejoins — the directory record (replayed by every
        reachable host) already stops requests from being FORWARDED to it
        for this graph's new key.
        """
        if gid in self._local_only:
            # frontier subgraph: repair through the single-host path — no
            # broadcast, no directory transition (the id was never placed)
            return GraphServeEngine._apply_mutation(self, gid, grp)
        deltas: List[EdgeDelta] = [it.payload[1] for it in grp]
        info = self._apply_deltas_local(gid, deltas)
        if self.process_count > 1 and self.peers:
            payload = {"graph_id": gid, "deltas": deltas,
                       "base_version": info["version"] - 1}
            for rank, client in sorted(self.peers.items()):
                try:
                    client.request("mutate", payload)
                    with self._counters_lock:
                        self.mutation_broadcasts += 1
                        self._peer_failures[rank] = 0
                except ConnectionError:
                    with self._counters_lock:
                        self.mutation_broadcast_failures += 1
        for it in grp:
            it.complete(dict(info))

    def _handle_peer_mutate(self, payload: Dict) -> Dict:
        """Data-plane handler: replay a peer's mutation on this host.

        Runs inline on the connection thread (like ``serve``); the version
        fork guard raises back to the writer if this host's chain is not
        at the broadcast's base version.
        """
        gid = payload["graph_id"]
        with self._bind_lock:
            if gid not in self._graphs:
                raise KeyError(
                    f"graph {gid!r} not registered on host "
                    f"{self.process_index}")
        info = self._apply_deltas_local(
            gid, payload["deltas"],
            expect_base=payload.get("base_version"))
        with self._counters_lock:
            self.remote_mutations += 1
        return {"graph_id": gid, "version": info["version"]}

    def _apply_deltas_local(self, gid: str, deltas: Sequence[EdgeDelta],
                            expect_base: Optional[int] = None) -> Dict:
        """One host's share of a fleet mutation (deterministic transition).

        Applies the deltas SEQUENTIALLY, advances the version chain in the
        directory (sticky owner slot via :meth:`PlacementDirectory.place_at`),
        and — only on the owner host — repairs and publishes the plan; the
        other hosts re-bind and retire their stale copies. With
        ``expect_base`` set (a replayed broadcast), a chain not at that
        version raises instead of forking.
        """
        with self._mutate_lock:
            with self._bind_lock:
                g_old = self._graphs[gid]
                old_key = self._keys[gid]
                cur_ver = self._versions[gid]
            if expect_base is not None and cur_ver != expect_base:
                raise RuntimeError(
                    f"mutation version fork on {gid!r}: host "
                    f"{self.process_index} is at v{cur_ver}, writer "
                    f"published against v{expect_base} — one writer per "
                    f"graph at a time")
            g_new = g_old
            touched: List[np.ndarray] = []
            n_edges = 0
            gh = old_key[0]
            for d in deltas:
                g_new = d.apply(g_new)
                touched.append(d.touched_rows())
                n_edges += d.size
                gh = delta_chain_hash(gh, d)
            # O(delta) chained key: every host chains the same deltas onto
            # the same parent hash, so the fleet converges on one key
            # without re-hashing the whole graph
            new_key = (gh, self.config)
            version = cur_ver + 1
            # deterministic directory transition: resolve the CURRENT
            # owner, advance the chain (drops the old key fleet-wide),
            # re-pin the new key to the same slot
            owner = self.directory.place(old_key)
            self.directory.record_version(gid, new_key, version)
            self.directory.place_at(new_key, owner.host, owner.device)
            repaired, reason, dirty = False, "non-owner rebind", 0
            if owner.host == self.process_index:
                plan_old = self.cache.lookup(old_key)
                if plan_old is not None:
                    pv = repair_plan(
                        plan_old, g_old, g_new,
                        (np.unique(np.concatenate(touched)) if touched
                         else np.empty(0, np.int64)),
                        churn_threshold=self.repair_churn_threshold,
                        graph_hash=gh)
                    plan_new = pv.plan
                    repaired, reason, dirty = (pv.repaired, pv.reason,
                                               pv.dirty_rows)
                else:       # owner copy LRU-evicted: nothing to repair from
                    plan_new = build_partition_plan(
                        g_new, self.config, graph_hash=new_key[0])
                    reason = "owner plan not resident; full build"
                plan_new.version = version
                self.cache.pin(new_key, owner.device)
                self.cache.publish(plan_new, retire_key=old_key)
            else:
                self.cache.retire(old_key)
            with self._bind_lock:
                self._graphs[gid] = g_new
                self._keys[gid] = new_key
                self._versions[gid] = version
            with self._counters_lock:
                self.mutations_applied += len(deltas)
                self.mutation_edges += n_edges
                if owner.host == self.process_index:
                    if repaired:
                        self.plan_repairs += 1
                    else:
                        self.plan_rebuilds += 1
        return {"graph_id": gid, "version": version, "repaired": repaired,
                "reason": reason, "dirty_rows": dirty}

    # ----------------------------------------------------------------- global
    def serve_global(self, graph_id: str, x: jax.Array) -> jax.Array:
        """COLLECTIVE whole-fleet dispatch of one graph (SPMD contract:
        every process calls with identical arguments, in the same order
        relative to its other serve_global calls).

        Routes over the GLOBAL device count: when the dispatch
        block-shards (giant narrow graph), the blocks round-robin over
        every host's devices and the psum crosses hosts — fleet capacity
        for a single graph becomes the sum of every host's memory. A
        dispatch that routes "single" falls back to the local serving
        path on every host (identical answers, no collective).
        """
        plan = self.plan_for(graph_id)
        gmesh = multihost_graph_mesh()
        n_global = int(gmesh.devices.size)
        fd = route_fleet(
            plan.n_cols, int(x.shape[1]), int(plan.slabs["C"]),
            int(plan.slabs["R"]), plan.num_blocks, n_global,
            min_blocks_per_device=self.min_blocks_per_device,
            n_hosts=self.process_count)
        if fd.strategy != "block" or self.process_count <= 1:
            return self.serve_one(graph_id, x)
        t0 = time.perf_counter()
        prep = self._shard_prepared("block", plan, n_global)
        # commit the (immutable) slabs to the global sharding ONCE per
        # plan; later global dispatches of the same graph reuse them
        with self._prep_lock:
            committed = prep.get("global_args")
        if committed is None:
            committed = commit_block_shards_global(prep["args"], gmesh)
            with self._prep_lock:
                prep["global_args"] = committed
        out, live = spmm_block_sharded(
            plan.slabs, x, plan.n_rows, gmesh,
            prepared=(committed, prep["live"]))
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        out = jnp.asarray(np.asarray(out)[prep["inv_np"]])
        with self._counters_lock:
            self.global_dispatches += 1
            self.sharded_dispatches["block"] += 1
            self.sharded_busy_s += dt
            self.last_fleet_decision = fd
            self.last_block_counts = [int(c) for c in live]
            self._note_window_locked(t0, dt)
        return out

    # ------------------------------------------------------------------ stats
    def _stats_locked(self, s: Dict[str, float]) -> Dict[str, float]:
        s = super()._stats_locked(s)
        s.update(
            fleet_process_index=self.process_index,
            fleet_hosts=self.process_count,
            fleet_forwarded=self.forwarded_requests,
            fleet_host_forwarded=list(self.host_forwarded),
            fleet_remote_served=self.remote_served,
            fleet_forward_busy_s=self.forward_busy_s,
            fleet_host_failovers=self.host_failovers,
            fleet_global_dispatches=self.global_dispatches,
            fleet_mutation_broadcasts=self.mutation_broadcasts,
            fleet_mutation_broadcast_failures=self.mutation_broadcast_failures,
            fleet_remote_mutations=self.remote_mutations,
        )
        for k, v in self.directory.stats().items():
            s[f"fleet_dir_{k}"] = v
        return s
