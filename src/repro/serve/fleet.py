"""Multi-device fleet serving: per-device dispatch groups + sharded SpMM.

:class:`FleetGraphEngine` is the multi-device :class:`GraphServeEngine`.
Same admission path (the continuous-batching :class:`BatchScheduler`), same
request semantics (``submit(graph_id, x) -> Future`` answered in ORIGINAL
row order) — what changes is the flush:

1. requests group by graph (feature-axis fusion), exactly as before;
2. each graph group is routed by :func:`repro.kernels.router.route_fleet`:

   * ``single``  — the graph's plan lives on ONE device (consistent-hash
     placement via :class:`~repro.distributed.placement.FleetPlanCache`);
     its group joins that device's fused dispatch. Distinct devices'
     dispatches launch CONCURRENTLY from a device pool — the fleet analogue
     of the paper's block-level balancing: independent work never queues
     behind an unrelated device's kernel.
   * ``feature`` — wide-feature dispatches split column-wise over the whole
     mesh (zero-communication, the combined-warp column parallelism at
     device granularity).
   * ``block``   — one giant narrow graph round-robins its partition blocks
     across the mesh (X replicated, per-device row slabs psum'd back).

3. one flush == one *fleet round* of concurrent launches. ``stats()``
   reports per-device dispatch/request/busy-time balance and the
   block-shard live-block counts next to the inherited ``sched_*`` /
   ``cache_*`` counters.

Validated on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(see ``tests/test_fleet.py`` and the CI device matrix) — real multi-device
semantics, no hardware required. On one device everything degrades to the
single-device engine (the pool has one worker, sharding never triggers).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.plan_cache import PartitionConfig, PartitionPlan
from ..distributed.placement import FleetPlanCache
from ..distributed.shard_spmm import (
    prepare_block_shards, prepare_feature_shards,
    spmm_block_sharded, spmm_feature_sharded,
)
from ..kernels.router import FleetDecision, route_fleet
from ..launch.mesh import graph_mesh
from .graph_engine import GraphServeEngine
from .scheduler import WorkItem

__all__ = ["FleetGraphEngine"]


class FleetGraphEngine(GraphServeEngine):
    """Continuous-batching graph server over a device mesh.

    ``n_devices=None`` takes every visible device. ``capacity_per_device``
    bounds each device's plan-cache shard, so fleet plan capacity (and HBM
    residency) scales with device count — the ROADMAP's "serve more graphs
    than one host's HBM holds" axis.
    """

    def __init__(
        self,
        *,
        n_devices: Optional[int] = None,
        capacity_per_device: int = 32,
        load_spread: int = 4,
        save_dir: Optional[str] = None,
        min_blocks_per_device: int = 4,
        config: Optional[PartitionConfig] = None,
        **engine_kw,
    ):
        self.mesh = graph_mesh(n_devices)
        self.devices = list(self.mesh.devices.flat)
        self.n_devices = len(self.devices)
        cache = engine_kw.pop("cache", None)
        if cache is None:
            cache = FleetPlanCache(self.devices,
                                   capacity_per_device=capacity_per_device,
                                   load_spread=load_spread,
                                   save_dir=save_dir)
        elif not hasattr(cache, "device_index_of"):
            # fail at construction, not with an AttributeError on the
            # scheduler thread at first flush
            raise TypeError(
                f"FleetGraphEngine needs a device-partitioned cache "
                f"(FleetPlanCache), got {type(cache).__name__}")
        super().__init__(config=config, cache=cache, **engine_kw)
        self.min_blocks_per_device = min_blocks_per_device
        self._pool = ThreadPoolExecutor(max_workers=self.n_devices,
                                        thread_name_prefix="fleet-dev")
        # memoized sharded-dispatch preparations (slab copies / round-robin
        # reorders + host inv_perm), keyed by (plan key, strategy): a
        # recurring sharded graph pays the O(B*C) host prep once, not per
        # request. Small LRU — entries are per GIANT/wide graph only.
        self._shard_prep: "OrderedDict[Tuple, Dict]" = OrderedDict()
        self._shard_prep_cap = 16
        self._prep_lock = threading.Lock()
        # fleet counters (all under the inherited _counters_lock)
        self.fleet_rounds = 0
        self.device_dispatches = [0] * self.n_devices
        self.device_requests = [0] * self.n_devices
        self.device_busy_s = [0.0] * self.n_devices
        self.sharded_dispatches = {"feature": 0, "block": 0}
        self.sharded_busy_s = 0.0    # whole-mesh launch time, kept separate
        #                              from the per-device busy clocks
        self.last_fleet_decision: Optional[FleetDecision] = None
        self.last_block_counts: Optional[List[int]] = None
        self._t_first_launch: Optional[float] = None
        self._t_last_done: Optional[float] = None

    def close(self) -> None:
        super().close()
        self._pool.shutdown(wait=True)

    # ------------------------------------------------------------------ flush
    def _flush(self, items: List[WorkItem]) -> None:
        """Group by graph, route each group, launch per-device CONCURRENTLY.

        Runs on the scheduler thread; per-device and sharded launches run on
        the device pool. A raising launch does not abort its siblings —
        every launch completes or fails its own items, then the first
        exception re-raises so the scheduler fails any stragglers.
        """
        order, groups = self._group_by_graph(items)
        plans = {gid: self.plan_for(gid) for gid in order}

        # counted at flush start so a stats() read racing the final
        # future resolution never sees requests from an uncounted round
        with self._counters_lock:
            self.fleet_rounds += 1

        sharded: List[Tuple[FleetDecision, str]] = []
        per_dev: Dict[int, List[str]] = {}
        for gid in order:
            plan = plans[gid]
            fused_f = sum(int(it.payload[1].shape[1]) for it in groups[gid])
            fd = route_fleet(
                plan.n_cols, fused_f, int(plan.slabs["C"]),
                int(plan.slabs["R"]), plan.num_blocks, self.n_devices,
                min_blocks_per_device=self.min_blocks_per_device)
            if fd.strategy in ("feature", "block"):
                sharded.append((fd, gid))
            else:
                dev = self.cache.device_index_of(self._keys[gid])
                per_dev.setdefault(dev, []).append(gid)

        # ONE pool task per device (its chunks run back to back, so the
        # per-device busy clock never double-bills overlapping launches);
        # sharded whole-mesh dispatches get their own tasks
        launches = []
        for dev, gids in sorted(per_dev.items()):
            launches.append((self._launch_device, dev, gids))
        for fd, gid in sharded:
            launches.append((self._launch_sharded, fd, gid))

        first_exc: Optional[BaseException] = None
        n_ok = 0
        if len(launches) == 1:          # common case: skip the pool hop
            fn, *args = launches[0]
            try:
                fn(*args, groups, plans)
                n_ok = 1
            except BaseException as e:  # noqa: BLE001 — re-raised below
                first_exc = e
        else:
            futs = [self._pool.submit(fn, *args, groups, plans)
                    for fn, *args in launches]
            for f in futs:
                try:
                    f.result()
                    n_ok += 1
                except BaseException as e:  # noqa: BLE001 — re-raised below
                    if first_exc is None:
                        first_exc = e
        if first_exc is not None:
            if n_ok == 0:
                # nothing dispatched: don't let an all-failed flush deflate
                # fleet_graphs_per_round (the nightly acceptance metric)
                with self._counters_lock:
                    self.fleet_rounds -= 1
            raise first_exc

    # ---------------------------------------------------------------- device
    def _launch_device(self, dev: int, gids: List[str],
                       groups: Dict[str, List[WorkItem]],
                       plans: Dict[str, PartitionPlan]) -> None:
        """One device's dispatches for this round, back to back: the plan
        slabs are already resident on ``devices[dev]`` (committed by the
        fleet cache), so running the inherited dispatch under that default
        device keeps every intermediate local to the owner. Chunking by
        ``max_graphs_per_batch`` matches the single-device engine."""
        t0 = time.perf_counter()
        with jax.default_device(self.devices[dev]):
            for start in range(0, len(gids), self.max_graphs_per_batch):
                chunk = gids[start:start + self.max_graphs_per_batch]
                # count BEFORE the dispatch resolves its futures: a caller
                # whose serve() unblocks on the last future must see these
                # requests in the per-device stats (rolled back on failure,
                # mirroring the base counters never advancing)
                n_req = sum(len(groups[g]) for g in chunk)
                with self._counters_lock:
                    self.device_dispatches[dev] += 1
                    self.device_requests[dev] += n_req
                try:
                    self._dispatch([(gid, groups[gid], plans[gid])
                                    for gid in chunk])
                except BaseException:
                    with self._counters_lock:
                        self.device_dispatches[dev] -= 1
                        self.device_requests[dev] -= n_req
                    raise
        dt = time.perf_counter() - t0
        with self._counters_lock:
            self.device_busy_s[dev] += dt
            self._note_window_locked(t0, dt)

    # --------------------------------------------------------------- sharded
    def _launch_sharded(self, fd: FleetDecision, gid: str,
                        groups: Dict[str, List[WorkItem]],
                        plans: Dict[str, PartitionPlan]) -> None:
        """Whole-mesh dispatch of ONE graph group (feature- or block-shard)."""
        t0 = time.perf_counter()
        grp = groups[gid]
        plan = plans[gid]
        feats = [jnp.asarray(it.payload[1], dtype=jnp.float32) for it in grp]
        x = feats[0] if len(feats) == 1 else jnp.concatenate(feats, axis=1)
        widths = [int(f.shape[1]) for f in feats]

        prep = self._shard_prepared(fd.strategy, plan)
        live_counts: Optional[np.ndarray] = None
        if fd.strategy == "feature":
            out = spmm_feature_sharded(plan.slabs, x, plan.n_rows, self.mesh,
                                       prepared=prep["args"])
        else:
            out, live_counts = spmm_block_sharded(
                plan.slabs, x, plan.n_rows, self.mesh,
                prepared=(prep["args"], prep["live"]))
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0

        now = time.perf_counter()
        # the mesh-sharded result cannot gather against the owner-committed
        # inv_perm (incompatible devices) — un-permute on host, re-upload
        # uncommitted so answers behave like every other engine output
        out = jnp.asarray(np.asarray(out)[prep["inv_np"]])
        # slice outside the lock (same rule as the base dispatch: concurrent
        # launches must not serialize compute on the counter lock)
        answers: List[Tuple[WorkItem, jax.Array]] = []
        col = 0
        wait_s = 0.0
        for item, w in zip(grp, widths):
            answers.append((item, out[:, col:col + w]))
            col += w
            wait_s += now - item.t_enqueue
        with self._counters_lock:
            self.requests_served += len(grp)
            self.rows_served += plan.n_rows * len(grp)
            self.values_served += plan.n_rows * sum(widths)
            self.total_request_latency_s += wait_s
            self.batches_dispatched += 1
            self.graphs_dispatched += 1
            self.total_serve_s += dt
            self.live_blocks += plan.num_blocks
            self.padded_blocks += plan.num_blocks
            # what actually executed inside shard_map is the jnp slab twin,
            # so the routed_* invariant (sums to batches_dispatched) holds
            self.backend_dispatches["blocked"] += 1
            self.sharded_dispatches[fd.strategy] += 1
            self.sharded_busy_s += dt
            self.last_fleet_decision = fd
            if live_counts is not None:
                self.last_block_counts = [int(c) for c in live_counts]
            self._note_window_locked(t0, dt)
        for item, result in answers:
            item.complete(result)

    def _shard_prepared(self, strategy: str, plan: PartitionPlan) -> Dict:
        """Memoized per-(plan, strategy) sharded-dispatch preparation."""
        key = (plan.key, strategy)
        with self._prep_lock:
            ent = self._shard_prep.get(key)
            if ent is not None:
                self._shard_prep.move_to_end(key)
                return ent
        if strategy == "feature":
            ent = {"args": prepare_feature_shards(plan.slabs), "live": None}
        else:
            args, live = prepare_block_shards(plan.slabs, plan.n_rows,
                                              self.n_devices)
            ent = {"args": args, "live": live}
        ent["inv_np"] = np.asarray(plan.inv_perm)
        with self._prep_lock:
            self._shard_prep[key] = ent
            while len(self._shard_prep) > self._shard_prep_cap:
                self._shard_prep.popitem(last=False)
        return ent

    def _note_window_locked(self, t0: float, dt: float) -> None:
        if self._t_first_launch is None:
            self._t_first_launch = t0
        self._t_last_done = max(self._t_last_done or 0.0, t0 + dt)

    # ------------------------------------------------------------------ stats
    def _stats_locked(self, s: Dict[str, float]) -> Dict[str, float]:
        """Extends the base under-lock snapshot, so base and fleet counters
        come from the SAME instant (one atomic snapshot, one lock hold)."""
        s = super()._stats_locked(s)
        wall = ((self._t_last_done - self._t_first_launch)
                if self._t_first_launch is not None
                and self._t_last_done is not None else 0.0)
        counts = self.last_block_counts
        s.update(
            fleet_devices=self.n_devices,
            fleet_rounds=self.fleet_rounds,
            # scheduler-level coalescing per synchronized launch wave — the
            # fleet analogue of the single engine's graphs_per_dispatch
            # (device launches in one round run concurrently, not back to
            # back)
            fleet_graphs_per_round=(self.graphs_dispatched
                                    / self.fleet_rounds
                                    if self.fleet_rounds else 0.0),
            fleet_device_dispatches=list(self.device_dispatches),
            fleet_device_requests=list(self.device_requests),
            fleet_device_busy_s=list(self.device_busy_s),
            fleet_sharded_busy_s=self.sharded_busy_s,
            fleet_wall_s=wall,
            # mean busy fraction across devices over the serving window,
            # from the per-device clocks only (per-device launches never
            # overlap on one device, so this stays <= 1; whole-mesh sharded
            # launches are reported separately as fleet_sharded_busy_s)
            fleet_occupancy=(sum(self.device_busy_s)
                             / (wall * self.n_devices)
                             if wall > 0 else 0.0),
            fleet_feature_sharded=self.sharded_dispatches["feature"],
            fleet_block_sharded=self.sharded_dispatches["block"],
            fleet_block_counts=list(counts) if counts else [],
            # balance of the last block-sharded dispatch: max/mean live
            # blocks per device (1.0 == perfectly balanced)
            fleet_block_balance=(max(counts) * len(counts) / sum(counts)
                                 if counts and sum(counts) else 0.0),
        )
        return s
