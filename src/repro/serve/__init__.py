from .engine import ServeEngine  # noqa: F401
from .graph_engine import GraphRequest, GraphServeEngine  # noqa: F401
