from .engine import Request, ServeEngine  # noqa: F401
from .fleet import FleetGraphEngine, MultihostGraphEngine  # noqa: F401
from .graph_engine import GraphRequest, GraphServeEngine  # noqa: F401
from .scheduler import BatchScheduler, ClassSpec, QueueFullError, WorkItem  # noqa: F401
