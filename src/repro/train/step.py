"""jit-able train / serve steps for every architecture.

``make_train_step(cfg)`` returns a pure function
    (state, batch) -> (state, metrics)
suitable for ``jax.jit`` with in/out shardings from ``sharding.param_specs``.
Microbatching (gradient accumulation) is a scan over microbatches with
bf16-compressed gradient accumulation (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import lm
from ..optim.adamw import AdamWState, adamw_init, adamw_update, cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(cfg: ArchConfig, key) -> TrainState:
    params = lm.init_lm(cfg, key)
    return TrainState(params, adamw_init(params))


def make_train_step(cfg: ArchConfig, *, peak_lr=3e-4, warmup=100, total=10_000,
                    microbatch: Optional[int] = None, loss_chunk=512,
                    q_chunk=512, kv_chunk=512, ssd_chunk=128):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"inputs": [B, T] or [B, T, D], "labels": [B, T]}.
    """

    def loss_fn(params, inputs, labels):
        loss, metrics = lm.lm_loss(cfg, params, inputs, labels, remat=True,
                                   loss_chunk=loss_chunk, q_chunk=q_chunk,
                                   kv_chunk=kv_chunk, ssd_chunk=ssd_chunk)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        inputs, labels = batch["inputs"], batch["labels"]
        if microbatch and microbatch < inputs.shape[0]:
            nmb = inputs.shape[0] // microbatch
            mb_in = inputs.reshape((nmb, microbatch) + inputs.shape[1:])
            mb_lb = labels.reshape((nmb, microbatch) + labels.shape[1:])

            def mb_body(acc, mb):
                (l, m), g = grad_fn(state.params, mb[0], mb[1])
                # bf16 accumulation halves the carried payload (compression)
                g16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g16), acc_l + l), m

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), state.params)
            (gsum, lsum), ms = jax.lax.scan(mb_body, (zero, jnp.zeros(())),
                                            (mb_in, mb_lb))
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / nmb, gsum)
            loss = lsum / nmb
            metrics = jax.tree.map(lambda x: x[-1], ms)
        else:
            (loss, metrics), grads = grad_fn(state.params, inputs, labels)

        # +1: the schedule is evaluated for the step being TAKEN (lr(0)=0
        # would silently no-op the first optimizer step)
        lr = cosine_schedule(state.opt.step + 1, peak_lr=peak_lr, warmup=warmup,
                             total=total)
        new_params, new_opt, om = adamw_update(grads, state.opt, state.params, lr=lr)
        metrics = dict(metrics, loss=loss, lr=lr, **om)
        return TrainState(new_params, new_opt), metrics

    return train_step


def make_serve_step(cfg: ArchConfig):
    """Returns serve_step(params, state, tokens) -> (next_tokens, logits, state).

    Greedy decode of one token for the whole batch.
    """

    def serve_step(params, state: lm.DecodeState, tokens):
        logits, state = lm.decode_step(cfg, params, tokens, state)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, state

    return serve_step
