"""Fault-tolerant training loop: checkpoint/restart, stateless data seeding,
straggler accounting, elastic-mesh restarts.

Fault-tolerance contract (DESIGN.md §6):
* the loop is *restartable at any step*: data batches are derived from
  (seed, step) alone, so a restart replays bit-identical inputs;
* checkpoints are atomic (see checkpoint.manager) and saved every
  ``ckpt_every`` steps plus on (simulated or real) failure signals;
* per-step wall-times are recorded; steps slower than
  ``straggler_factor x median`` are counted and surfaced in metrics — on a
  real fleet this feeds the backup-instance policy, here it exercises the
  accounting path;
* ``crash_at`` (test hook) raises mid-run to exercise restart-resume.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager


def train_loop(
    *,
    state,
    train_step: Callable,
    batch_fn: Callable,          # (step:int) -> batch pytree  (stateless!)
    n_steps: int,
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    straggler_factor: float = 3.0,
    crash_at: Optional[int] = None,
    log_fn: Callable[[str], None] = print,
) -> Dict:
    """Runs (or resumes) training; returns {'state', 'history', 'stragglers'}."""
    start = 0
    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, state)
            start = latest
            log_fn(f"[loop] resumed from checkpoint step {latest}")

    history = []
    times = []
    stragglers = 0
    for step in range(start, n_steps):
        if crash_at is not None and step == crash_at:
            raise RuntimeError(f"simulated failure at step {step}")
        t0 = time.perf_counter()
        batch = batch_fn(step)
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        med = float(np.median(times[-50:]))
        if len(times) > 5 and dt > straggler_factor * med:
            stragglers += 1
            log_fn(f"[loop] straggler step {step}: {dt:.3f}s vs median {med:.3f}s")
        history.append({k: float(v) for k, v in metrics.items()})
        if step % log_every == 0:
            log_fn(f"[loop] step {step}: " +
                   " ".join(f"{k}={float(v):.4g}" for k, v in metrics.items()))
        if ckpt is not None and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.save(n_steps, state)
    return {"state": state, "history": history, "stragglers": stragglers}
