from .step import make_train_step, make_serve_step, TrainState  # noqa: F401
from .loop import train_loop  # noqa: F401
