"""Attention: GQA with RoPE, sliding windows, logit soft-caps, KV caches.

Three compute paths, all numerically equivalent where they overlap:

* ``attention_forward``  — chunked online-softmax (flash-style) over KV
  blocks; never materializes a [T, T] score matrix. Used for training and
  prefill. Causality/windowing by masking.
* ``banded_attention``   — sliding-window layers only: gathers a static
  (window + q_chunk) KV band per query chunk, so compute is truly
  sub-quadratic (used by gemma-2 local layers at long sequence).
* ``attention_decode``   — single-token step against a static-size KV cache.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import PARAM_DTYPE, apply_rope, dense_init, rope_table

NEG_INF = -2.3819763e38  # large negative, safe in fp32


# Roofline probes unroll these chunk scans (see models/lm.py SCAN_UNROLL).
SCAN_UNROLL = False

# §Perf hillclimb lever: keep attention operands in bf16 and accumulate in
# fp32 via preferred_element_type (MXU-native) instead of materializing fp32
# copies of Q/K/V and the KV cache. Halves attention HBM traffic; numerics
# validated in tests/test_attention.py (bf16 tolerance).
BF16_EINSUMS = False


def _score_dot(q, k, spec_q, spec_k, out_spec):
    """einsum with fp32 accumulation; operands stay bf16 when BF16_EINSUMS."""
    if BF16_EINSUMS:
        return jnp.einsum(f"{spec_q},{spec_k}->{out_spec}", q, k,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(f"{spec_q},{spec_k}->{out_spec}",
                      q.astype(jnp.float32), k.astype(jnp.float32))


def _scan(f, init, xs):
    if SCAN_UNROLL:
        n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return jax.lax.scan(f, init, xs, unroll=max(int(n), 1))
    return jax.lax.scan(f, init, xs)


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
                   qkv_bias: bool = False, dtype=PARAM_DTYPE):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * d_head, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * d_head, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * d_head,), dtype)
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, d_head, rope_cos=None, rope_sin=None):
    B, T, _ = x.shape
    q = jnp.dot(x, p["wq"])
    k = jnp.dot(x, p["wk"])
    v = jnp.dot(x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    from ..sharding import shard_heads  # no-op without a mesh ctx
    q = shard_heads(q.reshape(B, T, n_heads, d_head))
    k = shard_heads(k.reshape(B, T, n_kv_heads, d_head))
    v = shard_heads(v.reshape(B, T, n_kv_heads, d_head))
    if rope_cos is not None:
        q = apply_rope(q, rope_cos, rope_sin)
        k = apply_rope(k, rope_cos, rope_sin)
    return q, k, v


def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= qpos[:, None] - kpos[None, :] < window
    return m


def chunked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      q_chunk=512, kv_chunk=512, scale=None):
    """Online-softmax attention. q: [B,Tq,H,D], k/v: [B,Tk,KH,D] -> [B,Tq,H,D]."""
    B, Tq, H, D = q.shape
    Tk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qc = min(q_chunk, Tq)
    kc = min(kv_chunk, Tk)
    assert Tq % qc == 0 and Tk % kc == 0, (Tq, qc, Tk, kc)
    nq, nk = Tq // qc, Tk // kc

    cdt = jnp.bfloat16 if BF16_EINSUMS else jnp.float32
    qr = (q.astype(jnp.float32) * scale).astype(cdt).reshape(B, nq, qc, KH, G, D)
    kr = k.astype(cdt).reshape(B, nk, kc, KH, D)
    vr = v.astype(cdt).reshape(B, nk, kc, KH, D)

    def q_step(_, qi_and_chunk):
        qi, qch = qi_and_chunk  # qch: [B, qc, KH, G, D]
        qpos = qi * qc + jnp.arange(qc)

        def kv_step(carry, ki_and_kv):
            m_run, l_run, acc = carry
            ki, kch, vch = ki_and_kv
            kpos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qch, kch,
                           preferred_element_type=jnp.float32)
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            msk = _mask(qpos, kpos, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(cdt), vch,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KH, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KH, G, qc, D), jnp.float32)
        (m, l, acc), _ = _scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out  # [B, KH, G, qc, D]

    _, outs = _scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    # outs: [nq, B, KH, G, qc, D] -> [B, Tq, H, D]
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(B, Tq, H, D)
    return out.astype(q.dtype)


def banded_attention(q, k, v, *, window: int, softcap=None, q_chunk=512, scale=None):
    """Sliding-window causal attention with true sub-quadratic compute.

    Per query chunk of qc tokens, only the [window + qc]-wide KV band is
    gathered (static shape), so FLOPs are O(T * (window + qc)) not O(T^2).
    """
    B, T, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = scale if scale is not None else D ** -0.5
    qc = min(q_chunk, T)
    assert T % qc == 0
    nq = T // qc
    W = window
    cdt = jnp.bfloat16 if BF16_EINSUMS else jnp.float32
    # left-pad KV by W so every band slice starts at qi*qc
    kp = jnp.pad(k.astype(cdt), ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v.astype(cdt), ((0, 0), (W, 0), (0, 0), (0, 0)))
    qr = (q.astype(jnp.float32) * scale).astype(cdt).reshape(B, nq, qc, KH, G, D)

    def q_step(_, args):
        qi, qch = args
        start = qi * qc
        kband = jax.lax.dynamic_slice_in_dim(kp, start, W + qc, axis=1)
        vband = jax.lax.dynamic_slice_in_dim(vp, start, W + qc, axis=1)
        qpos = start + jnp.arange(qc)
        kpos = start - W + jnp.arange(W + qc)  # true positions (<0 = pad)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qch, kband,
                       preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        msk = (qpos[:, None] >= kpos[None, :]) & (qpos[:, None] - kpos[None, :] < W) \
            & (kpos[None, :] >= 0)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(cdt), vband,
                         preferred_element_type=jnp.float32)
        return None, out

    _, outs = _scan(q_step, None, (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(B, T, H, D)
    return out.astype(q.dtype)


class KVCache(NamedTuple):
    k: jax.Array      # [B, S, KH, D]
    v: jax.Array      # [B, S, KH, D]

    @staticmethod
    def create(batch, max_seq, n_kv_heads, d_head, dtype=PARAM_DTYPE):
        shape = (batch, max_seq, n_kv_heads, d_head)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode(p, x, cache: KVCache, pos: jax.Array, *, n_heads, n_kv_heads,
                     d_head, rope_theta=None, softcap=None, window=None, scale=None,
                     start=None):
    """One-token decode. x: [B, 1, D_model]; pos: scalar current length.

    ``start`` (optional int32[B]) is the per-slot sequence start: cache
    positions below ``start[b]`` are masked out for batch slot ``b``. This
    is what makes decode-slot reuse sound — a slot admitted mid-stream at
    position p sets start=p and never attends to the previous occupant's
    stale keys. Rope scores depend only on position differences, so a
    sequence started at p matches one started at 0 (up to low-precision
    cache rounding: bf16 quantizes differently-rotated keys differently,
    ~1% on logits — greedy samples can occasionally differ, exactly like
    any continuous-batching server vs an offline run).

    Returns (out [B,1,D_model], new_cache).
    """
    B = x.shape[0]
    S = cache.k.shape[1]
    if rope_theta is not None:
        cos, sin = rope_table(jnp.full((1,), pos), d_head, rope_theta)
    else:
        cos = sin = None
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, d_head, cos, sin)
    newk = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), pos, axis=1)
    newv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), pos, axis=1)
    G = n_heads // n_kv_heads
    scale = scale if scale is not None else d_head ** -0.5
    cdt = jnp.bfloat16 if BF16_EINSUMS else jnp.float32
    # BF16_EINSUMS reads the cache in its storage dtype with fp32 accumulation
    # (no fp32 copy of the whole cache — the §Perf decode-memory fix).
    kc_ = newk if BF16_EINSUMS else newk.astype(jnp.float32)
    vc_ = newv if BF16_EINSUMS else newv.astype(jnp.float32)
    qh = (q.astype(jnp.float32) * scale).astype(kc_.dtype).reshape(
        B, n_kv_heads, G, d_head)
    s = jnp.einsum("bhgd,bshd->bhgs", qh, kc_,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(S)
    valid = kpos <= pos
    if window is not None:
        valid &= kpos > pos - window
    if start is None:
        s = jnp.where(valid[None, None, None], s, NEG_INF)
    else:  # per-slot mask [B, S]: drop positions before each slot's start
        valid_b = valid[None, :] & (kpos[None, :] >= start[:, None])
        s = jnp.where(valid_b[:, None, None, :], s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", pattn.astype(vc_.dtype), vc_,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    return jnp.dot(out, p["wo"]), KVCache(newk, newv)


def attention_forward(p, x, *, n_heads, n_kv_heads, d_head, causal=True,
                      rope_theta: Optional[float] = 10_000.0, window=None,
                      softcap=None, q_chunk=512, kv_chunk=512, scale=None,
                      use_banded=False, return_kv=False):
    """Full-sequence attention (training / prefill). x: [B, T, D_model]."""
    B, T, _ = x.shape
    if rope_theta is not None:
        cos, sin = rope_table(jnp.arange(T), d_head, rope_theta)
    else:
        cos = sin = None
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, d_head, cos, sin)
    if use_banded and window is not None and T > window:
        out = banded_attention(q, k, v, window=window, softcap=softcap,
                               q_chunk=q_chunk, scale=scale)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                softcap=softcap, q_chunk=q_chunk,
                                kv_chunk=kv_chunk, scale=scale)
    out = jnp.dot(out.reshape(B, T, n_heads * d_head), p["wo"])
    if return_kv:
        # cache dtype follows the activation dtype (bf16 in production)
        return out, KVCache(k.astype(x.dtype), v.astype(x.dtype))
    return out
