"""LM assembly for all assigned architectures.

One ``init_lm`` / ``forward_trunk`` / ``lm_loss`` / ``decode_step`` API covers
five families (dense, moe, ssm, hybrid, encoder). Layers are stacked and
iterated with ``lax.scan`` (compile time O(1) in depth); gemma-2's
local/global alternation scans *pairs*, zamba-2 scans (mamba x g + shared
attn + LoRA) groups. Training wraps scan bodies in ``jax.checkpoint``.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding import shard
from . import attention as A
from . import moe as M
from . import ssm as S
from .layers import (PARAM_DTYPE, dense_init, embed_init, init_mlp, apply_mlp,
                     layer_norm, rms_norm)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def _init_norm(cfg: ArchConfig, dtype=PARAM_DTYPE):
    if cfg.norm == "layer":
        return {"w": jnp.ones((cfg.d_model,), dtype), "b": jnp.zeros((cfg.d_model,), dtype)}
    return {"w": jnp.zeros((cfg.d_model,), dtype)}


def _norm(cfg: ArchConfig, p, x):
    if cfg.norm == "layer":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------
def _init_attn_layer(cfg: ArchConfig, key):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": _init_norm(cfg),
        "attn": A.init_attention(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.d_head, qkv_bias=cfg.qkv_bias),
        "ln2": _init_norm(cfg),
    }
    if cfg.post_block_norm:
        p["ln1_post"] = _init_norm(cfg)
        p["ln2_post"] = _init_norm(cfg)
    return p, ks[3]


def _init_dense_layer(cfg: ArchConfig, key, d_ff=None):
    p, k = _init_attn_layer(cfg, key)
    p["mlp"] = init_mlp(k, cfg.d_model, d_ff or cfg.d_ff, gated=cfg.mlp_gated)
    return p


def _init_moe_layer(cfg: ArchConfig, key):
    p, k = _init_attn_layer(cfg, key)
    p["moe"] = M.init_moe(k, cfg.d_model, cfg.d_ff, cfg.n_experts,
                          n_shared=cfg.n_shared_experts)
    return p


def _init_mamba_layer(cfg: ArchConfig, key):
    d_inner = cfg.ssm_expand * cfg.d_model
    return {
        "ln1": _init_norm(cfg),
        "mamba": S.init_mamba2(key, cfg.d_model, d_inner, cfg.ssm_head_dim,
                               cfg.ssm_state, cfg.ssm_conv_k),
    }


def _stack(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def _hybrid_counts(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(n_groups, mamba_per_group, tail) with n_layers mamba layers total."""
    g = cfg.hybrid_group
    n_groups = cfg.n_layers // g
    tail = cfg.n_layers - n_groups * g
    return n_groups, g, tail


# Layer-scan indirection: the roofline probes (launch/dryrun.py) set
# ``SCAN_UNROLL=True`` so XLA's cost analysis (which counts while-loop bodies
# once) sees every layer's FLOPs/bytes/collectives. Production keeps rolled
# scans for O(1)-in-depth compile times.
SCAN_UNROLL = False


def _scan(f, init, xs):
    if SCAN_UNROLL:
        n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        return jax.lax.scan(f, init, xs, unroll=max(int(n), 1))
    return jax.lax.scan(f, init, xs)


def init_lm(cfg: ArchConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {"final_norm": _init_norm(cfg)}

    if cfg.frontend == "token":
        params["embed"] = embed_init(ks[0], cfg.vocab, cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab)

    fam = cfg.family
    if fam in ("dense", "encoder"):
        if cfg.local_global_period == 2:
            assert cfg.n_layers % 2 == 0
            params["layers"] = _stack(
                lambda k: _stack(lambda k2: _init_dense_layer(cfg, k2), k, 2),
                ks[2], cfg.n_layers // 2)
        else:
            params["layers"] = _stack(lambda k: _init_dense_layer(cfg, k),
                                      ks[2], cfg.n_layers)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            params["dense_layers"] = _stack(
                lambda k: _init_dense_layer(cfg, k, d_ff=cfg.first_dense_ff), ks[3], nd)
        params["layers"] = _stack(lambda k: _init_moe_layer(cfg, k),
                                  ks[2], cfg.n_layers - nd)
    elif fam == "ssm":
        params["layers"] = _stack(lambda k: _init_mamba_layer(cfg, k),
                                  ks[2], cfg.n_layers)
    elif fam == "hybrid":
        n_groups, g, tail = _hybrid_counts(cfg)
        params["layers"] = _stack(
            lambda k: _stack(lambda k2: _init_mamba_layer(cfg, k2), k, g),
            ks[2], n_groups)
        if tail:
            params["tail"] = _stack(lambda k: _init_mamba_layer(cfg, k), ks[4], tail)
        params["shared"] = _init_dense_layer(cfg, ks[5])
        r = cfg.lora_rank

        def lora_init(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            return {
                "a_q": dense_init(k1, cfg.d_model, r),
                "b_q": (jnp.zeros((r, cfg.attn_dim), PARAM_DTYPE)),
                "a_i": dense_init(k3, cfg.d_model, r),
                "b_i": (jnp.zeros((r, cfg.d_ff), PARAM_DTYPE)),
            }

        params["lora"] = _stack(lora_init, ks[6], n_groups)
    else:
        raise ValueError(fam)
    return params


def param_count(params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# blocks (forward)
# ---------------------------------------------------------------------------
def _attn_kwargs(cfg: ArchConfig, local: bool):
    return dict(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
        causal=cfg.causal, rope_theta=cfg.rope_theta,
        window=cfg.sliding_window if local else None,
        softcap=cfg.attn_softcap, scale=cfg.attn_scale,
        use_banded=local,
    )


def _dense_block(cfg: ArchConfig, p, h, *, local=False, q_chunk=512, kv_chunk=512,
                 moe=False, dense_mlp_key="mlp"):
    a_in = _norm(cfg, p["ln1"], h)
    attn_out = A.attention_forward(p["attn"], a_in, q_chunk=q_chunk,
                                   kv_chunk=kv_chunk, **_attn_kwargs(cfg, local))
    if cfg.post_block_norm:
        attn_out = _norm(cfg, p["ln1_post"], attn_out)
    h = h + attn_out
    m_in = _norm(cfg, p["ln2"], h)
    aux = jnp.zeros((), jnp.float32)
    if moe:
        mlp_out, aux = M.moe_capacity(p["moe"], m_in, top_k=cfg.top_k,
                                      n_experts=cfg.n_experts,
                                      capacity_factor=cfg.moe_capacity_factor,
                                      act=cfg.act)
    else:
        mlp_out = apply_mlp(p[dense_mlp_key], m_in, act=cfg.act, gated=cfg.mlp_gated)
    if cfg.post_block_norm:
        mlp_out = _norm(cfg, p["ln2_post"], mlp_out)
    return h + mlp_out, aux


def _mamba_block(cfg: ArchConfig, p, h, chunk=128):
    m_in = _norm(cfg, p["ln1"], h)
    out = S.mamba2_forward(p["mamba"], m_in, head_dim=cfg.ssm_head_dim,
                           state=cfg.ssm_state, chunk=chunk)
    return h + out


def _shared_block(cfg: ArchConfig, shared, lora, h, q_chunk=512, kv_chunk=512):
    """zamba2 shared attn+mlp block with per-site LoRA on wq / wi."""
    p = dict(shared)
    attn = dict(p["attn"])
    attn["wq"] = attn["wq"] + (lora["a_q"].astype(jnp.float32)
                               @ lora["b_q"].astype(jnp.float32)).astype(attn["wq"].dtype)
    mlp = dict(p["mlp"])
    mlp["wi"] = mlp["wi"] + (lora["a_i"].astype(jnp.float32)
                             @ lora["b_i"].astype(jnp.float32)).astype(mlp["wi"].dtype)
    p2 = {**p, "attn": attn, "mlp": mlp}
    h, _ = _dense_block(cfg, p2, h, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return h


# ---------------------------------------------------------------------------
# trunk
# ---------------------------------------------------------------------------
def _sinusoid(T: int, D: int) -> jax.Array:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    i = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * i / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_inputs(cfg: ArchConfig, params, inputs) -> jax.Array:
    """tokens [B,T] int32 (token frontend) or embeddings [B,T,D] (stub)."""
    if cfg.frontend == "token":
        h = params["embed"][inputs]
        if cfg.name.startswith("gemma"):
            h = (h.astype(jnp.float32) * (cfg.d_model ** 0.5)).astype(h.dtype)
    else:
        h = inputs
        if cfg.family == "encoder":  # stub frontend: add sinusoidal positions
            h = h + _sinusoid(h.shape[1], cfg.d_model).astype(h.dtype)[None]
    return shard(h, "batch", None, None)


def forward_trunk(cfg: ArchConfig, params, h, *, remat=True, q_chunk=512,
                  kv_chunk=512, ssd_chunk=128):
    """[B, T, D] -> ([B, T, D], aux_loss)."""
    aux0 = jnp.zeros((), jnp.float32)
    fam = cfg.family

    def maybe_ckpt(f):
        return jax.checkpoint(f) if remat else f

    if fam in ("dense", "encoder"):
        if cfg.local_global_period == 2:
            def body(carry, lp):
                hh, aux = carry
                hh, _ = _dense_block(cfg, jax.tree.map(lambda x: x[0], lp), hh,
                                     local=True, q_chunk=q_chunk, kv_chunk=kv_chunk)
                hh, _ = _dense_block(cfg, jax.tree.map(lambda x: x[1], lp), hh,
                                     local=False, q_chunk=q_chunk, kv_chunk=kv_chunk)
                return (hh, aux), None
        else:
            def body(carry, lp):
                hh, aux = carry
                hh, _ = _dense_block(cfg, lp, hh, q_chunk=q_chunk, kv_chunk=kv_chunk)
                return (hh, aux), None
        (h, aux0), _ = _scan(maybe_ckpt(body), (h, aux0), params["layers"])

    elif fam == "moe":
        if "dense_layers" in params:
            def dbody(carry, lp):
                hh, aux = carry
                hh, _ = _dense_block(cfg, lp, hh, q_chunk=q_chunk, kv_chunk=kv_chunk)
                return (hh, aux), None
            (h, aux0), _ = _scan(maybe_ckpt(dbody), (h, aux0),
                                        params["dense_layers"])

        def body(carry, lp):
            hh, aux = carry
            hh, a = _dense_block(cfg, lp, hh, moe=True, q_chunk=q_chunk,
                                 kv_chunk=kv_chunk)
            return (hh, aux + a), None
        (h, aux0), _ = _scan(maybe_ckpt(body), (h, aux0), params["layers"])

    elif fam == "ssm":
        def body(carry, lp):
            hh, aux = carry
            return (_mamba_block(cfg, lp, hh, chunk=ssd_chunk), aux), None
        (h, aux0), _ = _scan(maybe_ckpt(body), (h, aux0), params["layers"])

    elif fam == "hybrid":
        shared, loras = params["shared"], params["lora"]

        def gbody(carry, args):
            hh, aux = carry
            group_p, lora = args

            def mbody(c, lp):
                return _mamba_block(cfg, lp, c, chunk=ssd_chunk), None
            hh, _ = _scan(mbody, hh, group_p)
            hh = _shared_block(cfg, shared, lora, hh, q_chunk, kv_chunk)
            return (hh, aux), None

        (h, aux0), _ = _scan(maybe_ckpt(gbody), (h, aux0),
                                    (params["layers"], loras))
        if "tail" in params:
            def tbody(carry, lp):
                hh, aux = carry
                return (_mamba_block(cfg, lp, hh, chunk=ssd_chunk), aux), None
            (h, aux0), _ = _scan(maybe_ckpt(tbody), (h, aux0), params["tail"])
    else:
        raise ValueError(fam)

    return _norm(cfg, params["final_norm"], h), aux0


def _head_weights(cfg: ArchConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def lm_logits(cfg: ArchConfig, params, h) -> jax.Array:
    logits = jnp.dot(h, _head_weights(cfg, params)).astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return shard(logits, *(["batch"] + [None] * (logits.ndim - 2) + ["model"]))


def lm_forward(cfg: ArchConfig, params, inputs, *, remat=False, **kw) -> jax.Array:
    """Full logits [B, T, V] — tests / small models only."""
    h = embed_inputs(cfg, params, inputs)
    h, _ = forward_trunk(cfg, params, h, remat=remat, **kw)
    return lm_logits(cfg, params, h)


def lm_loss(cfg: ArchConfig, params, inputs, labels, *, remat=True,
            loss_chunk=512, aux_weight=0.01, **kw):
    """Next-token CE, seq-chunked so [B, Tc, V] logits never exceed a chunk.

    labels: int32 [B, T], -1 = masked.
    """
    h = embed_inputs(cfg, params, inputs)
    h, aux = forward_trunk(cfg, params, h, remat=remat, **kw)
    B, T, D = h.shape
    W = _head_weights(cfg, params)
    c = min(loss_chunk, T)
    assert T % c == 0
    nc = T // c

    def chunk_body(carry, args):
        tot, cnt = carry
        hc, yc = args  # [B, c, D], [B, c]
        logits = jnp.dot(hc, W).astype(jnp.float32)
        if cfg.final_softcap:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        logits = shard(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        valid = (yc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - gold) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    hc = jnp.moveaxis(h.reshape(B, nc, c, D), 1, 0)
    yc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)
    (tot, cnt), _ = _scan(chunk_body, (jnp.zeros(()), jnp.zeros(())), (hc, yc))
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# prefill (serving): trunk + cache collection + last-token logits
# ---------------------------------------------------------------------------
def _dense_block_kv(cfg, p, h, *, local=False, q_chunk=512, kv_chunk=512, moe=False):
    a_in = _norm(cfg, p["ln1"], h)
    attn_out, kv = A.attention_forward(
        p["attn"], a_in, q_chunk=q_chunk, kv_chunk=kv_chunk, return_kv=True,
        **_attn_kwargs(cfg, local))
    if cfg.post_block_norm:
        attn_out = _norm(cfg, p["ln1_post"], attn_out)
    h = h + attn_out
    m_in = _norm(cfg, p["ln2"], h)
    if moe:
        mlp_out, _ = M.moe_capacity(p["moe"], m_in, top_k=cfg.top_k,
                                    n_experts=cfg.n_experts,
                                    capacity_factor=cfg.moe_capacity_factor, act=cfg.act)
    else:
        mlp_out = apply_mlp(p["mlp"], m_in, act=cfg.act, gated=cfg.mlp_gated)
    if cfg.post_block_norm:
        mlp_out = _norm(cfg, p["ln2_post"], mlp_out)
    return h + mlp_out, kv


def prefill_forward(cfg: ArchConfig, params, inputs, *, q_chunk=512,
                    kv_chunk=512, ssd_chunk=128):
    """Serving prefill: returns (last-token logits [B, V], DecodeState).

    Encoder family returns (frame logits [B, T, V], None).
    """
    h = embed_inputs(cfg, params, inputs)
    B, T = h.shape[0], h.shape[1]
    fam = cfg.family

    if fam == "encoder":
        hh, _ = forward_trunk(cfg, params, h, remat=False, q_chunk=q_chunk,
                              kv_chunk=kv_chunk)
        return lm_logits(cfg, params, hh), None

    caches: Dict[str, Any] = {}
    if fam in ("dense", "moe"):
        if fam == "moe" and "dense_layers" in params:
            def dbody(hh, lp):
                hh, kv = _dense_block_kv(cfg, lp, hh, q_chunk=q_chunk,
                                         kv_chunk=kv_chunk)
                return hh, kv
            h, kvd = _scan(dbody, h, params["dense_layers"])
            caches["kv_dense"] = kvd
        if cfg.local_global_period == 2:
            def body(hh, lp):
                hh, kv0 = _dense_block_kv(cfg, jax.tree.map(lambda x: x[0], lp), hh,
                                          local=True, q_chunk=q_chunk, kv_chunk=kv_chunk)
                hh, kv1 = _dense_block_kv(cfg, jax.tree.map(lambda x: x[1], lp), hh,
                                          local=False, q_chunk=q_chunk, kv_chunk=kv_chunk)
                return hh, jax.tree.map(lambda a, b: jnp.stack([a, b]), kv0, kv1)
        else:
            def body(hh, lp):
                return _dense_block_kv(cfg, lp, hh, moe=(fam == "moe"),
                                       q_chunk=q_chunk, kv_chunk=kv_chunk)
        h, kvs = _scan(body, h, params["layers"])
        caches["kv"] = kvs
    elif fam == "ssm":
        def body(hh, lp):
            m_in = _norm(cfg, lp["ln1"], hh)
            out, mc = S.mamba2_forward(lp["mamba"], m_in, head_dim=cfg.ssm_head_dim,
                                       state=cfg.ssm_state, chunk=ssd_chunk,
                                       return_state=True)
            return hh + out, mc
        h, mcs = _scan(body, h, params["layers"])
        caches["mamba"] = mcs
    elif fam == "hybrid":
        shared, loras = params["shared"], params["lora"]

        def gbody(hh, args):
            gp, lora = args

            def mbody(c, lp):
                m_in = _norm(cfg, lp["ln1"], c)
                out, mc = S.mamba2_forward(lp["mamba"], m_in,
                                           head_dim=cfg.ssm_head_dim,
                                           state=cfg.ssm_state, chunk=ssd_chunk,
                                           return_state=True)
                return c + out, mc
            hh, mc = _scan(mbody, hh, gp)
            p = dict(shared)
            attn = dict(p["attn"])
            attn["wq"] = attn["wq"] + (lora["a_q"].astype(jnp.float32)
                                       @ lora["b_q"].astype(jnp.float32)
                                       ).astype(attn["wq"].dtype)
            mlp = dict(p["mlp"])
            mlp["wi"] = mlp["wi"] + (lora["a_i"].astype(jnp.float32)
                                     @ lora["b_i"].astype(jnp.float32)
                                     ).astype(mlp["wi"].dtype)
            p2 = {**p, "attn": attn, "mlp": mlp}
            hh, kv = _dense_block_kv(cfg, p2, hh, q_chunk=q_chunk, kv_chunk=kv_chunk)
            return hh, (mc, kv)

        h, (mcs, kvs) = _scan(gbody, h, (params["layers"], loras))
        caches["mamba"], caches["kv"] = mcs, kvs
        if "tail" in params:
            def tbody(hh, lp):
                m_in = _norm(cfg, lp["ln1"], hh)
                out, mc = S.mamba2_forward(lp["mamba"], m_in,
                                           head_dim=cfg.ssm_head_dim,
                                           state=cfg.ssm_state, chunk=ssd_chunk,
                                           return_state=True)
                return hh + out, mc
            h, mct = _scan(tbody, h, params["tail"])
            caches["mamba_tail"] = mct
    else:
        raise ValueError(fam)

    h_last = _norm(cfg, params["final_norm"], h[:, -1:, :])
    logits = lm_logits(cfg, params, h_last)[:, 0]
    return logits, DecodeState(caches, jnp.asarray(T, jnp.int32))


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    caches: Any        # family-specific pytree, layer-stacked
    pos: jax.Array     # scalar int32: tokens already in cache
    # per-slot sequence start (int32[B]); None = every slot started at 0.
    # A slot reused mid-stream (continuous batching) sets start[b] to the
    # admission position so attention never sees the previous occupant's
    # stale cache entries; see reset_decode_slot.
    start: Optional[jax.Array] = None


def pad_prefill_caches(cfg: ArchConfig, state: "DecodeState", max_seq: int
                       ) -> "DecodeState":
    """Grow prefill KV caches (length T) to the decode budget ``max_seq``."""
    caches = dict(state.caches)
    for key in ("kv", "kv_dense"):
        if key in caches:
            kv = caches[key]
            seq_axis = kv.k.ndim - 3  # [..., S, KH, Dh]
            pad = max_seq - kv.k.shape[seq_axis]
            cfgpad = [(0, 0)] * kv.k.ndim
            cfgpad[seq_axis] = (0, pad)
            caches[key] = A.KVCache(jnp.pad(kv.k, cfgpad), jnp.pad(kv.v, cfgpad))
    return DecodeState(caches, state.pos, state.start)


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int) -> DecodeState:
    fam = cfg.family
    if fam in ("dense", "moe"):
        nd = cfg.first_dense_layers if fam == "moe" else 0
        L = cfg.n_layers - nd if cfg.local_global_period != 2 else cfg.n_layers // 2
        inner = 2 if cfg.local_global_period == 2 else 1
        shape = (L,) + ((inner,) if inner == 2 else ()) + \
                (batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        kv = A.KVCache(jnp.zeros(shape, PARAM_DTYPE), jnp.zeros(shape, PARAM_DTYPE))
        nd = cfg.first_dense_layers
        caches: Any = {"kv": kv}
        if fam == "moe" and nd:
            dshape = (nd, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
            caches["kv_dense"] = A.KVCache(jnp.zeros(dshape, PARAM_DTYPE),
                                           jnp.zeros(dshape, PARAM_DTYPE))
        return DecodeState(caches, jnp.zeros((), jnp.int32))
    if fam == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        conv_dim = d_inner + 2 * cfg.ssm_state
        caches = {"mamba": S.MambaCache(
            jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv_k - 1, conv_dim), PARAM_DTYPE),
            jnp.zeros((cfg.n_layers, batch, H, cfg.ssm_state, cfg.ssm_head_dim),
                      jnp.float32))}
        return DecodeState(caches, jnp.zeros((), jnp.int32))
    if fam == "hybrid":
        n_groups, g, tail = _hybrid_counts(cfg)
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        conv_dim = d_inner + 2 * cfg.ssm_state

        def mcache(n):
            return S.MambaCache(
                jnp.zeros((n, batch, cfg.ssm_conv_k - 1, conv_dim), PARAM_DTYPE),
                jnp.zeros((n, batch, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32))
        kvshape = (n_groups, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        caches = {
            "mamba": jax.tree.map(lambda x: x.reshape((n_groups, g) + x.shape[1:]),
                                  mcache(n_groups * g)),
            "kv": A.KVCache(jnp.zeros(kvshape, PARAM_DTYPE),
                            jnp.zeros(kvshape, PARAM_DTYPE)),
        }
        if tail:
            caches["mamba_tail"] = mcache(tail)
        return DecodeState(caches, jnp.zeros((), jnp.int32))
    raise ValueError(f"{cfg.family} has no decode step")


def track_slot_starts(state: DecodeState, batch: int) -> DecodeState:
    """Enable per-slot sequence-start tracking on a decode state (required
    before :func:`reset_decode_slot`); all slots start at position 0."""
    if state.start is not None:
        return state
    return DecodeState(state.caches, state.pos,
                       jnp.zeros((batch,), jnp.int32))


def _zero_batch_slot(tree, batch_axis: int, slot: int):
    def z(a):
        idx = (slice(None),) * batch_axis + (slot,)
        return a.at[idx].set(jnp.zeros_like(a[idx]))
    return jax.tree.map(z, tree)


def reset_decode_slot(cfg: ArchConfig, state: DecodeState, slot: int
                      ) -> DecodeState:
    """Recycle batch slot ``slot`` for a NEW sequence starting at the
    current position (continuous-batching slot reuse).

    Attention caches need no rewrite: ``start[slot] = pos`` masks every
    stale cache position for that slot, and rope attention scores depend
    only on position differences, so a sequence admitted at position p is
    equivalent to one started at 0. Recurrent (mamba) state is genuinely
    stateful, so the slot's conv/ssm entries are zeroed — a zero state IS
    the fresh-sequence initial state.
    """
    if state.start is None:
        raise ValueError("state has no per-slot start tracking; wrap it "
                         "with track_slot_starts(state, batch) first")
    caches = dict(state.caches)
    if "mamba" in caches:
        # ssm: [n_layers, B, ...]; hybrid groups: [n_groups, g, B, ...]
        axis = 2 if cfg.family == "hybrid" else 1
        caches["mamba"] = _zero_batch_slot(caches["mamba"], axis, slot)
    if "mamba_tail" in caches:
        caches["mamba_tail"] = _zero_batch_slot(caches["mamba_tail"], 1, slot)
    return DecodeState(caches, state.pos,
                       state.start.at[slot].set(state.pos))


def _attn_decode_block(cfg, p, h, kv, pos, *, local=False, start=None):
    a_in = _norm(cfg, p["ln1"], h)
    attn_out, kv = A.attention_decode(
        p["attn"], a_in, kv, pos, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.d_head, rope_theta=cfg.rope_theta, softcap=cfg.attn_softcap,
        window=cfg.sliding_window if local else None, scale=cfg.attn_scale,
        start=start)
    if cfg.post_block_norm:
        attn_out = _norm(cfg, p["ln1_post"], attn_out)
    h = h + attn_out
    m_in = _norm(cfg, p["ln2"], h)
    if "moe" in p:
        mlp_out, _ = M.moe_capacity(p["moe"], m_in, top_k=cfg.top_k,
                                    n_experts=cfg.n_experts,
                                    capacity_factor=cfg.moe_capacity_factor, act=cfg.act)
    else:
        mlp_out = apply_mlp(p["mlp"], m_in, act=cfg.act, gated=cfg.mlp_gated)
    if cfg.post_block_norm:
        mlp_out = _norm(cfg, p["ln2_post"], mlp_out)
    return h + mlp_out, kv


def decode_step(cfg: ArchConfig, params, tokens: jax.Array, state: DecodeState
                ) -> Tuple[jax.Array, DecodeState]:
    """One-token step for the whole batch. tokens: [B, 1] -> logits [B, V]."""
    h = embed_inputs(cfg, params, tokens)
    pos = state.pos
    start = state.start
    caches = dict(state.caches)
    fam = cfg.family

    if fam in ("dense", "moe"):
        if fam == "moe" and "kv_dense" in caches:
            def dbody(hh, args):
                lp, kv = args
                hh, kv = _attn_decode_block(cfg, lp, hh, kv, pos, start=start)
                return hh, kv
            h, kvd = _scan(dbody, h, (params["dense_layers"], caches["kv_dense"]))
            caches["kv_dense"] = kvd

        if cfg.local_global_period == 2:
            def body(hh, args):
                lp, kv = args
                hh, kv0 = _attn_decode_block(cfg, jax.tree.map(lambda x: x[0], lp), hh,
                                             jax.tree.map(lambda x: x[0], kv), pos,
                                             local=True, start=start)
                hh, kv1 = _attn_decode_block(cfg, jax.tree.map(lambda x: x[1], lp), hh,
                                             jax.tree.map(lambda x: x[1], kv), pos,
                                             start=start)
                kv = jax.tree.map(lambda a, b: jnp.stack([a, b]), kv0, kv1)
                return hh, kv
        else:
            def body(hh, args):
                lp, kv = args
                return _attn_decode_block(cfg, lp, hh, kv, pos, start=start)
        h, kvs = _scan(body, h, (params["layers"], caches["kv"]))
        caches["kv"] = kvs

    elif fam == "ssm":
        def body(hh, args):
            lp, mc = args
            m_in = _norm(cfg, lp["ln1"], hh)
            out, mc = S.mamba2_decode(lp["mamba"], m_in, mc,
                                      head_dim=cfg.ssm_head_dim, state=cfg.ssm_state)
            return hh + out, mc
        h, mcs = _scan(body, h, (params["layers"], caches["mamba"]))
        caches["mamba"] = mcs

    elif fam == "hybrid":
        shared, loras = params["shared"], params["lora"]

        def gbody(hh, args):
            gp, lora, mc, kv = args

            def mbody(c, a):
                lp, mcl = a
                m_in = _norm(cfg, lp["ln1"], c)
                out, mcl = S.mamba2_decode(lp["mamba"], m_in, mcl,
                                           head_dim=cfg.ssm_head_dim,
                                           state=cfg.ssm_state)
                return c + out, mcl
            hh, mc = _scan(mbody, hh, (gp, mc))
            # shared attn block with LoRA (decode)
            p = dict(shared)
            attn = dict(p["attn"])
            attn["wq"] = attn["wq"] + (lora["a_q"].astype(jnp.float32)
                                       @ lora["b_q"].astype(jnp.float32)
                                       ).astype(attn["wq"].dtype)
            mlp = dict(p["mlp"])
            mlp["wi"] = mlp["wi"] + (lora["a_i"].astype(jnp.float32)
                                     @ lora["b_i"].astype(jnp.float32)
                                     ).astype(mlp["wi"].dtype)
            p2 = {**p, "attn": attn, "mlp": mlp}
            hh, kv = _attn_decode_block(cfg, p2, hh, kv, pos, start=start)
            return hh, (mc, kv)

        h, (mcs, kvs) = _scan(
            gbody, h, (params["layers"], loras, caches["mamba"], caches["kv"]))
        caches["mamba"], caches["kv"] = mcs, kvs
        if "mamba_tail" in caches:
            def tbody(hh, args):
                lp, mc = args
                m_in = _norm(cfg, lp["ln1"], hh)
                out, mc = S.mamba2_decode(lp["mamba"], m_in, mc,
                                          head_dim=cfg.ssm_head_dim,
                                          state=cfg.ssm_state)
                return hh + out, mc
            h, mct = _scan(tbody, h, (params["tail"], caches["mamba_tail"]))
            caches["mamba_tail"] = mct
    else:
        raise ValueError(fam)

    h = _norm(cfg, params["final_norm"], h)
    logits = lm_logits(cfg, params, h)[:, 0]
    return logits, DecodeState(caches, pos + 1, start)
