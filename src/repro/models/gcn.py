"""GCN / GraphSAGE / GIN on the Accel-GCN SpMM operator.

The paper's target workload: ``X^{l+1} = act(A' . (X^l W^l))`` — linear
transform then sparse feature aggregation (paper §II-A). The aggregation runs
through :class:`repro.core.spmm.AccelSpMM` (degree sorting + block-level
partition + combined-warp feature tiling).

Gradients: SpMM appears inside ``jax.grad`` via the COO/segment path of the
custom VJP (d/dX of A.X is A^T.X-bar, precomputed as a second AccelSpMM over
A^T), so training uses the paper's operator in both directions.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from ..core.graph import CSRGraph, csr_transpose
from ..core.plan_cache import PlanCache
from ..core.spmm import AccelSpMM, make_accel_spmm
from .layers import dense_init


@dataclasses.dataclass
class GraphOp:
    """A' with a custom VJP so backprop also uses the Accel-GCN kernel."""

    fwd: AccelSpMM
    bwd: AccelSpMM  # operator for A'^T

    @classmethod
    def build(cls, g_norm: CSRGraph, backend: str = "blocked",
              plan_cache: Optional[PlanCache] = None, **kw) -> "GraphOp":
        """With ``plan_cache``, both A' and A'^T plans are cached: rebuilding
        the op for a recurring graph does zero partitioning work."""
        return cls(
            fwd=make_accel_spmm(g_norm, backend=backend,
                                plan_cache=plan_cache, **kw),
            bwd=make_accel_spmm(csr_transpose(g_norm), backend=backend,
                                plan_cache=plan_cache, **kw))

    def __call__(self, x: jax.Array) -> jax.Array:
        op_f, op_b = self.fwd, self.bwd

        @jax.custom_vjp
        def _spmm(xx):
            return op_f(xx)

        def _fwd(xx):
            return op_f(xx), None

        def _bwd(_, g):
            return (op_b(g.astype(jnp.float32)).astype(g.dtype),)

        _spmm.defvjp(_fwd, _bwd)
        return _spmm(x)


def init_gcn(key, dims: List[int], variant: str = "gcn", dtype=jnp.float32):
    """dims = [in, hidden..., out]. Returns list of per-layer params."""
    layers = []
    ks = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        k1, k2, k3 = jax.random.split(ks[i], 3)
        p = {"w": dense_init(k1, a, b, dtype), "b": jnp.zeros((b,), dtype)}
        if variant == "sage":
            p["w_self"] = dense_init(k2, a, b, dtype)
        if variant == "gin":
            p["w2"] = dense_init(k2, b, b, dtype)
            p["eps"] = jnp.zeros((), dtype)
        layers.append(p)
    return layers


def gcn_forward(params, aggr: Callable, x: jax.Array, variant: str = "gcn",
                act=jax.nn.relu) -> jax.Array:
    """aggr: callable computing A'.X (a GraphOp). Returns node logits."""
    h = x
    n = len(params)
    for i, p in enumerate(params):
        if variant == "gcn":
            h = aggr(jnp.dot(h, p["w"])) + p["b"]
        elif variant == "sage":
            h = jnp.dot(aggr(h), p["w"]) + jnp.dot(h, p["w_self"]) + p["b"]
        elif variant == "gin":
            z = (1.0 + p["eps"]) * h + aggr(h)
            h = jnp.dot(act(jnp.dot(z, p["w"]) + p["b"]), p["w2"])
        else:
            raise ValueError(variant)
        if i < n - 1:
            h = act(h)
    return h


def gcn_loss(params, aggr, x, labels, variant="gcn", mask=None):
    logits = gcn_forward(params, aggr, x, variant)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
