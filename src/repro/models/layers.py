"""Shared building blocks: norms, rotary embeddings, linear/embedding params.

Parameters are plain pytrees (dicts of jnp arrays); every layer is a pair of
``init_*`` / ``apply`` functions. Weight dtype defaults to bf16 with fp32
math where it matters (norms, softmax, rotary).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Dtype = jnp.dtype
PARAM_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=PARAM_DTYPE, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=PARAM_DTYPE):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms (fp32 math, cast back)
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def soft_cap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope_table(positions: jax.Array, d_head: int, theta: float = 10_000.0
               ) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables: positions [*(T)] -> ([*T, d_head/2], [*T, d_head/2])."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., T, H, D]; cos/sin: [T, D/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * c - xf2 * s, xf2 * c + xf1 * s], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, gated: bool = True, act: str = "silu",
             dtype=PARAM_DTYPE):
    ks = jax.random.split(key, 3)
    p = {"wo": dense_init(ks[2], d_ff, d_model, dtype)}
    if gated:
        p["wi"] = dense_init(ks[0], d_model, d_ff, dtype)
        p["wg"] = dense_init(ks[1], d_model, d_ff, dtype)
    else:
        p["wi"] = dense_init(ks[0], d_model, d_ff, dtype)
    p["_act"] = act  # static string survives as aux in our param trees? no — keep out
    del p["_act"]
    return p


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
         "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}


def apply_mlp(p, x: jax.Array, act: str = "silu", gated: bool = True) -> jax.Array:
    from ..sharding import shard  # late import; no-op without a mesh ctx
    a = _ACTS[act]
    h = jnp.dot(x, p["wi"])
    if gated:
        h = a(jnp.dot(x, p["wg"]).astype(jnp.float32)).astype(x.dtype) * h
    else:
        h = a(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, *(["batch"] + [None] * (h.ndim - 2) + ["model"]))
    return jnp.dot(h, p["wo"])
