"""Mixture-of-Experts with Accel-GCN-style block-balanced dispatch.

Two dispatch paths (numerically equivalent up to capacity drops):

* ``moe_capacity``  — sort-based capacity dispatch with static shapes; this is
  the path that lowers/shards for the multi-pod dry-run (experts on the
  ``model``/``expert`` mesh axis, tokens on ``data``).
* ``moe_block``     — the paper's technique (DESIGN.md §4): tokens are
  degree-sorted by expert id, block-partitioned into fixed 128-row slabs with
  one scalar metadata word per block, and multiplied by the Pallas grouped
  GEMM (`kernels/grouped_matmul.py`). Dropless. CPU/TPU-kernel path.

Routers: softmax top-k with optional normalization (dbrx normalizes top-k
probs; deepseek-moe uses unnormalized gates + shared experts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .layers import PARAM_DTYPE, apply_mlp, dense_init, init_mlp
from ..kernels.ops import grouped_matmul_blocked, grouped_matmul_pallas


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int = 0,
             dtype=PARAM_DTYPE):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32),
        "wi": (jax.random.normal(ks[1], (n_experts, d_model, d_ff), jnp.float32)
               * (d_model ** -0.5)).astype(dtype),
        "wg": (jax.random.normal(ks[2], (n_experts, d_model, d_ff), jnp.float32)
               * (d_model ** -0.5)).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_experts, d_ff, d_model), jnp.float32)
               * (d_ff ** -0.5)).astype(dtype),
    }
    if n_shared:
        p["shared"] = init_mlp(ks[4], d_model, d_ff * n_shared, gated=True, dtype=dtype)
    return p


def _route(p, x2d, top_k: int, normalize: bool):
    """x2d: [T, D] -> (weights [T, k] f32, ids [T, k] i32, probs [T, E])."""
    logits = jnp.dot(x2d.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    if normalize:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, ids, probs


def aux_load_balance_loss(probs: jax.Array, ids: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load balancing loss (mean_prob x mean_assignment)."""
    me = probs.mean(0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], n_experts, dtype=jnp.float32), axis=0)
    return n_experts * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# Path 1: capacity dispatch (lowering/dry-run path)
# ---------------------------------------------------------------------------
# §Perf lever (GShard-style grouped dispatch): when >1, tokens are split into
# this many groups (set = the mesh "data" extent) with per-group capacity, so
# the dispatch scatter/gather is LOCAL to each data shard and the only
# cross-device movement is the clean (data -> expert) resharding of xe.
# Baseline (1): a single global scatter whose updates XLA's scatter
# partitioner replicates — measured 61% of dbrx collective bytes (§Perf).
DISPATCH_GROUPS = 1


def _dispatch_group(xt, ids, w, *, top_k, n_experts, cap):
    """Per-group capacity dispatch. xt: [t, D] -> (xe [E, cap, D], slot, keep)."""
    t = xt.shape[0]
    flat_e = ids.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    order = jnp.argsort(flat_e, stable=True)
    ranks = jnp.zeros_like(flat_e)
    ranks = ranks.at[order].set(
        jnp.arange(t * top_k) -
        jnp.searchsorted(flat_e[order], flat_e[order], side="left"))
    keep = ranks < cap
    slot = jnp.where(keep, flat_e * cap + ranks, n_experts * cap)
    xe = jnp.zeros((n_experts * cap + 1, xt.shape[1]), xt.dtype
                   ).at[slot].set(xt[flat_t])
    return xe[:-1], slot, flat_t


def moe_capacity(p, x, *, top_k: int, n_experts: int, capacity_factor: float = 1.25,
                 normalize: bool = True, act: str = "silu"):
    """x: [B, T, D] -> [B, T, D]. Static shapes; shardable on (data, expert)."""
    from ..sharding import shard
    B, T, D = x.shape
    xt = x.reshape(B * T, D)
    n_tok = B * T
    w, ids, probs = _route(p, xt, top_k, normalize)

    # grouping pays for itself only at scale; tiny (decode-sized) token
    # counts keep the single-group path (measured: dbrx decode 0.94->1.56 s
    # collective with grouping forced at 8 tokens/group)
    G = (DISPATCH_GROUPS
         if (DISPATCH_GROUPS and n_tok % DISPATCH_GROUPS == 0
             and n_tok // DISPATCH_GROUPS >= 64)
         else 1)
    tl = n_tok // G
    cap = int(capacity_factor * tl * top_k / n_experts)
    cap = max(8, ((cap + 7) // 8) * 8)

    if G == 1:
        xe, slot, flat_t = _dispatch_group(xt, ids, w, top_k=top_k,
                                           n_experts=n_experts, cap=cap)
        xe = shard(xe.reshape(n_experts, cap, D), "model", None, None)
        h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
        g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h)
        ye = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(n_experts * cap, D)
        ye = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], axis=0)
        yt = ye[slot] * w.reshape(-1)[:, None].astype(ye.dtype)
        out = jax.ops.segment_sum(yt.astype(jnp.float32), flat_t,
                                  num_segments=n_tok)
    else:
        xg = shard(xt.reshape(G, tl, D), "data", None, None)
        idg = ids.reshape(G, tl, top_k)
        wg_ = w.reshape(G, tl, top_k)
        xe, slot, flat_t = jax.vmap(
            lambda a, b, c: _dispatch_group(a, b, c, top_k=top_k,
                                            n_experts=n_experts, cap=cap)
        )(xg, idg, wg_)                                   # xe: [G, E*cap, D]
        xe = xe.reshape(G, n_experts, cap, D).transpose(1, 0, 2, 3)
        xe = shard(xe, "model", "data", None, None)       # the one resharding
        h = jnp.einsum("egcd,edf->egcf", xe, p["wi"])
        g = jnp.einsum("egcd,edf->egcf", xe, p["wg"])
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h)
        ye = jnp.einsum("egcf,efd->egcd", h, p["wo"]).astype(x.dtype)
        ye = shard(ye, "model", "data", None, None)
        # reshard expert->data BEFORE the combine gather so it lowers as one
        # clean all-to-all instead of per-gather all-reduces (§Perf iter 3);
        # kept in bf16 so the reshard (and its backward) moves half the bytes
        # (§Perf iter 4 — the fp32 combine upcast doubled the backward
        # all-gather).
        ye = shard(ye.transpose(1, 0, 2, 3), "data", None, None, None)
        ye = ye.reshape(G, n_experts * cap, D)
        ye = jnp.concatenate([ye, jnp.zeros((G, 1, D), ye.dtype)], axis=1)
        ye = shard(ye, "data", None, None)  # concat drops the sharding

        def combine(ye_g, slot_g, w_g, t_g):
            yt = ye_g[slot_g] * w_g.reshape(-1)[:, None].astype(ye_g.dtype)
            return jax.ops.segment_sum(yt.astype(jnp.float32), t_g,
                                       num_segments=tl)
        out = jax.vmap(combine)(ye, slot, wg_, flat_t).reshape(n_tok, D)

    out = out.astype(x.dtype)
    if "shared" in p:
        out = out + apply_mlp(p["shared"], xt, act=act)
    return out.reshape(B, T, D), aux_load_balance_loss(probs, ids, n_experts)


# ---------------------------------------------------------------------------
# Path 2: Accel-GCN block dispatch (paper technique; Pallas kernel)
# ---------------------------------------------------------------------------
def moe_block(p, x, *, top_k: int, n_experts: int, m_tile: int = 128,
              normalize: bool = True, act: str = "silu", use_pallas: bool = True):
    """Dropless block-balanced dispatch via the paper's recipe.

    1. degree sort: stable sort of (token,slot) rows by expert id;
    2. block partition: pad each expert's run to a multiple of ``m_tile``;
       one int32 expert-id per block is the whole metadata (cf. paper int4);
    3. combined warp: Pallas grouped GEMM with 128-lane tiles.
    """
    B, T, D = x.shape
    xt = x.reshape(B * T, D)
    n_tok = B * T
    w, ids, probs = _route(p, xt, top_k, normalize)

    flat_e = ids.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n_tok), top_k)
    flat_w = w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)      # degree sorting
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    # block partition with per-expert padding to m_tile (worst case: every
    # expert partially fills one extra block)
    S = n_tok * top_k
    M = S + n_experts * m_tile
    M = ((M + m_tile - 1) // m_tile) * m_tile
    counts = jnp.bincount(flat_e, length=n_experts)
    padded = ((counts + m_tile - 1) // m_tile) * m_tile
    starts = jnp.concatenate([jnp.zeros(1, padded.dtype), jnp.cumsum(padded)])[:-1]
    rank_in_e = jnp.arange(S) - jnp.searchsorted(se, se, side="left")
    dst = starts[se] + rank_in_e                   # padded destination row

    xs = jnp.zeros((M, D), x.dtype).at[dst].set(xt[st])
    nb = M // m_tile
    blk_start = jnp.arange(nb) * m_tile
    block_expert = jnp.clip(
        jnp.searchsorted(starts + padded, blk_start, side="right"), 0, n_experts - 1
    ).astype(jnp.int32)

    if use_pallas:
        gmm = functools.partial(grouped_matmul_pallas, m_tile=m_tile)
    else:
        gmm = functools.partial(grouped_matmul_blocked, m_tile=m_tile)
    h = gmm(xs, p["wi"], block_expert).astype(x.dtype)
    g = gmm(xs, p["wg"], block_expert).astype(x.dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    ys = gmm(h, p["wo"], block_expert).astype(jnp.float32)

    yt = ys[dst] * sw[:, None]
    out = jax.ops.segment_sum(yt, st, num_segments=n_tok).astype(x.dtype)
    if "shared" in p:
        out = out + apply_mlp(p["shared"], xt, act=act)
    return out.reshape(B, T, D), aux_load_balance_loss(probs, ids, n_experts)
