"""Mamba-2 (SSD — state-space duality) blocks: chunked scan + decode step.

Implements the SSD chunked algorithm from arXiv:2405.21060: within-chunk
quadratic (attention-like) term + cross-chunk state recurrence, giving
O(T * chunk) work and scan-friendly lowering. A naive recurrent oracle lives
in the tests.

Recurrence convention: h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x) x_t,
y_t = C_t . h_t + D * x_t, with A negative (A = -exp(A_log)).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import PARAM_DTYPE, dense_init, rms_norm


def init_mamba2(key, d_model: int, d_inner: int, head_dim: int, state: int,
                conv_k: int = 4, dtype=PARAM_DTYPE):
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * state
    ks = jax.random.split(key, 5)
    # dt bias init so softplus(dt_bias) ~ [1e-3, 1e-1] (mamba default)
    u = jax.random.uniform(ks[3], (n_heads,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "w_in": dense_init(ks[0], d_model, 2 * d_inner + 2 * state + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_k, conv_dim), jnp.float32)
                   * (conv_k ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": dt_bias,
        "norm_w": jnp.zeros((d_inner,), dtype),
        "w_out": dense_init(ks[2], d_inner, d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, T, C]; w: [K, C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K is tiny (4); unrolled adds, no conv primitive needed
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, A, B, C, h0=None, chunk: int = 128):
    """SSD scan. x: [b,T,H,P]; dt: [b,T,H]; A: [H]; B,C: [b,T,N].

    Returns (y [b,T,H,P], h_final [b,H,P,N]).
    """
    b, T, H, P = x.shape
    N = B.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    nc = T // L

    a = dt.astype(jnp.float32) * A[None, None, :]            # [b,T,H] (<=0)
    xc = x.astype(jnp.float32).reshape(b, nc, L, H, P)
    dtc = dt.astype(jnp.float32).reshape(b, nc, L, H)
    Bc = B.astype(jnp.float32).reshape(b, nc, L, N)
    Cc = C.astype(jnp.float32).reshape(b, nc, L, N)
    ac = a.reshape(b, nc, L, H)
    acs = jnp.cumsum(ac, axis=2)                              # inclusive cumsum

    # ---- intra-chunk (attention-like, lower-triangular decay) -------------
    # decay[i, j] = exp(acs[i] - acs[j]) for i >= j
    diff = acs[:, :, :, None, :] - acs[:, :, None, :, :]      # [b,c,i,j,h]
    tri = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # [b,c,i,j]
    scores = cb[..., None] * decay * dtc[:, :, None, :, :]    # [b,c,i,j,h]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)

    # ---- chunk states ------------------------------------------------------
    seg_end = acs[:, :, -1:, :]                               # [b,c,1,h]
    w_state = jnp.exp(seg_end - acs) * dtc                    # [b,c,l,h]
    S = jnp.einsum("bcln,bclh,bclhp->bchnp", Bc, w_state, xc)  # [b,c,h,n,p]
    chunk_decay = jnp.exp(seg_end[:, :, 0, :])                # [b,c,h]

    # ---- cross-chunk recurrence -------------------------------------------
    if h0 is None:
        h0 = jnp.zeros((b, H, N, P), jnp.float32)

    def step(h, args):
        dec, s = args                                          # dec: [b,h]; s: [b,h,n,p]
        h_out = h                                              # state BEFORE this chunk
        h_new = dec[:, :, None, None] * h + s
        return h_new, h_out

    h_final, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                      # [b,c,h,n,p]

    # ---- inter-chunk contribution -----------------------------------------
    in_decay = jnp.exp(acs)                                    # [b,c,l,h]
    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp", Cc, in_decay, h_prevs)

    y = (y_intra + y_inter).reshape(b, T, H, P)
    return y, h_final


class MambaCache(NamedTuple):
    conv: jax.Array   # [B, K-1, conv_dim] last inputs
    ssm: jax.Array    # [B, H, N, P]

    @staticmethod
    def create(batch, conv_k, conv_dim, n_heads, state, head_dim, dtype=jnp.float32):
        return MambaCache(
            jnp.zeros((batch, conv_k - 1, conv_dim), dtype),
            jnp.zeros((batch, n_heads, state, head_dim), jnp.float32),
        )


def mamba2_forward(p, x, *, head_dim: int, state: int, chunk: int = 128,
                   return_state: bool = False):
    """Full-sequence Mamba2 block. x: [B, T, D] -> [B, T, D]."""
    Bsz, T, D = x.shape
    d_inner = p["w_out"].shape[0]
    H = d_inner // head_dim
    K = p["conv_w"].shape[0]
    zxbcdt = jnp.dot(x, p["w_in"])
    z, xbc_pre, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * state], axis=-1)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs, Bs, Cs = jnp.split(xbc, [d_inner, d_inner + state], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_fin = ssd_chunked(xs.reshape(Bsz, T, H, head_dim), dtv, A, Bs, Cs, chunk=chunk)
    y = y + p["D"][None, None, :, None] * xs.reshape(Bsz, T, H, head_dim).astype(jnp.float32)
    y = y.reshape(Bsz, T, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm_w"])
    out = jnp.dot(y, p["w_out"])
    if return_state:
        cache = MambaCache(xbc_pre[:, T - (K - 1):, :], h_fin)
        return out, cache
    return out


def mamba2_decode(p, x, cache: MambaCache, *, head_dim: int, state: int
                  ) -> Tuple[jax.Array, MambaCache]:
    """One-token step. x: [B, 1, D]."""
    Bsz = x.shape[0]
    d_inner = p["w_out"].shape[0]
    H = d_inner // head_dim
    zxbcdt = jnp.dot(x[:, 0], p["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * state], axis=-1)
    # conv over (cached K-1 inputs + current)
    hist = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # [B, K, C]
    w = p["conv_w"].astype(jnp.float32)
    conv_out = (hist.astype(jnp.float32) * w[None]).sum(1) + p["conv_b"].astype(jnp.float32)
    xbc_a = jax.nn.silu(conv_out)
    xs, Bs, Cs = jnp.split(xbc_a, [d_inner, d_inner + state], axis=-1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # [B,H]
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(Bsz, H, head_dim)
    dec = jnp.exp(dtv * A[None])                                     # [B,H]
    h_new = (dec[:, :, None, None] * cache.ssm
             + jnp.einsum("bn,bh,bhp->bhnp", Bs, dtv, xh))
    y = jnp.einsum("bn,bhnp->bhp", Cs, h_new) + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm_w"])
    out = jnp.dot(y, p["w_out"])[:, None, :]
    return out, MambaCache(hist[:, 1:], h_new)
