"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` on an SPMD-partitioned executable reports the *per-device*
program, so the three terms are computed per chip directly:

    compute_s    = flops_per_device / PEAK_FLOPS
    memory_s     = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

``collective_bytes`` parses the optimized HLO text and sums the operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (both fused and -start/-done async forms, counted once).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

HW = {
    "peak_flops": 197e12,   # bf16 / chip
    "hbm_bw": 819e9,        # B/s
    "ici_bw": 50e9,         # B/s/link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\(?[a-z0-9#,\[\]{}() ]+?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

# bytes actually moved over links, as a multiple of the RESULT size
# (ring-algorithm estimates; reduce-scatter uses operand = result x group).
_XFER_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "all-to-all": 1.0,
                "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind link bytes from optimized HLO text.

    Optimized HLO prints operands as bare names, so sizes are read from the
    RESULT shape (printed left of '='), scaled per kind: all-reduce moves
    ~2x its size (reduce+broadcast ring), reduce-scatter moves ~operand =
    result x group_size, the others ~1x. ``-done`` halves of async pairs are
    skipped so async collectives are counted once.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_seg, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue
        total = sum(_shape_bytes(dm.group(1), dm.group(2))
                    for dm in _SHAPE_RE.finditer(result_seg))
        if kind == "reduce-scatter":
            gm = _GROUPS_RE.search(line)
            group = int(gm.group(2)) if gm else 1
            total *= group
        else:
            total = int(total * _XFER_FACTOR[kind])
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device
    bytes_hbm: float             # per-device
    bytes_coll: float            # per-device
    coll_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None   # global 6*N*D
    useful_ratio: Optional[float] = None  # model_flops / (flops * chips)

    def to_row(self) -> Dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.bytes_hbm,
            "coll_bytes_per_dev": self.bytes_coll,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "coll_breakdown": self.coll_breakdown,
        }


def roofline_terms(cost: Dict, hlo_text: str, *, chips: int,
                   model_flops: Optional[float] = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    cbytes = float(sum(coll.values()))
    compute_s = flops / HW["peak_flops"]
    memory_s = nbytes / HW["hbm_bw"]
    collective_s = cbytes / HW["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / max(flops * chips, 1.0)
    return Roofline(flops, nbytes, cbytes, coll, compute_s, memory_s,
                    collective_s, bottleneck, model_flops, useful)


def model_flops_estimate(n_params_active: float, n_tokens: float,
                         kind: str = "train") -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference forward."""
    c = 6.0 if kind == "train" else 2.0
    return c * n_params_active * n_tokens
