"""Logical-axis -> mesh-axis sharding rules with divisibility fallback.

Scheme (DESIGN.md §6): FSDP + TP hybrid.
  * column-parallel weights [d_in, d_out]  -> P("data", "model")
  * row-parallel weights    [d_in, d_out]  -> P("model", "data")
  * expert weights [E, ...]                -> experts on "model" (EP)
  * embeddings [V, D]                      -> P("model", "data") (vocab-TP)
  * activations: batch on ("pod","data"), feature/expert/vocab on "model",
    attention heads on "model" when divisible, else head_dim, else replicate.

Every axis assignment is validated for divisibility; a non-dividing axis is
dropped (replication) — e.g. qwen's 40 kv-heads on a 16-way model axis fall
back to head_dim (128/16) sharding. This is the documented fallback chain
that makes all 10 archs lower on the same mesh.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: dict = {"mesh": None}


def set_mesh_ctx(mesh: Optional[Mesh]) -> None:
    _CTX["mesh"] = mesh


def get_mesh_ctx() -> Optional[Mesh]:
    return _CTX["mesh"]


def clear_mesh_ctx() -> None:
    _CTX["mesh"] = None


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch: ("pod","data") when pod exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def resolve_spec(shape: Sequence[int], want: Sequence, mesh: Mesh) -> P:
    """Validate a candidate spec against divisibility; drop failing axes."""
    out = []
    for dim, ax in zip(shape, want):
        if ax is None:
            out.append(None)
            continue
        if dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def shard(x: jax.Array, *want) -> jax.Array:
    """Activation sharding constraint (no-op outside a mesh context).

    ``want`` entries: None | mesh-axis name | tuple of axis names | "batch"
    (resolves to ("pod","data") / ("data",) depending on the mesh).
    """
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    resolved = []
    for ax in want:
        if ax == "batch":
            ax = batch_axes(mesh)
        resolved.append(ax)
    spec = resolve_spec(x.shape, resolved, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_heads(x: jax.Array, head_axis: int = 2, dim_axis: int = 3) -> jax.Array:
    """Shard [B, T, H, Dh]: heads on "model" when divisible, else UNCONSTRAINED.

    §Perf finding (EXPERIMENTS.md): the earlier head_dim fallback (shard Dh
    when H does not divide the model axis) forced XLA into "involuntary full
    rematerialization" copies around RoPE's half-split — qwen prefill_32k
    memory term 385 s -> 32 s (12x) once removed. Non-divisible head counts
    now leave the layout to the partitioner.
    """
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    msz = _axis_size(mesh, "model")
    want: list = [batch_axes(mesh)] + [None] * (x.ndim - 1)
    if x.shape[head_axis] % msz == 0:
        want[head_axis] = "model"
    # batch stays constrained in all cases (dropping it regressed decode 3x)
    spec = resolve_spec(x.shape, want, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------
# (regex on the param's key-path leaf(s), spec for the trailing dims).
# Leading stacked-layer dims are replicated automatically.
_RULES = [
    (r"(wq|wk|wv|wi|wg)$", ("data", "model")),
    (r"wo$", ("model", "data")),
    (r"w_in$", ("data", "model")),
    (r"w_out$", ("model", "data")),
    (r"embed$", ("model", "data")),
    (r"head$", ("data", "model")),
    (r"router$", ("data", None)),
    (r"conv_w$", (None, "model")),
    (r"(a_q|a_i)$", ("data", None)),      # LoRA A
    (r"(b_q|b_i)$", (None, "model")),     # LoRA B
]
_MOE_RULES = [  # expert-stacked weights, matched when rank >= 3 tail (E, d, f)
    (r"(wi|wg)$", ("model", "data", None)),
    (r"wo$", ("model", None, "data")),
]

# §Perf lever (ZeRO-1 for expert weights): when True, MoE expert *parameters*
# are replicated along "data" (sharded on "model"/EP only) so forward/backward
# issue NO per-layer FSDP gathers; only the optimizer state stays
# data-sharded, turning per-layer weight gathers into one per-step
# reduce-scatter(grad) + all-gather(params) pair inserted by SPMD at the
# optimizer boundary.
ZERO1_MOE = False


def _leaf_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    # expert-parallel weights: inside an "moe" scope with >= 3 dims
    if ".moe." in path or path.endswith("moe"):
        is_param_side = ".opt." not in path and not path.startswith("opt.")
        if ZERO1_MOE and is_param_side:
            for pat, tail in _MOE_RULES:
                if re.search(pat, path) and len(shape) >= len(tail):
                    want = [None] * (len(shape) - 3) + ["model", None, None]
                    return resolve_spec(shape, want, mesh)
        for pat, tail in _MOE_RULES:
            if re.search(pat, path) and len(shape) >= len(tail):
                want = [None] * (len(shape) - len(tail)) + list(tail)
                return resolve_spec(shape, want, mesh)
    for pat, tail in _RULES:
        if re.search(pat, path) and len(shape) >= len(tail):
            want = [None] * (len(shape) - len(tail)) + list(tail)
            return resolve_spec(shape, want, mesh)
    return P()  # norms, biases, scalars: replicated


def cache_specs(tree, mesh: Mesh):
    """Decode-state shardings: batch on ("pod","data"); KV heads on "model"
    (falling back to head_dim), SSM heads / conv channels on "model".

    Positions are taken from the right so leading layer-stack dims never
    matter: kv [..., B, S, KH, Dh]; conv [..., B, K-1, C]; ssm [..., B, H, N, P].
    """
    b_ax = batch_axes(mesh)
    msz = _axis_size(mesh, "model")

    def spec_for(path: str, shape) -> P:
        nd = len(shape)
        want: list = [None] * nd
        if path.endswith(".k") or path.endswith(".v"):
            want[nd - 4] = b_ax
            if shape[nd - 2] % msz == 0:
                want[nd - 2] = "model"
            elif shape[nd - 1] % msz == 0:
                want[nd - 1] = "model"
        elif path.endswith(".conv"):
            want[nd - 3] = b_ax
            want[nd - 1] = "model"
        elif path.endswith(".ssm"):
            want[nd - 4] = b_ax
            want[nd - 3] = "model"
        elif path.endswith("pos") or nd == 0:
            return P()
        return resolve_spec(shape, want, mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = []
    for path, leaf in flat:
        pstr = ".".join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                        for k in path)
        specs.append(NamedSharding(mesh, spec_for(pstr, np.shape(leaf))))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_specs(params, mesh: Mesh):
    """Pytree of NamedShardings matching ``params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        pstr = pstr.replace("/", ".")
        specs.append(NamedSharding(mesh, _leaf_spec(pstr, np.shape(leaf), mesh)))
    return jax.tree_util.tree_unflatten(treedef, specs)
