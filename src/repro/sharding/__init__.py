from .rules import (  # noqa: F401
    param_specs, cache_specs, set_mesh_ctx, get_mesh_ctx, clear_mesh_ctx,
    shard, shard_heads, batch_axes, resolve_spec,
)
