"""gemma2-27b [dense] — 46L d_model=4608 32H (kv=16) d_ff=36864 vocab=256000.

Local (sliding-window 4096) / global alternating attention, attn-logit
softcap 50, final-logit softcap 30, GeGLU, pre+post block norms, tied
embeddings, query scale (d_model/n_heads)^-1/2 [arXiv:2408.00118; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=36864, vocab=256000, rope_theta=10_000.0,
    attn_softcap=50.0, final_softcap=30.0, sliding_window=4096,
    local_global_period=2, attn_scale=(4608 / 32) ** -0.5,
    tie_embeddings=True, post_block_norm=True, act="gelu_tanh",
    notes="local+global alternating; logit softcaps; GeGLU",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="gemma2-reduced", n_layers=4, d_model=64,
                          n_heads=4, n_kv_heads=2, d_head=16, d_ff=192,
                          vocab=256, sliding_window=32,
                          attn_scale=(64 / 4) ** -0.5)
