"""Config registry: one module per assigned architecture (+ the paper's GCN).

``get_config(name)`` returns the full published config; ``get_reduced(name)``
the same-family smoke-test config (small dims, CPU-runnable).
"""
from __future__ import annotations

from .base import ArchConfig, ShapeConfig, SHAPES, SHAPES_BY_NAME, shape_skips

ARCH_IDS = [
    "qwen1.5-32b",
    "phi3-mini-3.8b",
    "gemma2-27b",
    "internlm2-20b",
    "zamba2-7b",
    "hubert-xlarge",
    "dbrx-132b",
    "deepseek-moe-16b",
    "chameleon-34b",
    "mamba2-780m",
]

_MODULES = {
    "qwen1.5-32b": "qwen1p5_32b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "gemma2-27b": "gemma2_27b",
    "internlm2-20b": "internlm2_20b",
    "zamba2-7b": "zamba2_7b",
    "hubert-xlarge": "hubert_xlarge",
    "dbrx-132b": "dbrx_132b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-780m": "mamba2_780m",
}


def get_config(name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.reduced()
