"""dbrx-132b [moe] — 40L d_model=6144 48H (kv=8) d_ff=10752 vocab=100352.

16 experts, top-4, fine-grained [hf:databricks/dbrx-base]. Every layer MoE.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=10752, vocab=100352, rope_theta=500_000.0,
    n_experts=16, top_k=4,
    notes="16e top-4 MoE; GQA kv=8; block-dispatch uses the paper technique",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="dbrx-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=2, d_head=16, d_ff=96,
                          vocab=256, n_experts=4, top_k=2,
                          moe_capacity_factor=4.0)  # dropless at smoke scale
