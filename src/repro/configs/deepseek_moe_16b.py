"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400.

2 shared + 64 routed experts, top-6, fine-grained [arXiv:2401.06066; hf].
First layer uses a dense FFN (d_ff=10944) per the published config.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab=102400, rope_theta=10_000.0,
    n_experts=64, top_k=6, n_shared_experts=2,
    first_dense_layers=1, first_dense_ff=10944,
    notes="fine-grained MoE: 2 shared + 64 routed top-6; first layer dense",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="deepseek-moe-reduced", n_layers=3, d_model=64,
                          n_heads=4, n_kv_heads=4, d_head=16, d_ff=48,
                          vocab=256, n_experts=8, top_k=2, n_shared_experts=1,
                          first_dense_layers=1, first_dense_ff=128,
                          moe_capacity_factor=4.0)  # dropless at smoke scale
