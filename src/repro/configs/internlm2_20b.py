"""internlm2-20b [dense] — 48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92544.

GQA 6:1 [arXiv:2403.17297; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab=92544, rope_theta=1_000_000.0,
    notes="GQA kv=8; SwiGLU",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="internlm2-reduced", n_layers=2, d_model=64,
                          n_heads=8, n_kv_heads=2, d_head=8, d_ff=128, vocab=256)
