"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000.

Mamba-2 backbone with a single weight-shared attention+MLP block applied
every ``hybrid_group`` Mamba layers, with per-site LoRA adapters
[arXiv:2411.15242]. ssm_state=64.

Simplifications recorded in DESIGN.md: the shared-block input is the
residual stream (no embedding concat); LoRA rank 128 on the shared QKV and
MLP-in projections.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
    d_ff=14336, vocab=32000, rope_theta=10_000.0,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_group=6, lora_rank=128,
    notes="Mamba2 + shared attn blocks (13 sites) + per-site LoRA",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="zamba2-reduced", n_layers=7, d_model=64,
                          n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
                          vocab=256, ssm_state=8, ssm_head_dim=16,
                          hybrid_group=3, lora_rank=8)
