"""chameleon-34b [vlm] — 48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536.

Early-fusion VLM: images arrive as VQ tokens in the shared 65536 vocab
[arXiv:2405.09818], so the backbone is a dense GQA decoder and the modality
frontend is the (stubbed) VQ tokenizer — ``input_specs`` provides token ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab=65536, rope_theta=10_000.0,
    notes="early-fusion VLM; VQ image tokens share the text vocab (frontend stub)",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="chameleon-reduced", n_layers=2, d_model=64,
                          n_heads=8, n_kv_heads=2, d_head=8, d_ff=160, vocab=256)
