"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only transformer backbone (same as wav2vec2) [arXiv:2106.07447].
The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings [B, T, 1280]; sinusoidal positions are
added in the embed stage. Output head: 504-way frame classification.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_head=80,
    d_ff=5120, vocab=504, rope_theta=None, causal=False,
    norm="layer", act="gelu", mlp_gated=False, frontend="stub_embed",
    notes="encoder-only; audio frontend stubbed as precomputed embeddings",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="hubert-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=64)
