"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064.

RoPE + SwiGLU + (degenerate, kv=heads) GQA [arXiv:2404.14219].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_head=96,
    d_ff=8192, vocab=32064, rope_theta=10_000.0,
    notes="RoPE SwiGLU; MHA",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="phi3-mini-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_head=16, d_ff=160, vocab=256)
