"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280.

SSD (state-space duality) [arXiv:2405.21060]; d_inner = 2*d_model = 3072,
head_dim 64 (48 ssm heads), ssm_state=128.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=50280, rope_theta=None,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    notes="attention-free SSD; tied embeddings per mamba convention",
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="mamba2-reduced", n_layers=3, d_model=64,
                          vocab=256, ssm_state=16, ssm_head_dim=16)
