"""Architecture + run configuration dataclasses.

Every assigned architecture gets one file in this package defining
``CONFIG: ArchConfig`` with the exact published dimensions, plus a
``reduced()`` helper producing the same-family smoke-test config.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # attention
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10_000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global_period: int = 0     # 2 => alternate local/global (gemma-2)
    attn_scale: Optional[float] = None
    causal: bool = True
    tie_embeddings: bool = False
    norm: str = "rms"                # rms | layer
    post_block_norm: bool = False
    act: str = "silu"
    mlp_gated: bool = True
    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    first_dense_ff: int = 0
    moe_capacity_factor: float = 1.25
    # ssm
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_k: int = 4
    # hybrid (zamba-2): shared attn+mlp block every `hybrid_group` mamba layers
    hybrid_group: int = 0
    lora_rank: int = 0
    # modality frontend: token | stub_embed (precomputed frame/patch embeds)
    frontend: str = "token"
    notes: str = ""

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.d_head

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (kind, seq_len, global_batch)."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_skips(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a skip-reason string, or None if the (arch, shape) cell runs.

    Recorded per the assignment spec and DESIGN.md §5.
    """
    if cfg.family == "encoder" and shape.kind == "decode":
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return "long_500k requires sub-quadratic attention (pure full-attention arch)"
    return None
