"""qwen1.5-32b [dense] — 64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064.

QKV bias per the Qwen1.5 family [hf:Qwen/Qwen1.5-0.5B scaled; hf].
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_head=128,
    d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
    notes="MHA (kv=40); SwiGLU; QKV bias",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="qwen1.5-32b-reduced", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=256)
