"""Cross-host placement directory: ``plan_key -> (host, device)`` fleet-wide.

:class:`~repro.distributed.placement.FleetPlanCache` caps the serving
working set at one *host's* devices. The :class:`PlacementDirectory` is the
level above it: every process of a multi-host JAX fleet holds one, and a
plan key resolves to the ``(process_index, local_device)`` slot that owns
the plan — so fleet capacity becomes the sum of every host's HBM, and a
request admitted on any host is forwarded to (and served from) the one host
whose device actually holds the staged plan.

Placement policy (mirroring ``FleetPlanCache``, one level up):

* **consistent hash over (host, device) slots** — every local device of
  every host is a ring slot (labelled ``host{p}:dev{i}``, virtual nodes per
  slot). Pure-hash placements are *deterministic across processes*: two
  directories built from the same host table place every key identically
  without any coordination, which is what makes the directory
  "distributed" — there is no directory server to ask.
* **load-aware override** — when the ring's slot already holds
  ``load_spread`` more placements than the emptiest slot, the key goes to
  the least-loaded slot instead. Overrides are an ingress-local
  optimization (they depend on the order this process saw keys); the
  executing host remains authoritative for which of ITS devices serves,
  so divergent overrides cost at most a duplicate local staging, never a
  wrong answer.
* **epoch-stamped entries** — each host carries an ``epoch`` that bumps on
  restart. An entry records its owner's epoch at placement time; when a
  host re-announces with a newer epoch (it restarted and lost its plan
  cache), every entry stamped with the old epoch is invalidated and
  re-placed on next lookup. :meth:`evict_host` removes a host from the
  ring entirely (crash, drain): its keys re-place onto the survivors,
  everyone else's arcs stay put (the consistent-hashing property).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .placement import ConsistentHashRing

__all__ = ["HostInfo", "Placement", "PlacementDirectory"]


@dataclasses.dataclass(frozen=True)
class HostInfo:
    """One fleet process: its rank, local device count, and restart epoch."""

    process_index: int
    n_devices: int
    epoch: int = 0

    def __post_init__(self):
        if self.process_index < 0:
            raise ValueError(f"bad process_index {self.process_index}")
        if self.n_devices < 1:
            raise ValueError(
                f"host {self.process_index} needs >= 1 device, "
                f"got {self.n_devices}")


@dataclasses.dataclass(frozen=True)
class Placement:
    """A key's recorded owner: host rank, local device index, owner epoch."""

    host: int
    device: int
    epoch: int


def _slot_label(host: int, device: int) -> str:
    return f"host{host}:dev{device}"


class PlacementDirectory:
    """Per-process view of the fleet-wide ``plan_key -> (host, device)`` map.

    Thread-safe; every mutation runs under one lock. Keys are whatever the
    plan cache uses (``(graph_hash, PartitionConfig)`` tuples) — the
    directory only hashes their first element, mirroring the per-host ring.
    """

    def __init__(self, hosts: Sequence[HostInfo], *,
                 load_spread: int = 4, vnodes: int = 32):
        hosts = list(hosts)
        if not hosts:
            raise ValueError("placement directory needs >= 1 host")
        ranks = [h.process_index for h in hosts]
        if len(set(ranks)) != len(ranks):
            raise ValueError(f"duplicate host ranks: {sorted(ranks)}")
        self.load_spread = load_spread
        self.vnodes = vnodes
        self._lock = threading.Lock()
        self._hosts: Dict[int, HostInfo] = {
            h.process_index: h for h in hosts}
        self._entries: Dict[object, Placement] = {}
        self._slots: List[Tuple[int, int]] = []
        self._ring: Optional[ConsistentHashRing] = None
        self._rebuild_ring_locked()
        # monotone counters (the fleet_* stats vocabulary feeds off these)
        self.placement_overrides = 0
        self.epoch_invalidations = 0   # entries dropped by a host restart
        self.evicted_placements = 0    # entries dropped by evict_host

    # ------------------------------------------------------------------ ring
    def _rebuild_ring_locked(self) -> None:
        self._slots = [(h.process_index, d)
                       for h in sorted(self._hosts.values(),
                                       key=lambda h: h.process_index)
                       for d in range(h.n_devices)]
        labels = [_slot_label(p, d) for p, d in self._slots]
        self._ring = ConsistentHashRing(range(len(self._slots)),
                                        vnodes=self.vnodes, labels=labels)

    def slots(self) -> List[Tuple[int, int]]:
        """Every live ``(host, device)`` slot, host-major."""
        with self._lock:
            return list(self._slots)

    def hosts(self) -> List[HostInfo]:
        with self._lock:
            return sorted(self._hosts.values(),
                          key=lambda h: h.process_index)

    # ------------------------------------------------------------- placement
    def place(self, key) -> Placement:
        """Resolve (placing if unseen or stale) the owner of ``key``.

        Stale entries — owner evicted, or owner restarted with a newer
        epoch — are invalidated here and the key re-placed with current
        ring/load data.
        """
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None:
                host = self._hosts.get(ent.host)
                if host is not None and host.epoch == ent.epoch:
                    return ent
                # stale: the owner restarted (lost its plans) or left
                del self._entries[key]
                self.epoch_invalidations += 1
            return self._place_locked(key)

    def lookup(self, key) -> Optional[Placement]:
        """Peek without placing; returns None for unseen AND stale keys."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return None
            host = self._hosts.get(ent.host)
            if host is None or host.epoch != ent.epoch:
                return None
            return ent

    def _place_locked(self, key) -> Placement:
        hash_key = key[0] if isinstance(key, tuple) else str(key)
        slot_idx = self._ring.lookup(str(hash_key))
        counts = self._slot_counts_locked()
        least = min(range(len(self._slots)), key=counts.__getitem__)
        if counts[slot_idx] - counts[least] > self.load_spread:
            slot_idx = least
            self.placement_overrides += 1
        host, device = self._slots[slot_idx]
        ent = Placement(host, device, self._hosts[host].epoch)
        self._entries[key] = ent
        return ent

    def _slot_counts_locked(self) -> List[int]:
        index = {slot: i for i, slot in enumerate(self._slots)}
        counts = [0] * len(self._slots)
        for ent in self._entries.values():
            i = index.get((ent.host, ent.device))
            if i is not None:
                counts[i] += 1
        return counts

    def release(self, key) -> None:
        """Drop a key's entry (its plan was evicted from the owning shard)."""
        with self._lock:
            self._entries.pop(key, None)

    # --------------------------------------------------------------- liveness
    def update_host(self, host: HostInfo) -> int:
        """(Re-)announce a host. A newer epoch invalidates every entry the
        host owned under older epochs — a restarted process lost its plan
        cache, so stale placements must not keep forwarding traffic to
        plans that no longer exist. Returns the number invalidated.
        A brand-new rank joins the ring (its arcs move ~1/slots of keys).

        A changed DEVICE COUNT at the same epoch (the default directory
        guessed a homogeneous fleet; the handshake learned the truth)
        also invalidates the host's entries that point past the corrected
        slot table — a placement on a device that does not exist must
        re-place, and dangling entries would silently fall out of the
        load accounting otherwise.
        """
        with self._lock:
            prev = self._hosts.get(host.process_index)
            self._hosts[host.process_index] = host
            if prev is None or prev.n_devices != host.n_devices:
                self._rebuild_ring_locked()
            if prev is not None and prev.epoch != host.epoch:
                stale = [k for k, e in self._entries.items()
                         if e.host == host.process_index
                         and e.epoch != host.epoch]
            elif prev is not None and prev.n_devices != host.n_devices:
                stale = [k for k, e in self._entries.items()
                         if e.host == host.process_index
                         and e.device >= host.n_devices]
            else:
                stale = []
            for k in stale:
                del self._entries[k]
            self.epoch_invalidations += len(stale)
            return len(stale)

    def evict_host(self, process_index: int) -> int:
        """Remove a host from the ring (crashed / drained): its entries drop
        and its keys re-place onto the survivors on next lookup. Returns
        the number of entries dropped. Evicting the last host raises.
        """
        with self._lock:
            if process_index not in self._hosts:
                return 0
            if len(self._hosts) == 1:
                raise ValueError("cannot evict the last live host")
            del self._hosts[process_index]
            self._rebuild_ring_locked()
            dead = [k for k, e in self._entries.items()
                    if e.host == process_index]
            for k in dead:
                del self._entries[k]
            self.evicted_placements += len(dead)
            return len(dead)

    # ------------------------------------------------------------------ stats
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def host_placement_counts(self) -> Dict[int, int]:
        """Live placements per host rank (0 for hosts with none)."""
        with self._lock:
            counts = {p: 0 for p in self._hosts}
            for ent in self._entries.values():
                if ent.host in counts:
                    counts[ent.host] += 1
            return counts

    def stats(self) -> Dict[str, object]:
        with self._lock:
            per_host = {p: 0 for p in self._hosts}
            for ent in self._entries.values():
                per_host[ent.host] = per_host.get(ent.host, 0) + 1
            return {
                "hosts": len(self._hosts),
                "slots": len(self._slots),
                "placements": len(self._entries),
                "host_placements": [per_host[p] for p in sorted(per_host)],
                "placement_overrides": self.placement_overrides,
                "epoch_invalidations": self.epoch_invalidations,
                "evicted_placements": self.evicted_placements,
            }
