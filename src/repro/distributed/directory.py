"""Cross-host placement directory: ``plan_key -> (host, device)`` fleet-wide.

:class:`~repro.distributed.placement.FleetPlanCache` caps the serving
working set at one *host's* devices. The :class:`PlacementDirectory` is the
level above it: every process of a multi-host JAX fleet holds one, and a
plan key resolves to the ``(process_index, local_device)`` slot that owns
the plan — so fleet capacity becomes the sum of every host's HBM, and a
request admitted on any host is forwarded to (and served from) the one host
whose device actually holds the staged plan.

Placement policy (mirroring ``FleetPlanCache``, one level up):

* **consistent hash over (host, device) slots** — every local device of
  every host is a ring slot (labelled ``host{p}:dev{i}``, virtual nodes per
  slot). Pure-hash placements are *deterministic across processes*: two
  directories built from the same host table place every key identically
  without any coordination, which is what makes the directory
  "distributed" — there is no directory server to ask.
* **load-aware override** — when the ring's slot already holds
  ``load_spread`` more placements than the emptiest slot, the key goes to
  the least-loaded slot instead. Overrides are an ingress-local
  optimization (they depend on the order this process saw keys); the
  executing host remains authoritative for which of ITS devices serves,
  so divergent overrides cost at most a duplicate local staging, never a
  wrong answer.
* **epoch-stamped entries** — each host carries an ``epoch`` that bumps on
  restart. An entry records its owner's epoch at placement time; when a
  host re-announces with a newer epoch (it restarted and lost its plan
  cache), every entry stamped with the old epoch is invalidated and
  re-placed on next lookup. :meth:`evict_host` removes a host from the
  ring entirely (crash, drain): its keys re-place onto the survivors,
  everyone else's arcs stay put (the consistent-hashing property).
* **replica sets** — a hot plan may be staged on several slots at once:
  :meth:`add_replica` / :meth:`remove_replica` maintain an ordered replica
  list per key (the primary owner first), each replica epoch-stamped like
  a primary entry. Losing the primary (epoch bump, host eviction) PROMOTES
  the first surviving replica instead of dropping the key — evicting one
  replica never discards the plan's other replicas — and :meth:`replicas`
  returns only live replicas, lazily scrubbing stale ones.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .placement import ConsistentHashRing

__all__ = ["HostInfo", "Placement", "PlacementDirectory"]


@dataclasses.dataclass(frozen=True)
class HostInfo:
    """One fleet process: its rank, local device count, and restart epoch."""

    process_index: int
    n_devices: int
    epoch: int = 0

    def __post_init__(self):
        if self.process_index < 0:
            raise ValueError(f"bad process_index {self.process_index}")
        if self.n_devices < 1:
            raise ValueError(
                f"host {self.process_index} needs >= 1 device, "
                f"got {self.n_devices}")


@dataclasses.dataclass(frozen=True)
class Placement:
    """A key's recorded owner: host rank, local device index, owner epoch."""

    host: int
    device: int
    epoch: int


def _slot_label(host: int, device: int) -> str:
    return f"host{host}:dev{device}"


class PlacementDirectory:
    """Per-process view of the fleet-wide ``plan_key -> (host, device)`` map.

    Thread-safe; every mutation runs under one lock. Keys are whatever the
    plan cache uses (``(graph_hash, PartitionConfig)`` tuples) — the
    directory only hashes their first element, mirroring the per-host ring.
    """

    def __init__(self, hosts: Sequence[HostInfo], *,
                 load_spread: int = 4, vnodes: int = 32):
        hosts = list(hosts)
        if not hosts:
            raise ValueError("placement directory needs >= 1 host")
        ranks = [h.process_index for h in hosts]
        if len(set(ranks)) != len(ranks):
            raise ValueError(f"duplicate host ranks: {sorted(ranks)}")
        self.load_spread = load_spread
        self.vnodes = vnodes
        self._lock = threading.Lock()
        self._hosts: Dict[int, HostInfo] = {
            h.process_index: h for h in hosts}
        self._entries: Dict[object, Placement] = {}
        # extra replicas beyond the primary owner, insertion-ordered; the
        # full replica set of a key is [primary] + _replica_entries[key]
        self._replica_entries: Dict[object, List[Placement]] = {}
        self._slots: List[Tuple[int, int]] = []
        self._ring: Optional[ConsistentHashRing] = None
        with self._lock:
            self._rebuild_ring_locked()
        # versioned plan chains: graph_id -> (current plan key, version).
        # Publishing a newer version drops the OLD key's primary and every
        # replica, so no host can resolve a stale epoch through this
        # directory — and because record_version is deterministic (pure
        # function of its arguments), every host's directory converges on
        # the same current key without coordination.
        self._versions: Dict[str, Tuple[object, int]] = {}
        # monotone counters (the fleet_* stats vocabulary feeds off these)
        self.placement_overrides = 0
        self.epoch_invalidations = 0   # entries dropped by a host restart
        self.evicted_placements = 0    # entries dropped by evict_host
        self.replicas_added = 0
        self.replicas_removed = 0
        self.replica_promotions = 0    # replica became primary on owner loss
        self.replica_invalidations = 0  # stale replicas scrubbed
        self.version_invalidations = 0  # keys dropped by a newer plan version

    # ------------------------------------------------------------------ ring
    def _rebuild_ring_locked(self) -> None:
        self._slots = [(h.process_index, d)
                       for h in sorted(self._hosts.values(),
                                       key=lambda h: h.process_index)
                       for d in range(h.n_devices)]
        labels = [_slot_label(p, d) for p, d in self._slots]
        self._ring = ConsistentHashRing(range(len(self._slots)),
                                        vnodes=self.vnodes, labels=labels)

    def slots(self) -> List[Tuple[int, int]]:
        """Every live ``(host, device)`` slot, host-major."""
        with self._lock:
            return list(self._slots)

    def hosts(self) -> List[HostInfo]:
        with self._lock:
            return sorted(self._hosts.values(),
                          key=lambda h: h.process_index)

    # ------------------------------------------------------------- placement
    def place(self, key) -> Placement:
        """Resolve (placing if unseen or stale) the owner of ``key``.

        Stale entries — owner evicted, or owner restarted with a newer
        epoch — are invalidated here and the key re-placed with current
        ring/load data.
        """
        with self._lock:
            return self._resolve_primary_locked(key)

    def _live_locked(self, ent: Placement) -> bool:
        host = self._hosts.get(ent.host)
        return (host is not None and host.epoch == ent.epoch
                and ent.device < host.n_devices)

    def _resolve_primary_locked(self, key) -> Placement:
        ent = self._entries.get(key)
        if ent is not None:
            if self._live_locked(ent):
                return ent
            # stale: the owner restarted (lost its plans) or left
            del self._entries[key]
            self.epoch_invalidations += 1
        promoted = self._promote_locked(key)
        if promoted is not None:
            return promoted
        return self._place_locked(key)

    def _promote_locked(self, key) -> Optional[Placement]:
        """Make the first surviving replica of ``key`` the primary owner.

        Returns the promoted placement, or None when no live replica
        exists (the key's replica list, if any, is dropped).
        """
        live = self._scrub_replicas_locked(key)
        if not live:
            return None
        ent = live.pop(0)
        if live:
            self._replica_entries[key] = live
        else:
            self._replica_entries.pop(key, None)
        self._entries[key] = ent
        self.replica_promotions += 1
        return ent

    def _scrub_replicas_locked(self, key) -> List[Placement]:
        """Drop stale extras of ``key``; return the surviving list."""
        lst = self._replica_entries.get(key)
        if not lst:
            return []
        primary = self._entries.get(key)
        live = [e for e in lst
                if self._live_locked(e)
                and (primary is None
                     or (e.host, e.device) != (primary.host, primary.device))]
        self.replica_invalidations += len(lst) - len(live)
        if live:
            self._replica_entries[key] = live
        else:
            self._replica_entries.pop(key, None)
        return list(live)

    def lookup(self, key) -> Optional[Placement]:
        """Peek without placing; returns None for unseen AND stale keys."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return None
            host = self._hosts.get(ent.host)
            if host is None or host.epoch != ent.epoch:
                return None
            return ent

    def _place_locked(self, key) -> Placement:
        hash_key = key[0] if isinstance(key, tuple) else str(key)
        slot_idx = self._ring.lookup(str(hash_key))
        counts = self._slot_counts_locked()
        least = min(range(len(self._slots)), key=counts.__getitem__)
        if counts[slot_idx] - counts[least] > self.load_spread:
            slot_idx = least
            self.placement_overrides += 1
        host, device = self._slots[slot_idx]
        ent = Placement(host, device, self._hosts[host].epoch)
        self._entries[key] = ent
        return ent

    def _slot_counts_locked(self) -> List[int]:
        index = {slot: i for i, slot in enumerate(self._slots)}
        counts = [0] * len(self._slots)
        for ent in self._entries.values():
            i = index.get((ent.host, ent.device))
            if i is not None:
                counts[i] += 1
        for lst in self._replica_entries.values():
            for ent in lst:
                i = index.get((ent.host, ent.device))
                if i is not None:
                    counts[i] += 1
        return counts

    def place_at(self, key, host: int, device: int) -> Placement:
        """Record the primary owner of ``key`` at an EXPLICIT slot.

        The version-publish path uses this to keep a mutated graph's new
        plan key on the slot that already holds the superseded version —
        sticky ownership across versions, so warmed device state, replica
        history, and pin markers stay meaningful. Deterministic given the
        same host table, like :meth:`record_version`, so every host's
        directory converges on the same owner without coordination.
        Stamped with the host's CURRENT epoch; overwrites any prior
        primary for the key. Raises on unknown hosts / bad devices.
        """
        with self._lock:
            hinfo = self._hosts.get(host)
            if hinfo is None:
                raise KeyError(f"unknown host rank {host}")
            if not 0 <= device < hinfo.n_devices:
                raise ValueError(
                    f"host {host} has {hinfo.n_devices} devices, "
                    f"no device {device}")
            ent = Placement(host, device, hinfo.epoch)
            self._entries[key] = ent
            return ent

    def release(self, key) -> None:
        """Forget a key entirely — primary AND every replica. For dropping
        a single slot of a replicated key, use :meth:`remove_replica`."""
        with self._lock:
            self._entries.pop(key, None)
            self._replica_entries.pop(key, None)

    # -------------------------------------------------------------- versions
    def record_version(self, graph_id: str, key, version: int) -> bool:
        """Record that ``graph_id`` is now served by plan ``key`` at
        ``version``. A NEWER version invalidates the superseded key — its
        primary placement and every replica drop, so a forwarded request
        can never resolve to a host still holding the retired epoch (it
        re-places the new key instead). A stale or duplicate publish
        (``version <=`` the recorded one) is ignored, which makes
        concurrent/out-of-order announcements from several hosts converge:
        the call is a pure function of ``(graph_id, key, version)`` against
        the monotone version chain. Returns True when the record advanced.
        """
        with self._lock:
            cur = self._versions.get(graph_id)
            if cur is not None:
                cur_key, cur_ver = cur
                if version <= cur_ver:
                    return False
                if cur_key != key:
                    dropped = int(self._entries.pop(cur_key, None)
                                  is not None)
                    dropped += len(self._replica_entries.pop(cur_key, ()))
                    self.version_invalidations += dropped
            self._versions[graph_id] = (key, int(version))
            return True

    def current_version(self, graph_id: str) -> Optional[Tuple[object, int]]:
        """The recorded ``(plan key, version)`` of ``graph_id`` (None if
        the graph was never versioned through this directory)."""
        with self._lock:
            return self._versions.get(graph_id)

    # -------------------------------------------------------------- replicas
    def replicas(self, key) -> List[Placement]:
        """The live replica set of ``key``, primary first.

        Resolves (placing if unseen, promoting if the primary went stale)
        like :meth:`place`, and lazily scrubs stale extras — the returned
        list always has >= 1 element and element 0 is the primary.
        """
        with self._lock:
            primary = self._resolve_primary_locked(key)
            return [primary] + self._scrub_replicas_locked(key)

    def add_replica(self, key, host: int, device: int) -> Placement:
        """Record that ``key``'s plan is (being) staged on ``(host, device)``
        too. Epoch-stamped with the host's CURRENT epoch, like a primary
        placement. Idempotent: re-adding a live replica (or the primary's
        own slot) returns the existing placement. Raises on unknown hosts
        or out-of-range devices.
        """
        with self._lock:
            hinfo = self._hosts.get(host)
            if hinfo is None:
                raise KeyError(f"unknown host rank {host}")
            if not 0 <= device < hinfo.n_devices:
                raise ValueError(
                    f"host {host} has {hinfo.n_devices} devices, "
                    f"no device {device}")
            primary = self._resolve_primary_locked(key)
            if (primary.host, primary.device) == (host, device):
                return primary
            live = self._scrub_replicas_locked(key)
            for e in live:
                if (e.host, e.device) == (host, device):
                    return e
            ent = Placement(host, device, hinfo.epoch)
            self._replica_entries.setdefault(key, []).append(ent)
            self.replicas_added += 1
            return ent

    def remove_replica(self, key, host: int, device: int) -> bool:
        """Drop ONE replica of ``key``. Removing an extra replica leaves the
        primary and the other replicas untouched; removing the primary's
        slot promotes the first surviving replica (the key is only
        forgotten when its last replica goes). Returns True if a replica
        was actually removed.
        """
        with self._lock:
            primary = self._entries.get(key)
            if primary is not None and (primary.host,
                                        primary.device) == (host, device):
                del self._entries[key]
                self.replicas_removed += 1
                self._promote_locked(key)
                return True
            lst = self._replica_entries.get(key)
            if not lst:
                return False
            keep = [e for e in lst if (e.host, e.device) != (host, device)]
            if len(keep) == len(lst):
                return False
            if keep:
                self._replica_entries[key] = keep
            else:
                del self._replica_entries[key]
            self.replicas_removed += 1
            return True

    # --------------------------------------------------------------- liveness
    def update_host(self, host: HostInfo) -> int:
        """(Re-)announce a host. A newer epoch invalidates every entry the
        host owned under older epochs — a restarted process lost its plan
        cache, so stale placements must not keep forwarding traffic to
        plans that no longer exist. Returns the number invalidated.
        A brand-new rank joins the ring (its arcs move ~1/slots of keys).

        A changed DEVICE COUNT at the same epoch (the default directory
        guessed a homogeneous fleet; the handshake learned the truth)
        also invalidates the host's entries that point past the corrected
        slot table — a placement on a device that does not exist must
        re-place, and dangling entries would silently fall out of the
        load accounting otherwise.
        """
        with self._lock:
            prev = self._hosts.get(host.process_index)
            self._hosts[host.process_index] = host
            if prev is None or prev.n_devices != host.n_devices:
                self._rebuild_ring_locked()
            if prev is not None and prev.epoch != host.epoch:
                stale = [k for k, e in self._entries.items()
                         if e.host == host.process_index
                         and e.epoch != host.epoch]
            elif prev is not None and prev.n_devices != host.n_devices:
                stale = [k for k, e in self._entries.items()
                         if e.host == host.process_index
                         and e.device >= host.n_devices]
            else:
                stale = []
            for k in stale:
                del self._entries[k]
                # a surviving replica (on another host, or stamped with the
                # new epoch) takes over instead of the key being forgotten
                self._promote_locked(k)
            self.epoch_invalidations += len(stale)
            for k in list(self._replica_entries):
                self._scrub_replicas_locked(k)
            return len(stale)

    def evict_host(self, process_index: int) -> int:
        """Remove a host from the ring (crashed / drained): its entries drop
        and its keys re-place onto the survivors on next lookup. Returns
        the number of entries dropped. Evicting the last host raises.
        """
        with self._lock:
            if process_index not in self._hosts:
                return 0
            if len(self._hosts) == 1:
                raise ValueError("cannot evict the last live host")
            del self._hosts[process_index]
            self._rebuild_ring_locked()
            dead = [k for k, e in self._entries.items()
                    if e.host == process_index]
            dropped = 0
            for k in dead:
                del self._entries[k]
                # evicting one replica (the primary's host) must not drop
                # the plan's other replicas: promote a survivor if any
                if self._promote_locked(k) is None:
                    dropped += 1
            self.evicted_placements += dropped
            for k in list(self._replica_entries):
                self._scrub_replicas_locked(k)
            return dropped

    # ------------------------------------------------------------------ stats
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def host_placement_counts(self) -> Dict[int, int]:
        """Live placements per host rank (0 for hosts with none)."""
        with self._lock:
            counts = {p: 0 for p in self._hosts}
            for ent in self._entries.values():
                if ent.host in counts:
                    counts[ent.host] += 1
            return counts

    def stats(self) -> Dict[str, object]:
        with self._lock:
            per_host = {p: 0 for p in self._hosts}
            for ent in self._entries.values():
                per_host[ent.host] = per_host.get(ent.host, 0) + 1
            return {
                "hosts": len(self._hosts),
                "slots": len(self._slots),
                "placements": len(self._entries),
                "host_placements": [per_host[p] for p in sorted(per_host)],
                "placement_overrides": self.placement_overrides,
                "epoch_invalidations": self.epoch_invalidations,
                "evicted_placements": self.evicted_placements,
                "replicated_keys": sum(
                    1 for lst in self._replica_entries.values() if lst),
                "replica_entries": sum(
                    len(lst) for lst in self._replica_entries.values()),
                "replicas_added": self.replicas_added,
                "replicas_removed": self.replicas_removed,
                "replica_promotions": self.replica_promotions,
                "replica_invalidations": self.replica_invalidations,
                "versioned_graphs": len(self._versions),
                "version_invalidations": self.version_invalidations,
            }
