"""Device-partitioned plan cache: each partition plan resident on ONE device.

A single host's :class:`~repro.core.plan_cache.PlanCache` caps the serving
working set at what one device's HBM holds. :class:`FleetPlanCache` wraps a
per-device shard of ``PlanCache`` behind a placement policy so the fleet's
aggregate plan capacity grows with device count:

* **consistent-hash placement** — a graph's content hash lands on a hash
  ring (:class:`ConsistentHashRing`, virtual nodes per device), so the same
  graph always lands on the same device across processes and restarts, and
  resizing the fleet remaps only ~1/d of the keys;
* **load-aware override** — when the ring's choice is already far fuller
  than the emptiest shard (more than ``load_spread`` plans apart), the plan
  goes to the least-loaded shard instead. Placements are sticky: once a key
  is placed, later lookups go to the recorded shard, so the override never
  strands a cached plan.

Staging: the owning shard's plans have their device arrays ``device_put``
onto the owning device, so a fleet dispatch reads slabs from local memory —
the plan is *resident on exactly one device* by default. Hot plans can be
**replicated**: :meth:`FleetPlanCache.add_replica` stages an independent
copy of the primary's plan on another device's shard (independent because
``_ensure_staged`` mutates plans in place — a shared object would yank the
primary's slabs off its device), and :meth:`FleetPlanCache.drop_replica`
demotes a cold copy. The primary placement is never dropped by demotion.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import threading
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax

from ..core.graph import CSRGraph
from ..core.plan_cache import (
    PartitionConfig, PartitionPlan, PlanCache, graph_content_hash,
    build_partition_plan,
)

__all__ = ["ConsistentHashRing", "FleetPlanCache"]


class ConsistentHashRing:
    """Classic consistent-hash ring over integer member ids.

    ``vnodes`` virtual points per member smooth the arc lengths; lookup is
    a bisect over the sorted point list. Members are the fleet's device
    indices — adding/removing a device moves only the keys on its arcs.

    ``labels`` optionally names each member's ring points (same length as
    ``members``). Point positions depend only on the label, so a caller
    whose member ids are *indices into a mutable slot table* (the
    cross-host placement directory) keeps surviving keys stationary when
    the table shrinks: rebuild the ring with the surviving labels and only
    the removed member's arcs move.
    """

    def __init__(self, members: Sequence[int], vnodes: int = 64,
                 labels: Optional[Sequence[str]] = None):
        members = list(members)
        if not members:
            raise ValueError("hash ring needs >= 1 member")
        if labels is not None and len(labels) != len(members):
            raise ValueError(
                f"{len(labels)} labels for {len(members)} members")
        self.vnodes = vnodes
        self._points: List[Tuple[int, int]] = []
        for j, m in enumerate(members):
            label = labels[j] if labels is not None else f"dev{m}"
            for v in range(vnodes):
                h = hashlib.blake2b(f"{label}#v{v}".encode(),
                                    digest_size=8).digest()
                self._points.append((int.from_bytes(h, "big"), int(m)))
        self._points.sort()
        self._keys = [p[0] for p in self._points]

    def lookup(self, key: str) -> int:
        """Member owning ``key`` (first ring point clockwise of its hash)."""
        h = int.from_bytes(
            hashlib.blake2b(key.encode(), digest_size=8).digest(), "big")
        i = bisect.bisect_right(self._keys, h) % len(self._points)
        return self._points[i][1]


class FleetPlanCache:
    """Per-device :class:`PlanCache` shards behind one placement policy.

    Drop-in for the single ``PlanCache`` where the serving engine is
    concerned (``get_or_build`` / ``get_by_key`` / ``stats`` / ``builds``…),
    plus :meth:`device_index_of` so the fleet engine can group dispatches
    by owning device. ``capacity_per_device`` bounds each shard, so total
    fleet capacity is ``capacity_per_device * len(devices)``.
    """

    def __init__(self, devices: Optional[Sequence] = None, *,
                 capacity_per_device: int = 32,
                 load_spread: int = 4,
                 vnodes: int = 64,
                 save_dir: Optional[str] = None):
        self.devices = list(devices if devices is not None else jax.devices())
        if not self.devices:
            raise ValueError("FleetPlanCache needs >= 1 device")
        self.capacity_per_device = capacity_per_device
        self.load_spread = load_spread
        # shards share one spill dir: spill names are content-hashed, so a
        # plan evicted from shard 3 can be reloaded by any shard later
        self.shards: List[PlanCache] = [
            PlanCache(capacity_per_device, save_dir=save_dir)
            for _ in self.devices]
        self.ring = ConsistentHashRing(range(len(self.devices)), vnodes)
        self._lock = threading.Lock()
        self._placements: Dict[Tuple[str, PartitionConfig], int] = {}
        # keys whose build is in flight (placed, not yet inserted into the
        # owning shard): exempt from placement pruning, refcounted because
        # several threads can be waiting on one single-flight build
        self._building: Dict[Tuple[str, PartitionConfig], int] = {}
        # extra replica devices per key (primary NOT included); replicated
        # and pinned keys are exempt from placement pruning
        self._replicas: Dict[Tuple[str, PartitionConfig], List[int]] = {}
        self._pinned: Set[Tuple[str, PartitionConfig]] = set()
        # version pins route to the shard that was serving the key when its
        # first reader pinned it — the placement may be gone by unpin time
        # (publish retires superseded keys), so the shard is remembered here
        self._vpins: Dict[Tuple[str, PartitionConfig], int] = {}
        self.placement_overrides = 0   # load-aware departures from the ring
        self.replicas_added = 0
        self.replicas_removed = 0

    # ------------------------------------------------------------- placement
    def device_index_of(self, key: Tuple[str, PartitionConfig]) -> int:
        """Owning device index of ``key`` (placing it if never seen)."""
        with self._lock:
            return self._place_locked(key)

    def pin(self, key: Tuple[str, PartitionConfig], device_index: int) -> int:
        """Pre-record an externally-decided placement for ``key``.

        The cross-host placement directory decides (host, device) fleet-wide;
        the owning host pins the directory's *device* choice here so its
        local shard placement agrees with what every other host believes.
        Sticky like any other placement: an existing placement wins (the
        plan is already resident there) and is returned.
        """
        if not 0 <= device_index < len(self.devices):
            raise ValueError(
                f"pin({device_index}) outside the {len(self.devices)}-device "
                f"fleet")
        with self._lock:
            self._pinned.add(key)
            return self._placements.setdefault(key, int(device_index))

    def _place_locked(self, key: Tuple[str, PartitionConfig]) -> int:
        dev = self._placements.get(key)
        if dev is not None:
            return dev
        dev = self.ring.lookup(key[0])
        sizes = [len(s) for s in self.shards]
        least = min(range(len(sizes)), key=sizes.__getitem__)
        if sizes[dev] - sizes[least] > self.load_spread:
            dev = least
            self.placement_overrides += 1
        self._placements[key] = dev
        # stickiness only matters while the plan is resident: once the
        # placement map outgrows the fleet's live capacity, drop entries
        # whose plan the owning shard has since evicted. A later lookup
        # re-places them with CURRENT load data (and this bounds the map
        # under one-off-graph churn instead of leaking per distinct graph).
        cap = 2 * self.capacity_per_device * len(self.shards)
        if len(self._placements) > cap:
            # exempt the key just placed and every in-flight build: their
            # plans have not been inserted into the owning shard yet, and a
            # pruned-mid-build placement would re-place later (possibly on
            # another shard) leaving a duplicate resident copy. Also exempt
            # pinned keys (the cross-host directory dictated their device —
            # re-placing would disagree with every other host) and keys with
            # a resident copy on ANY replica shard, not just the primary:
            # dropping the placement of a replicated key would strand its
            # replica copies and double-stage the plan on re-lookup.
            self._placements = {
                k: d for k, d in self._placements.items()
                if k == key or k in self._building or k in self._pinned
                or k in self.shards[d]
                or any(k in self.shards[r]
                       for r in self._replicas.get(k, ()))}
        return dev

    # -------------------------------------------------------------- replicas
    def replica_devices(self, key: Tuple[str, PartitionConfig]) -> List[int]:
        """Device indices holding ``key``'s plan, primary first.

        Extras whose shard has since LRU-evicted the copy are lazily
        dropped. Does NOT place unseen keys — an unplaced key returns [].
        """
        with self._lock:
            primary = self._placements.get(key)
            if primary is None:
                return []
            extras = self._replicas.get(key)
            if extras:
                live = [d for d in extras if key in self.shards[d]]
                if len(live) != len(extras):
                    self.replicas_removed += len(extras) - len(live)
                    if live:
                        self._replicas[key] = live
                    else:
                        del self._replicas[key]
                extras = live
            return [primary] + list(extras or [])

    def add_replica(self, key: Tuple[str, PartitionConfig],
                    device_index: int) -> bool:
        """Stage an independent copy of ``key``'s plan on another device.

        The copy's slabs/inv_perm are ``device_put`` onto the target via a
        ``dataclasses.replace`` clone — the primary plan object is mutated
        in place by ``_ensure_staged``, so sharing it would move the
        primary's arrays. Idempotent; returns False when the primary has
        no resident plan to copy (nothing staged).
        """
        if not 0 <= device_index < len(self.devices):
            raise ValueError(
                f"add_replica({device_index}) outside the "
                f"{len(self.devices)}-device fleet")
        with self._lock:
            primary = self._placements.get(key)
            if primary is None or device_index == primary:
                return primary is not None and device_index == primary
            if device_index in self._replicas.get(key, ()):
                return True
        plan = self.shards[primary].lookup(key)
        if plan is None:
            return False
        device = self.devices[device_index]
        copy = dataclasses.replace(
            plan,
            slabs={k: (jax.device_put(v, device) if hasattr(v, "shape")
                       else v)
                   for k, v in plan.slabs.items()},
            inv_perm=jax.device_put(plan.inv_perm, device))
        self.shards[device_index].put(copy)
        with self._lock:
            lst = self._replicas.setdefault(key, [])
            if device_index not in lst:
                lst.append(device_index)
                self.replicas_added += 1
        return True

    def drop_replica(self, key: Tuple[str, PartitionConfig],
                     device_index: int) -> bool:
        """Demote one replica copy. The PRIMARY placement is never dropped
        here — demotion only trims extras, so a cold streak can never
        un-place a plan (use ``clear`` or shard eviction for that)."""
        with self._lock:
            lst = self._replicas.get(key)
            if not lst or device_index not in lst:
                return False
            lst.remove(device_index)
            if not lst:
                del self._replicas[key]
            self.replicas_removed += 1
        self.shards[device_index].remove(key)
        return True

    def plan_on(self, key: Tuple[str, PartitionConfig],
                device_index: int) -> Optional[PartitionPlan]:
        """The resident plan copy on one specific shard (None if absent)."""
        return self.shards[device_index].lookup(key)

    # -------------------------------------------------------- version chain
    def pin_version(self, key: Tuple[str, PartitionConfig]) -> int:
        """Pin a reader's plan version on its serving shard (see
        :meth:`~repro.core.plan_cache.PlanCache.pin`). Returns the new
        refcount, or 0 when the key has no placement to pin against."""
        with self._lock:
            dev = self._vpins.get(key)
            if dev is None:
                dev = self._placements.get(key)
                if dev is None:
                    return 0
                self._vpins[key] = dev
        return self.shards[dev].pin(key)

    def unpin_version(self, key: Tuple[str, PartitionConfig]) -> int:
        """Release one reader pin (reclaims a retired version when the last
        pin drains). Routed by the shard remembered at pin time — the
        placement itself may already belong to a successor version."""
        with self._lock:
            dev = self._vpins.get(key)
        if dev is None:
            return 0
        c = self.shards[dev].unpin(key)
        if c == 0:
            with self._lock:
                self._vpins.pop(key, None)
        return c

    def retire(self, key: Tuple[str, PartitionConfig]) -> bool:
        """Retire a superseded key on EVERY shard (see
        :meth:`~repro.core.plan_cache.PlanCache.retire`) and drop its
        placement / replica / pin bookkeeping. The NON-owning hosts of a
        multihost mutation use this: they have no successor plan to
        publish locally, but a stale copy of the retired version (e.g. a
        replica staged onto this host) must not outlive its epoch. Returns
        True if any shard actually held the key."""
        any_retired = False
        for s in self.shards:
            any_retired = s.retire(key) or any_retired
        with self._lock:
            self._placements.pop(key, None)
            self._replicas.pop(key, None)
            self._pinned.discard(key)
        return any_retired

    def publish(self, plan: PartitionPlan, retire_key=None) -> PartitionPlan:
        """Publish the next version of a graph's plan fleet-wide (same
        shape as :meth:`PlanCache.publish`, which makes the serving
        engines' publish hook cache-agnostic):

        1. the new key inherits the retired key's PRIMARY device (sticky
           placement across versions — replicas, pinned directories, and
           warmed HBM stay meaningful), staged and inserted atomically on
           that shard;
        2. every replica device of the retired key gets a re-staged copy
           of the NEW version (hot graphs stay hot through a mutation);
        3. the retired key drops from every shard (parking per-shard where
           readers still pin it), its placement, replica list, and pin
           marker with it.
        """
        with self._lock:
            primary = None
            extras: List[int] = []
            if retire_key is not None:
                primary = self._placements.get(retire_key)
                extras = list(self._replicas.get(retire_key, ()))
            if primary is None:
                primary = self._place_locked(plan.key)
            else:
                self._placements[plan.key] = primary
            if retire_key in self._pinned:
                self._pinned.add(plan.key)
        staged = self._ensure_staged(plan, self.devices[primary])
        self.shards[primary].publish(staged)
        for dev in extras:
            self.add_replica(plan.key, dev)
        if retire_key is not None and retire_key != plan.key:
            for s in self.shards:
                s.retire(retire_key)
            with self._lock:
                self._placements.pop(retire_key, None)
                self._replicas.pop(retire_key, None)
                self._pinned.discard(retire_key)
        return staged

    # --------------------------------------------------------------- lookups
    def get_or_build(self, g: CSRGraph, cfg: PartitionConfig) -> PartitionPlan:
        key = (graph_content_hash(g), cfg)
        return self.get_by_key(
            key, lambda: build_partition_plan(g, cfg, graph_hash=key[0]))

    def get_by_key(self, key: Tuple[str, PartitionConfig],
                   build_fn: Callable[[], PartitionPlan]) -> PartitionPlan:
        # place AND register the in-flight build in ONE lock hold: a prune
        # racing between the two could otherwise drop the fresh placement
        # (key not yet in _building nor in any shard) and let a later
        # lookup re-place the key while the first copy builds — two
        # resident copies of one plan
        with self._lock:
            dev_idx = self._place_locked(key)
            self._building[key] = self._building.get(key, 0) + 1
        device = self.devices[dev_idx]
        try:
            plan = self.shards[dev_idx].get_by_key(key, build_fn)
        finally:
            with self._lock:
                n = self._building.get(key, 1) - 1
                if n <= 0:
                    self._building.pop(key, None)
                else:
                    self._building[key] = n
        return self._ensure_staged(plan, device)

    def lookup(self, key: Tuple[str, PartitionConfig]) -> Optional[PartitionPlan]:
        with self._lock:
            dev_idx = self._placements.get(key)
        if dev_idx is None:
            return None
        return self.shards[dev_idx].lookup(key)

    @staticmethod
    def _ensure_staged(plan: PartitionPlan, device) -> PartitionPlan:
        """Commit the plan's device arrays to the owning device (idempotent).

        Mutates the shared plan object in place: the staged arrays replace
        the unstaged ones for every holder, and re-staging an already-local
        array is a no-op transfer. Races between threads write equivalent
        values, so no lock is needed.
        """
        probe = plan.slabs["colidx"]
        if getattr(probe, "devices", lambda: None)() == {device}:
            return plan
        plan.slabs = {
            k: (jax.device_put(v, device) if hasattr(v, "shape") else v)
            for k, v in plan.slabs.items()}
        plan.inv_perm = jax.device_put(plan.inv_perm, device)
        return plan

    # ----------------------------------------------------------------- admin
    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def __contains__(self, key) -> bool:
        return any(key in s for s in self.shards)

    def clear(self) -> None:
        for s in self.shards:
            s.clear()
        with self._lock:
            self._placements.clear()
            self._replicas.clear()
            self._pinned.clear()

    def keys(self):
        out = []
        for s in self.shards:
            out.extend(s.keys())
        return out

    # aggregate counters, mirroring the PlanCache attribute API the tests
    # and engine use (reads are sums over shard snapshots)
    @property
    def builds(self) -> int:
        return sum(s.stats()["builds"] for s in self.shards)

    @property
    def hits(self) -> int:
        return sum(s.stats()["hits"] for s in self.shards)

    @property
    def misses(self) -> int:
        return sum(s.stats()["misses"] for s in self.shards)

    def stats(self) -> Dict[str, float]:
        """Aggregate counters + per-shard occupancy (for balance stats)."""
        per = [s.stats() for s in self.shards]
        agg: Dict[str, float] = {}
        for k in ("size", "lookups", "hits", "misses", "builds", "evictions",
                  "spills", "disk_hits", "device_bytes", "publishes", "pins",
                  "retired_versions", "retired_reclaimed", "retired_live"):
            agg[k] = sum(p[k] for p in per)
        total = agg["hits"] + agg["misses"]
        agg["capacity"] = self.capacity_per_device * len(self.shards)
        agg["hit_rate"] = agg["hits"] / total if total else 0.0
        agg["devices"] = len(self.devices)
        agg["shard_sizes"] = [p["size"] for p in per]
        agg["shard_bytes"] = [p["device_bytes"] for p in per]
        with self._lock:
            agg["placements"] = len(self._placements)
            agg["placement_overrides"] = self.placement_overrides
            agg["replicated_keys"] = sum(
                1 for lst in self._replicas.values() if lst)
            agg["replica_copies"] = sum(
                len(lst) for lst in self._replicas.values())
            agg["replicas_added"] = self.replicas_added
            agg["replicas_removed"] = self.replicas_removed
            agg["pinned"] = len(self._pinned)
        return agg
