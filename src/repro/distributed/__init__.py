"""Fleet execution: sharded SpMM dispatch + device-partitioned plan cache.

Three layers (ISSUE 4 / ROADMAP "shard hot plans across devices"):

* :mod:`repro.distributed.shard_spmm` — ``shard_map``-based SpMM over
  :func:`repro.launch.mesh.graph_mesh`: feature sharding (zero-comm column
  split) and block sharding (round-robin blocks, psum partials);
* :mod:`repro.distributed.placement` — :class:`FleetPlanCache`, per-device
  ``PlanCache`` shards behind consistent-hash + load-aware placement;
* :mod:`repro.serve.fleet` — ``FleetGraphEngine``, the continuous-batching
  engine whose flush groups work by owning device and launches per-device
  dispatches concurrently.
"""
from .placement import ConsistentHashRing, FleetPlanCache
from .shard_spmm import (
    prepare_block_shards,
    prepare_feature_shards,
    round_robin_block_order,
    spmm_block_sharded,
    spmm_feature_sharded,
)

__all__ = [
    "ConsistentHashRing",
    "FleetPlanCache",
    "prepare_block_shards",
    "prepare_feature_shards",
    "round_robin_block_order",
    "spmm_block_sharded",
    "spmm_feature_sharded",
]
