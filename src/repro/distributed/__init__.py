"""Fleet execution: sharded SpMM dispatch + partitioned plan placement.

Four layers (ISSUE 4 "shard hot plans across devices" + ISSUE 5 cross-host):

* :mod:`repro.distributed.shard_spmm` — ``shard_map``-based SpMM over
  :func:`repro.launch.mesh.graph_mesh`: feature sharding (zero-comm column
  split) and block sharding (round-robin blocks, psum partials — also over
  the GLOBAL multi-host mesh);
* :mod:`repro.distributed.placement` — :class:`FleetPlanCache`, per-device
  ``PlanCache`` shards behind consistent-hash + load-aware placement;
* :mod:`repro.distributed.directory` — :class:`PlacementDirectory`, the
  level above: ``plan_key -> (host, device)`` across a multi-process fleet
  (consistent-hash over every host's device slots, epoch-stamped entries,
  stale-host eviction);
* :mod:`repro.distributed.replication` — :class:`ReplicaManager`, EWMA
  request-rate tracking driving hot-plan replica promotion/demotion (the
  AWB-GCN runtime-rebalancing idea applied to the placement layer);
* :mod:`repro.distributed.multihost` — ``jax.distributed`` rendezvous,
  the TCP forwarding data plane (:class:`PeerServer`/:class:`PeerClient`),
  and the CPU-only multi-subprocess CI harness (:func:`run_cpu_fleet`).

The serving entry points sit in :mod:`repro.serve.fleet`
(``FleetGraphEngine`` per host, ``MultihostGraphEngine`` across hosts).
"""
from .directory import HostInfo, Placement, PlacementDirectory
from .multihost import (
    FrontierExchange,
    MultihostContext,
    PeerClient,
    PeerServer,
    free_port,
    initialize_multihost,
    peer_ports,
    run_cpu_fleet,
)
from .placement import ConsistentHashRing, FleetPlanCache
from .replication import EwmaRate, ReplicaManager
from .shard_spmm import (
    prepare_block_shards,
    prepare_feature_shards,
    round_robin_block_order,
    spmm_block_sharded,
    spmm_feature_sharded,
)

__all__ = [
    "ConsistentHashRing",
    "EwmaRate",
    "FleetPlanCache",
    "FrontierExchange",
    "HostInfo",
    "ReplicaManager",
    "MultihostContext",
    "PeerClient",
    "PeerServer",
    "Placement",
    "PlacementDirectory",
    "free_port",
    "initialize_multihost",
    "peer_ports",
    "prepare_block_shards",
    "prepare_feature_shards",
    "round_robin_block_order",
    "run_cpu_fleet",
    "spmm_block_sharded",
    "spmm_feature_sharded",
]
