"""Sharded SpMM dispatch: the Accel-GCN block schedule over a device mesh.

Two strategies, both ``shard_map`` over :func:`repro.launch.mesh.graph_mesh`
(CPU-validated with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``,
so the suite exercises real multi-device semantics without hardware):

* **feature sharding** (:func:`spmm_feature_sharded`) — the paper's
  combined-warp column parallelism lifted to device granularity. Each
  device owns a contiguous ``F_pad / d`` column shard of the dense X and
  runs the FULL block schedule on it: slabs replicated, X sharded on its
  feature axis, output sharded the same way, ZERO cross-device
  communication. The per-device work is exactly the single-device kernel
  with a narrower F, so any per-device backend is sound.

* **block sharding** (:func:`spmm_block_sharded`) — for one giant graph
  whose features are too narrow to split. The partition plan's blocks are
  placed round-robin across devices (:func:`round_robin_block_order`):
  the partitioner emits blocks in degree-sorted order, so interleaving
  spreads the heavy dense-row blocks and the light multi-row blocks evenly
  — AWB-GCN's workload rebalancing across processing elements, applied at
  device granularity. X is replicated (all-gathered once), each device
  scatters its block subset into a full-height partial result, and a
  ``psum`` over the mesh adds the per-device row slabs back together
  (split rows — degree > C, continued across blocks that may now live on
  different devices — are exactly why the combine is an add).

Both paths run the portable jnp slab twin (``ops.spmm_blocked``) inside
``shard_map`` — same slab layout and math as the Pallas kernels, and the
multi-device semantics (specs, collectives, balance) are identical to what
the per-device Pallas call will see on hardware (the real-TPU flip is the
existing ROADMAP item).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.ops import spmm_blocked

__all__ = [
    "round_robin_block_order",
    "prepare_feature_shards",
    "prepare_block_shards",
    "commit_block_shards_global",
    "spmm_feature_sharded",
    "spmm_block_sharded",
]


def round_robin_block_order(num_blocks: int, n_devices: int
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Round-robin block -> device placement, as a device-contiguous order.

    Block ``i`` goes to device ``i % n_devices``; blocks are then laid out
    device-major so a ``shard_map`` split along the block axis hands device
    ``k`` exactly its assignment. The block count is padded up to a multiple
    of ``n_devices`` (padding indices ``>= num_blocks`` are sentinel blocks
    the caller must append).

    Returns ``(order, live_counts)``: ``order`` is the int64 permutation of
    ``ceil(B/d)*d`` block slots (device-major), ``live_counts[k]`` the
    number of REAL blocks device ``k`` received. Round-robin guarantees
    ``max(live_counts) - min(live_counts) <= 1`` for every (B, d).
    """
    if num_blocks < 0 or n_devices < 1:
        raise ValueError(f"bad {num_blocks=} / {n_devices=}")
    per = -(-num_blocks // n_devices) if num_blocks else 1
    b_pad = per * n_devices
    idx = np.arange(b_pad, dtype=np.int64)
    # stable sort by assigned device keeps each device's blocks in original
    # (degree-sorted) order — fp reduction order within a device unchanged
    order = np.argsort(idx % n_devices, kind="stable")
    live = np.bincount(idx[idx < num_blocks] % n_devices,
                       minlength=n_devices).astype(np.int64)
    return order, live


def _pad_blocks(slabs: Dict, b_pad: int, n_rows: int) -> Dict[str, np.ndarray]:
    """Host-side copy of the slab arrays padded to ``b_pad`` blocks.

    Padding blocks carry value 0, in-bounds colidx, rowloc pointing at the
    last slab row, and the drop sentinel ``n_rows`` as their output row —
    the same convention as the batched merge, so they contribute nothing.
    """
    colidx = np.asarray(slabs["colidx"], dtype=np.int32)
    values = np.asarray(slabs["values"], dtype=np.float32)
    rowloc = np.asarray(slabs["rowloc"], dtype=np.int32)
    out_row = np.asarray(slabs["out_row"], dtype=np.int32)
    B = colidx.shape[0]
    R = out_row.shape[1]
    pad = b_pad - B
    if pad > 0:
        colidx = np.pad(colidx, ((0, pad), (0, 0)))
        values = np.pad(values, ((0, pad), (0, 0)))
        rowloc = np.pad(rowloc, ((0, pad), (0, 0)), constant_values=R - 1)
        out_row = np.pad(out_row, ((0, pad), (0, 0)), constant_values=n_rows)
    return {"colidx": colidx, "values": values, "rowloc": rowloc,
            "out_row": out_row}


def prepare_feature_shards(slabs: Dict) -> Tuple[jax.Array, ...]:
    """Host-uncommitted copies of the slab arrays for the replicated specs.

    One host round-trip per plan — a serving engine should memoize the
    result per plan and reuse it across dispatches (the slab contents are
    immutable once the plan is built).
    """
    return (jnp.asarray(np.asarray(slabs["colidx"], dtype=np.int32)),
            jnp.asarray(np.asarray(slabs["values"], dtype=np.float32)),
            jnp.asarray(np.asarray(slabs["rowloc"], dtype=np.int32)),
            jnp.asarray(np.asarray(slabs["out_row"], dtype=np.int32)))


def spmm_feature_sharded(slabs: Dict, x: jax.Array, n_rows: int, mesh: Mesh,
                         *, prepared: Optional[Tuple[jax.Array, ...]] = None
                         ) -> jax.Array:
    """A' @ X with X column-sharded over ``mesh``; zero communication.

    Each device runs the full block schedule on its contiguous F-shard;
    the output comes back column-sharded and is sliced to the caller's F.
    Per-column reduction order is untouched, so the result matches the
    single-device slab path bitwise per column. ``prepared`` takes a
    memoized :func:`prepare_feature_shards` result (recurring-graph
    serving) instead of re-copying the slabs.
    """
    d = int(mesh.devices.size)
    F = int(x.shape[1])
    f_shard = -(-F // d)
    x_p = jnp.asarray(x, dtype=jnp.float32)
    if f_shard * d != F:
        x_p = jnp.pad(x_p, ((0, 0), (0, f_shard * d - F)))

    colidx, values, rowloc, out_row = (
        prepared if prepared is not None else prepare_feature_shards(slabs))
    fn = shard_map(
        functools.partial(spmm_blocked, n_rows=int(n_rows)),
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(None, "dev")),
        out_specs=P(None, "dev"),
    )
    out = fn(colidx, values, rowloc, out_row, x_p)
    return out[:, :F]


def prepare_block_shards(slabs: Dict, n_rows: int, n_devices: int
                         ) -> Tuple[Dict[str, jax.Array], np.ndarray]:
    """Round-robin-reorder + pad the slab arrays for a block-sharded
    dispatch: ``(device-major arrays, per-device live block counts)``.

    Deterministic per (plan, device count) — memoize per plan in serving
    so recurring giant graphs pay the O(B*C) host reorder once.
    """
    B = int(np.asarray(slabs["colidx"]).shape[0])
    order, live = round_robin_block_order(B, n_devices)
    padded = _pad_blocks(slabs, len(order), int(n_rows))
    # device-major reorder: shard_map's contiguous split along the block
    # axis now IS the round-robin assignment
    return {k: jnp.asarray(v[order]) for k, v in padded.items()}, live


def _mesh_spans_processes(mesh: Mesh) -> bool:
    """True when ``mesh`` contains another process's (non-addressable)
    devices — the global serving mesh of a multi-host fleet."""
    procs = {d.process_index for d in mesh.devices.flat}
    return len(procs) > 1


@functools.lru_cache(maxsize=32)
def _global_block_sharded_fn(mesh: Mesh, n_rows: int):
    """Jitted multi-host block-shard computation, cached per (mesh,
    n_rows): rebuilding the shard_map closure per call would defeat jit's
    identity-keyed cache and recompile on EVERY global dispatch."""
    def _local(colidx, values, rowloc, out_row, x_rep):
        part = spmm_blocked(colidx, values, rowloc, out_row, x_rep,
                            n_rows=n_rows)
        return jax.lax.psum(part, "dev")

    return jax.jit(shard_map(
        _local,
        mesh=mesh,
        in_specs=(P("dev"), P("dev"), P("dev"), P("dev"), P()),
        out_specs=P(),
    ))


def commit_block_shards_global(arrs: Dict[str, jax.Array], mesh: Mesh
                               ) -> Dict[str, jax.Array]:
    """Commit prepared block-shard slabs to the GLOBAL mesh sharding.

    Every process holds the same host-side value (plans build
    deterministically from the same graph), so ``device_put`` with the
    global sharding just extracts this process's addressable shards.
    Memoize the result per plan (the fleet engine stores it in its prep
    cache) — the slabs are immutable, so the transfer is a one-time cost.
    Already-committed arrays pass through untouched.
    """
    shard = NamedSharding(mesh, P("dev"))
    out = {}
    for k, v in arrs.items():
        if getattr(v, "sharding", None) == shard:
            out[k] = v
        else:
            out[k] = jax.device_put(np.asarray(v), shard)
    return out


def spmm_block_sharded(slabs: Dict, x: jax.Array, n_rows: int, mesh: Mesh,
                       *, prepared: Optional[Tuple[Dict, np.ndarray]] = None
                       ) -> Tuple[jax.Array, np.ndarray]:
    """A' @ X with the plan's blocks round-robin across ``mesh`` devices.

    X is replicated across the mesh; each device scatters its block subset
    into a full ``[n_rows, F]`` partial and a ``psum`` adds the per-device
    row slabs back together. Returns ``(out, live_counts)`` — the per-device
    REAL block counts, the balance evidence the fleet stats export.
    ``prepared`` takes a memoized :func:`prepare_block_shards` result.

    The mesh may be the GLOBAL multi-host mesh
    (:func:`repro.launch.mesh.multihost_graph_mesh`): inputs are then
    committed through explicit ``NamedSharding``s — each process extracts
    its addressable shards from the (host-replicated) arrays, the psum
    crosses hosts, and the replicated output is readable on every host.
    That call is SPMD-collective: EVERY process of the fleet must enter it
    with identical arguments (the ``serve_global`` contract).
    """
    d = int(mesh.devices.size)
    arrs, live = (prepared if prepared is not None
                  else prepare_block_shards(slabs, n_rows, d))

    x = jnp.asarray(x, dtype=jnp.float32)
    if _mesh_spans_processes(mesh):
        # multi-host: explicit global shardings + the cached jitted fn
        # (callers memoize commit_block_shards_global per plan, so the
        # slab transfer is paid once; X is fresh data, committed per call)
        arrs = commit_block_shards_global(arrs, mesh)
        x = jax.device_put(np.asarray(x), NamedSharding(mesh, P()))
        fn = _global_block_sharded_fn(mesh, int(n_rows))
    else:
        def _local(colidx, values, rowloc, out_row, x_rep):
            part = spmm_blocked(colidx, values, rowloc, out_row, x_rep,
                                n_rows=int(n_rows))
            return jax.lax.psum(part, "dev")

        fn = shard_map(
            _local,
            mesh=mesh,
            in_specs=(P("dev"), P("dev"), P("dev"), P("dev"), P()),
            out_specs=P(),
        )
    out = fn(arrs["colidx"], arrs["values"], arrs["rowloc"],
             arrs["out_row"], x)
    return out, live
