"""Multi-host fleet bootstrap + the forwarding data plane.

Three concerns, one module:

* **Rendezvous** — :func:`initialize_multihost` wraps
  ``jax.distributed.initialize`` (coordinator address, process count, rank)
  and returns a :class:`MultihostContext` with the local/global device
  split. On CPU the gloo collectives implementation is selected so
  cross-process ``psum`` works with fake host devices — the same SPMD
  semantics the TPU pods will see, no hardware required.

* **Data plane** — serving forwards *requests*, not collectives: a request
  admitted on host A for a plan owned by host B travels over a plain TCP
  channel (:class:`PeerServer` / :class:`PeerClient`, length-prefixed
  pickled frames) and the answer comes back the same way. Collectives only
  enter for the explicitly-collective global-mesh dispatch
  (``MultihostGraphEngine.serve_global``). The channels carry a
  ``hello`` handshake exchanging ``(process_index, epoch)`` so the
  placement directory learns about restarts. The transport trusts its
  peers (it is an intra-fleet protocol on a private interconnect, like any
  parameter-server wire format) — do not expose the ports publicly.

* **CI harness** — :func:`run_cpu_fleet` spawns N subprocesses, each a
  JAX process with ``XLA_FLAGS=--xla_force_host_platform_device_count=K``
  fake CPU devices, wired together with a free coordinator port and a
  peer-port table published via ``REPRO_MH_*`` env vars. Workers call
  :func:`initialize_multihost` with no arguments (env-driven) and print a
  final JSON line; the harness returns one parsed record per rank. This is
  how the two-process end-to-end tests and the CI smoke job get REAL
  multi-process coverage on a single machine.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import socket
import struct
import subprocess
import sys
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "MultihostContext",
    "initialize_multihost",
    "peer_ports",
    "PeerServer",
    "PeerClient",
    "FrontierExchange",
    "free_port",
    "run_cpu_fleet",
]

# env vars the CPU harness publishes to its worker subprocesses
_ENV_COORD = "REPRO_MH_COORD"
_ENV_NPROCS = "REPRO_MH_NPROCS"
_ENV_PID = "REPRO_MH_PID"
_ENV_PEER_PORTS = "REPRO_MH_PEER_PORTS"
_ENV_EPOCH = "REPRO_MH_EPOCH"


@dataclasses.dataclass
class MultihostContext:
    """One process's view of the fleet after rendezvous."""

    process_index: int
    process_count: int
    coordinator: Optional[str]
    local_devices: List[Any]
    global_devices: List[Any]
    epoch: int = 0

    @property
    def n_local_devices(self) -> int:
        return len(self.local_devices)

    @property
    def n_global_devices(self) -> int:
        return len(self.global_devices)


def initialize_multihost(coordinator_address: Optional[str] = None,
                         num_processes: Optional[int] = None,
                         process_id: Optional[int] = None,
                         *, epoch: Optional[int] = None) -> MultihostContext:
    """Rendezvous this process into the fleet; env-driven when arguments are
    omitted (the CPU harness publishes ``REPRO_MH_*``).

    Must run before any other JAX call touches devices (the usual
    ``jax.distributed.initialize`` contract). A single-process fleet
    (``num_processes`` absent or 1) skips distributed init entirely and
    degrades to the local device set — the engine layers all treat that as
    the one-host case.
    """
    coordinator_address = coordinator_address or os.environ.get(_ENV_COORD)
    if num_processes is None:
        num_processes = int(os.environ.get(_ENV_NPROCS, "1"))
    if process_id is None:
        process_id = int(os.environ.get(_ENV_PID, "0"))
    if epoch is None:
        epoch = int(os.environ.get(_ENV_EPOCH, "0"))

    import jax

    if num_processes > 1:
        if coordinator_address is None:
            raise ValueError(
                f"multi-process fleet ({num_processes} processes) needs a "
                f"coordinator address (or {_ENV_COORD} in the environment)")
        try:
            # CPU cross-process collectives need gloo; harmless elsewhere
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except Exception:  # noqa: BLE001 — older jax: option absent
            pass
        jax.distributed.initialize(coordinator_address=coordinator_address,
                                   num_processes=num_processes,
                                   process_id=process_id)
    return MultihostContext(
        process_index=(jax.process_index() if num_processes > 1
                       else process_id),
        process_count=(jax.process_count() if num_processes > 1
                       else max(1, num_processes)),
        coordinator=coordinator_address,
        local_devices=list(jax.local_devices()),
        global_devices=list(jax.devices()),
        epoch=epoch,
    )


def peer_ports() -> Dict[int, int]:
    """The harness-published ``rank -> data-plane port`` table (env-driven)."""
    raw = os.environ.get(_ENV_PEER_PORTS, "")
    if not raw:
        return {}
    return {int(r): int(p)
            for r, p in (pair.split(":") for pair in raw.split(","))}


# --------------------------------------------------------------------------
# framed transport
# --------------------------------------------------------------------------
_FRAME_HDR = struct.Struct(">Q")
_MAX_FRAME = 1 << 31      # 2 GiB: a corrupted header must not OOM the host


def _send_frame(sock: socket.socket, obj: Any) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_FRAME_HDR.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed the channel mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> Any:
    (n,) = _FRAME_HDR.unpack(_recv_exact(sock, _FRAME_HDR.size))
    if n > _MAX_FRAME:
        raise ConnectionError(f"oversized frame ({n} bytes)")
    return pickle.loads(_recv_exact(sock, n))


class PeerServer:
    """Data-plane listener: one daemon accept-loop, one thread per peer
    connection, a handler registry keyed by op name.

    Handlers run on the connection thread and may block (e.g. submitting a
    forwarded request into the local scheduler and waiting on its future) —
    each peer connection is its own thread, so one slow request never
    stalls a different peer. Handler exceptions travel back as ``("err",
    repr)`` frames and re-raise caller-side; transport errors surface as
    ``ConnectionError`` so the caller can fail the peer over.
    """

    def __init__(self, port: int = 0, *, host: str = "127.0.0.1",
                 process_index: int = 0, epoch: int = 0,
                 n_devices: int = 1):
        self.process_index = process_index
        self.epoch = epoch
        self.n_devices = n_devices
        self._handlers: Dict[str, Callable[[Any], Any]] = {}
        self._lock = threading.Lock()
        self._conn_threads: List[threading.Thread] = []
        self._closing = False
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.port = self._sock.getsockname()[1]
        self.requests_served = 0
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"peer-server-{self.port}",
            daemon=True)
        self._accept_thread.start()

    def register(self, op: str, fn: Callable[[Any], Any]) -> None:
        with self._lock:
            self._handlers[op] = fn

    def close(self) -> None:
        self._closing = True
        try:
            self._sock.close()
        except OSError:
            pass

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return              # listener closed
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()
            # prune finished handler threads: reconnect-after-reset churn
            # must not grow this list without bound on a long-lived server
            self._conn_threads = [c for c in self._conn_threads
                                  if c.is_alive()]
            self._conn_threads.append(t)

    def _serve_conn(self, conn: socket.socket) -> None:
        with conn:
            try:
                while True:
                    op, payload = _recv_frame(conn)
                    if op == "hello":
                        _send_frame(conn, ("ok", {
                            "process_index": self.process_index,
                            "epoch": self.epoch,
                            "n_devices": self.n_devices}))
                        continue
                    with self._lock:
                        fn = self._handlers.get(op)
                    if fn is None:
                        _send_frame(conn, ("err", f"unknown op {op!r}"))
                        continue
                    try:
                        result = fn(payload)
                    except Exception:  # noqa: BLE001 — ship to the caller
                        _send_frame(conn, ("err", traceback.format_exc()))
                        continue
                    with self._lock:
                        self.requests_served += 1
                    _send_frame(conn, ("ok", result))
            except (ConnectionError, EOFError, OSError):
                return              # peer went away; its thread ends here
            except Exception:  # noqa: BLE001 — corrupt frame/pickle: drop
                return              # the CONNECTION (socket closes, the
                #                     peer reconnects), never the server


class PeerClient:
    """One host's channel to one peer: lazy connect, ``hello`` handshake,
    one in-flight request per channel (a lock serializes; the engine runs
    one forward task per peer per flush, so this is the natural unit).
    """

    def __init__(self, address: Tuple[str, int], *,
                 process_index: int = 0, epoch: int = 0,
                 timeout_s: float = 120.0, connect_timeout_s: float = 30.0):
        self.address = address
        self.process_index = process_index   # OUR rank (sent in the hello)
        self.epoch = epoch
        self.timeout_s = timeout_s
        self.connect_timeout_s = connect_timeout_s
        self.peer_process: Optional[int] = None
        self.peer_epoch: Optional[int] = None
        self.peer_devices: Optional[int] = None
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()

    def _connect_locked(self) -> socket.socket:
        if self._sock is not None:
            return self._sock
        # fleet processes come up asynchronously: a refused connection
        # usually means the peer has not bound its server YET, so retry
        # with backoff until connect_timeout_s before giving up (a dead
        # peer then surfaces as ConnectionError -> directory eviction)
        deadline = time.monotonic() + self.connect_timeout_s
        delay = 0.05
        while True:
            try:
                sock = socket.create_connection(self.address,  # statics: ignore[blocking-call-under-lock] -- the per-channel mutex intentionally serializes connect + one in-flight request; only forwarders block on it
                                                timeout=self.timeout_s)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(delay)  # statics: ignore[blocking-call-under-lock] -- bounded connect backoff under the same per-channel mutex (see above)
                delay = min(delay * 2, 0.5)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        _send_frame(sock, ("hello", {"process_index": self.process_index,
                                     "epoch": self.epoch}))
        status, info = _recv_frame(sock)
        if status != "ok":
            sock.close()
            raise ConnectionError(f"handshake rejected: {info}")
        self.peer_process = int(info["process_index"])
        self.peer_epoch = int(info["epoch"])
        self.peer_devices = int(info.get("n_devices", 1))
        self._sock = sock
        return sock

    def handshake(self) -> Tuple[int, int]:
        """Connect (if needed) and return the peer's ``(rank, epoch)``."""
        with self._lock:
            self._connect_locked()
            return self.peer_process, self.peer_epoch

    def request(self, op: str, payload: Any) -> Any:
        """One round trip; remote handler exceptions re-raise as
        RuntimeError, transport failures as ConnectionError (after which
        the channel is reset so the next request reconnects)."""
        with self._lock:
            sock = self._connect_locked()
            try:
                _send_frame(sock, (op, payload))
                status, result = _recv_frame(sock)
            except (ConnectionError, EOFError, OSError) as e:
                self._reset_locked()
                raise ConnectionError(
                    f"peer {self.address} channel failed: {e}") from e
            if status != "ok":
                raise RuntimeError(f"remote {op!r} failed:\n{result}")
            return result

    def _reset_locked(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._reset_locked()


class FrontierExchange:
    """Cross-partition frontier exchange over the peer data plane.

    The sampling layer partitions the graph store into contiguous node
    ranges, one shard per host; sampling a frontier layer then needs the
    in-edges of REMOTE-owned nodes. This class is both ends of that hop:

    * ``serve(server, store)`` registers the ``"sample-hop"`` op on a
      host's :class:`PeerServer`, answering peers' sample requests from
      the local shard (arrays in, arrays out — one round trip per
      (hop, owner) pair, not per node);
    * ``sampler_for(rank)`` wraps a :class:`PeerClient` into the
      ``SampleFn`` shape :class:`~repro.sampling.store.GraphStore` uses,
      ready to drop into a ``PartitionedStoreClient``'s remote map.

    A transport failure counts one failover, then ONE reconnect retry
    (the channel resets itself on error); a second failure raises —
    unlike plan forwarding there is no local fallback, the remote shard
    is the only holder of those rows. The nightly partitioned-store gate
    asserts ``failovers == 0`` on a healthy fleet.
    """

    OP = "sample-hop"

    def __init__(self, peers: "Dict[int, PeerClient]"):
        self.peers = dict(peers)
        self.failovers = 0
        self.requests = 0
        self._lock = threading.Lock()

    @staticmethod
    def serve(server: "PeerServer", store) -> None:
        """Install the remote end: answer sample requests from ``store``
        (anything with the ``sample_in_neighbors`` signature)."""
        def _handle(payload: Dict[str, Any]) -> Dict[str, Any]:
            src, dst, val = store.sample_in_neighbors(
                np.asarray(payload["nodes"], dtype=np.int64),
                payload["fanout"], seed=int(payload["seed"]),
                hop=int(payload["hop"]),
                replace=bool(payload["replace"]))
            return {"src": src, "dst": dst, "val": val}
        server.register(FrontierExchange.OP, _handle)

    def sampler_for(self, rank: int):
        """A ``SampleFn`` that samples on host ``rank``'s shard."""
        client = self.peers[rank]

        def _sample(nodes, fanout=None, *, seed=0, hop=0, replace=False):
            payload = {"nodes": np.asarray(nodes, dtype=np.int64),
                       "fanout": fanout, "seed": seed, "hop": hop,
                       "replace": replace}
            with self._lock:
                self.requests += 1
            try:
                out = client.request(self.OP, payload)
            except ConnectionError:
                with self._lock:
                    self.failovers += 1
                out = client.request(self.OP, payload)  # channel was reset
            return out["src"], out["dst"], out["val"]

        return _sample

    def remote_map(self) -> Dict[int, Any]:
        """``{rank: SampleFn}`` for every connected peer — the ``remote=``
        argument of a ``PartitionedStoreClient``."""
        return {rank: self.sampler_for(rank) for rank in self.peers}


# --------------------------------------------------------------------------
# CPU-only fleet harness (CI / tests)
# --------------------------------------------------------------------------
def free_port() -> int:
    """An OS-assigned free TCP port (racy in principle, fine for CI)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def run_cpu_fleet(worker_src: str, *, num_processes: int = 2,
                  n_local_devices: int = 4, timeout_s: float = 600.0,
                  extra_env: Optional[Dict[str, str]] = None,
                  cwd: Optional[str] = None) -> List[Dict]:
    """Spawn ``num_processes`` CPU JAX processes running ``worker_src``.

    Each worker gets ``n_local_devices`` fake host devices, the coordinator
    address, its rank, a shared epoch, and the full rank->port table for
    the forwarding data plane, all via ``REPRO_MH_*`` env vars — so the
    worker body is just::

        ctx = initialize_multihost()          # env-driven
        ports = peer_ports()                  # rank -> data-plane port
        ... build directory/engine, serve, and finally ...
        print(json.dumps(record))             # LAST stdout line

    Returns the parsed final-JSON-line of every rank (rank order). Raises
    RuntimeError with the failing rank's tail of stderr on any non-zero
    exit — including when a worker hangs past ``timeout_s`` (all workers
    are killed so CI never wedges).
    """
    coord_port = free_port()
    ports = {r: free_port() for r in range(num_processes)}
    port_table = ",".join(f"{r}:{p}" for r, p in sorted(ports.items()))
    procs = []
    for rank in range(num_processes):
        env = dict(os.environ)
        env.update({
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS":
                f"--xla_force_host_platform_device_count={n_local_devices}",
            _ENV_COORD: f"127.0.0.1:{coord_port}",
            _ENV_NPROCS: str(num_processes),
            _ENV_PID: str(rank),
            _ENV_PEER_PORTS: port_table,
            _ENV_EPOCH: "0",
        })
        if extra_env:
            env.update(extra_env)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", worker_src],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env, cwd=cwd))
    # drain every rank's pipes CONCURRENTLY: waiting on rank 0 while rank
    # 1's pipes sit unread lets rank 1 block on a full 64 KiB pipe buffer
    # mid-collective, wedging rank 0 too — a spurious "hang" with no bug
    outs: List[Optional[Tuple[str, str]]] = [None] * num_processes
    drainers = []
    for rank, p in enumerate(procs):
        t = threading.Thread(
            target=lambda r=rank, pr=p: outs.__setitem__(r, pr.communicate()),
            daemon=True)
        t.start()
        drainers.append(t)
    deadline = time.monotonic() + timeout_s
    for t in drainers:
        t.join(max(0.0, deadline - time.monotonic()))
    if any(t.is_alive() for t in drainers):
        for p in procs:
            p.kill()
        for t in drainers:     # communicate() returns once the kill lands
            t.join(30.0)
        raise RuntimeError(
            f"cpu fleet timed out after {timeout_s}s; rank stderr tails:\n"
            + "\n".join(f"--- rank {r} ---\n{o[1][-2000:]}"
                        for r, o in enumerate(outs) if o))
    records = []
    for rank, p in enumerate(procs):
        out, err = outs[rank]
        if p.returncode != 0:
            raise RuntimeError(
                f"fleet rank {rank} exited {p.returncode}:\n{err[-4000:]}")
        lines = [ln for ln in out.strip().splitlines() if ln.strip()]
        if not lines:
            raise RuntimeError(f"fleet rank {rank} printed no JSON record")
        records.append(json.loads(lines[-1]))
    return records
