"""Hot-plan replication: EWMA request rates drive replica promotion/demotion.

The fleet layers place each plan on exactly one device — correct for
capacity, wrong for zipf-skewed popularity, where a handful of hot graphs
turn their single owning device into the whole fleet's ceiling (the same
workload-imbalance failure Accel-GCN's block-level partition fixes inside a
kernel, recurring one level up). AWB-GCN's answer was runtime rebalancing;
ours is **replica sets**: track each plan's request rate with a decayed
counter, replicate plans whose rate exceeds what one device should absorb
onto the least-loaded devices, and drop replicas again when the rate fades.

This module is deliberately engine-agnostic: :class:`ReplicaManager` talks
to the placement layers through callables (list replicas / add / drop /
per-device load), so the single-host fleet engine wires it to
``FleetPlanCache`` and the multi-host engine can mirror decisions into the
:class:`~repro.distributed.directory.PlacementDirectory` as well.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

__all__ = ["EwmaRate", "ReplicaManager"]

_LN2 = math.log(2.0)


class EwmaRate:
    """Per-key exponentially-decayed request counter -> rate estimate.

    Each observation adds ``n`` to a counter that halves every
    ``halflife_s`` seconds: ``c <- c * 0.5**(dt/halflife) + n``. Under a
    steady rate ``r`` the counter converges to ``r * halflife / ln2``, so
    :meth:`rate` divides back out and reads in requests/second. O(1) per
    observation, no sample buffers; thread-safe.
    """

    def __init__(self, halflife_s: float = 5.0,
                 now_fn: Callable[[], float] = time.monotonic):
        if halflife_s <= 0:
            raise ValueError("halflife_s must be > 0")
        self.halflife_s = float(halflife_s)
        self._now = now_fn
        self._lock = threading.Lock()
        self._counts: Dict[object, float] = {}
        self._stamps: Dict[object, float] = {}

    def observe(self, key, n: int = 1) -> None:
        now = self._now()
        with self._lock:
            c = self._counts.get(key, 0.0)
            t = self._stamps.get(key, now)
            c *= 0.5 ** ((now - t) / self.halflife_s)
            self._counts[key] = c + n
            self._stamps[key] = now

    def rate(self, key) -> float:
        """Estimated requests/second for ``key`` (0.0 if never seen)."""
        now = self._now()
        with self._lock:
            c = self._counts.get(key)
            if c is None:
                return 0.0
            c *= 0.5 ** ((now - self._stamps[key]) / self.halflife_s)
            return c * _LN2 / self.halflife_s

    def keys(self) -> List[object]:
        with self._lock:
            return list(self._counts)

    def prune(self, floor: float = 1e-3) -> int:
        """Forget keys whose decayed counter fell below ``floor``."""
        now = self._now()
        with self._lock:
            dead = [k for k, c in self._counts.items()
                    if c * 0.5 ** ((now - self._stamps[k])
                                   / self.halflife_s) < floor]
            for k in dead:
                del self._counts[k]
                del self._stamps[k]
            return len(dead)


class ReplicaManager:
    """Promote hot plans to extra devices, demote cold replicas.

    ``step()`` is the whole policy: for every tracked key the target
    replica count is ``clamp(ceil(rate / rate_per_replica), 1,
    max_replicas)`` — one replica per ``rate_per_replica`` req/s of
    demand. Promotion picks the least-loaded devices (by the caller's
    ``device_load_fn``) not already holding the plan; demotion drops the
    most recently added extras first and NEVER touches the primary.

    The engine calls :meth:`observe` per request on the hot path (O(1))
    and :meth:`maybe_step` at flush boundaries — replication runs
    "in the background" of serving without needing its own thread.
    """

    def __init__(self, *,
                 replicas_fn: Callable[[object], Sequence[int]],
                 add_fn: Callable[[object, int], bool],
                 drop_fn: Callable[[object, int], bool],
                 device_load_fn: Callable[[], Sequence[float]],
                 rate_per_replica: float = 50.0,
                 max_replicas: int = 4,
                 halflife_s: float = 5.0,
                 interval_s: float = 0.25,
                 now_fn: Callable[[], float] = time.monotonic):
        if rate_per_replica <= 0:
            raise ValueError("rate_per_replica must be > 0")
        if max_replicas < 1:
            raise ValueError("max_replicas must be >= 1")
        self._replicas_fn = replicas_fn
        self._add_fn = add_fn
        self._drop_fn = drop_fn
        self._device_load_fn = device_load_fn
        self.rate_per_replica = float(rate_per_replica)
        self.max_replicas = int(max_replicas)
        self.interval_s = float(interval_s)
        self._now = now_fn
        self.rates = EwmaRate(halflife_s, now_fn=now_fn)
        self._lock = threading.Lock()
        self._last_step: Optional[float] = None
        self.promotions = 0
        self.demotions = 0
        self.steps = 0

    def observe(self, key, n: int = 1) -> None:
        self.rates.observe(key, n)

    def target_replicas(self, key) -> int:
        rate = self.rates.rate(key)
        return max(1, min(self.max_replicas,
                          math.ceil(rate / self.rate_per_replica)))

    def maybe_step(self) -> bool:
        """Run :meth:`step` if ``interval_s`` elapsed since the last run.
        Non-blocking for concurrent callers: one thread steps, the rest
        skip. Returns True when a step actually ran."""
        now = self._now()
        with self._lock:
            if (self._last_step is not None
                    and now - self._last_step < self.interval_s):
                return False
            self._last_step = now
        self.step()
        return True

    def step(self) -> Dict[str, int]:
        """One promotion/demotion sweep over every tracked key."""
        promoted = demoted = 0
        loads = list(self._device_load_fn())
        for key in self.rates.keys():
            target = self.target_replicas(key)
            current = list(self._replicas_fn(key))
            if not current:
                continue        # never placed (or already forgotten)
            if target > len(current):
                held = set(current)
                candidates = sorted(
                    (d for d in range(len(loads)) if d not in held),
                    key=loads.__getitem__)
                for dev in candidates[:target - len(current)]:
                    if self._add_fn(key, dev):
                        promoted += 1
                        # count the new copy so later keys in THIS sweep
                        # see the device as more loaded
                        loads[dev] += 1.0
            elif target < len(current):
                # drop newest extras first; current[0] is the primary
                for dev in current[:target - len(current) - 1:-1]:
                    if self._drop_fn(key, dev):
                        demoted += 1
        self.rates.prune()
        with self._lock:
            self.promotions += promoted
            self.demotions += demoted
            self.steps += 1
        return {"promoted": promoted, "demoted": demoted}

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"promotions": self.promotions,
                    "demotions": self.demotions,
                    "replication_steps": self.steps}
