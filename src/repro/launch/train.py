"""Distributed LM training launcher.

On real hardware this runs under `jax.distributed.initialize()` with the
production mesh; on this host it runs reduced configs on a 1-device mesh.
Demonstrates the full substrate: sharded train step, fault-tolerant loop,
checkpointing, stateless data.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --reduced --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..checkpoint.manager import CheckpointManager
from ..configs import ARCH_IDS, get_config, get_reduced
from ..data.tokens import token_batch_fn
from ..sharding import param_specs, set_mesh_ctx
from ..train.loop import train_loop
from ..train.step import init_train_state, make_train_step
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="full config (needs a real pod + jax.distributed)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh() if args.reduced else make_production_mesh()
    set_mesh_ctx(mesh)
    print(f"[train] {cfg.name} on mesh {dict(mesh.shape)}")

    state = init_train_state(cfg, jax.random.PRNGKey(0))
    specs = param_specs(state, mesh)
    step = jax.jit(
        make_train_step(cfg, peak_lr=args.lr, microbatch=args.microbatch,
                        loss_chunk=min(512, args.seq),
                        q_chunk=min(512, args.seq),
                        kv_chunk=min(512, args.seq), ssd_chunk=8),
        in_shardings=(specs, None), out_shardings=(specs, None))

    if cfg.frontend == "token":
        bf_np = token_batch_fn(batch=args.batch, seq=args.seq, vocab=cfg.vocab)

        def bf(s):
            return {k: jnp.asarray(v) for k, v in bf_np(s).items()}
    else:  # stub frontend: synthetic frame embeddings
        def bf(s):
            key = jax.random.PRNGKey(s)
            x = jax.random.normal(key, (args.batch, args.seq, cfg.d_model),
                                  jnp.float32).astype(jnp.bfloat16)
            y = jax.random.randint(key, (args.batch, args.seq), 0, cfg.vocab)
            return {"inputs": x, "labels": y}

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    out = train_loop(state=state, train_step=step, batch_fn=bf,
                     n_steps=args.steps, ckpt=ckpt, ckpt_every=50, log_every=5)
    print(f"[train] done; final loss "
          f"{out['history'][-1]['loss']:.4f}, stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
