import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run + roofline extraction (deliverables (e) and (g)).

For every (architecture x input shape) cell:
  1. PRODUCTION program (layer scans rolled): ``jit(step).lower().compile()``
     on the single-pod (16x16) and multi-pod (2x16x16) meshes -> proves the
     distribution config is coherent; records ``memory_analysis()``.
  2. ROOFLINE probes (single-pod mesh): XLA's cost analysis counts while-loop
     bodies ONCE (verified 8x undercount on an 8-step scan), so per-layer
     unit costs are measured on depth-reduced *unrolled* probe configs and
     extrapolated linearly to full depth:
         cost(full) = cost(probe_a) + (units_full - units_a) * d_cost/d_unit
     Attention chunk scans are unrolled too (probe chunk sizes chosen so the
     total FLOPs equal the production program's). SSD keeps its production
     chunk (its heavy einsums are outside the carry scan, so they are counted
     correctly).

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-32b --shape train_4k
  python -m repro.launch.dryrun --all --out benchmarks/results/dryrun.json
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..analysis.roofline import collective_bytes, model_flops_estimate, roofline_terms
from ..configs import ARCH_IDS, SHAPES_BY_NAME, get_config, shape_skips
from ..configs.base import ArchConfig, ShapeConfig
from ..models import attention as attention_mod
from ..models import lm
from ..sharding import cache_specs, param_specs, set_mesh_ctx
from ..train.step import init_train_state, make_train_step
from .mesh import make_production_mesh


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "token":
            inp = jax.ShapeDtypeStruct((B, T), jnp.int32)
        else:  # stub modality frontend: precomputed frame/patch embeddings
            inp = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            return {"inputs": inp, "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}
        return {"inputs": inp}
    # decode: one new token against a T-long cache
    state = jax.eval_shape(
        functools.partial(lm.init_decode_state, cfg, B, T))
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32), "state": state}


def _batch_sharding(mesh, sds_tree):
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(sds):
        want = [baxes] + [None] * (len(sds.shape) - 1)
        from ..sharding import resolve_spec
        return NamedSharding(mesh, resolve_spec(sds.shape, want, mesh))

    return jax.tree.map(spec, sds_tree)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *, chunks=None):
    """Returns (fn, args_sds tuple, in_shardings, out_shardings)."""
    chunks = chunks or {}
    q = chunks.get("q_chunk", 512)
    kv = chunks.get("kv_chunk", 512)
    lc = chunks.get("loss_chunk", 512)
    sc = chunks.get("ssd_chunk", 128)
    mb = chunks.get("microbatch", None)
    specs = input_specs(cfg, shape)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        state_sds = jax.eval_shape(functools.partial(init_train_state, cfg), key)
        state_sh = param_specs(state_sds, mesh)
        batch_sh = _batch_sharding(mesh, specs)
        fn = make_train_step(cfg, loss_chunk=lc, q_chunk=q, kv_chunk=kv,
                             ssd_chunk=sc, microbatch=mb)
        return fn, (state_sds, specs), ((state_sh, batch_sh)), (state_sh, None)

    params_sds = jax.eval_shape(functools.partial(lm.init_lm, cfg), key)
    params_sh = param_specs(params_sds, mesh)
    if shape.kind == "prefill":
        fn = functools.partial(lm.prefill_forward, cfg, q_chunk=q, kv_chunk=kv,
                               ssd_chunk=sc)
        in_sh = (params_sh, _batch_sharding(mesh, specs["inputs"]))
        return fn, (params_sds, specs["inputs"]), in_sh, None

    # decode
    state_sds = specs["state"]
    state_sh = cache_specs(state_sds, mesh)

    def fn(params, state, tokens):
        logits, st = lm.decode_step(cfg, params, tokens, state)
        return jnp.argmax(logits, -1).astype(jnp.int32), st

    tok_sh = _batch_sharding(mesh, specs["tokens"])
    return (fn, (params_sds, state_sds, specs["tokens"]),
            (params_sh, state_sh, tok_sh), (None, state_sh))


def lower_and_compile(cfg, shape, mesh, *, chunks=None, unroll=False):
    lm.SCAN_UNROLL = unroll
    attention_mod.SCAN_UNROLL = unroll
    set_mesh_ctx(mesh)
    # optimized-default (§Perf): grouped MoE dispatch, one group per data shard
    from ..models import moe as moe_mod
    prev_groups = moe_mod.DISPATCH_GROUPS
    if moe_mod.DISPATCH_GROUPS == 1:
        moe_mod.DISPATCH_GROUPS = dict(zip(mesh.axis_names,
                                           mesh.devices.shape)).get("data", 1)
    try:
        fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh, chunks=chunks)
        t0 = time.time()
        with mesh:
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jfn.lower(*args)
            compiled = lowered.compile()
        dt = time.time() - t0
        return lowered, compiled, dt
    finally:
        lm.SCAN_UNROLL = False
        attention_mod.SCAN_UNROLL = False
        moe_mod.DISPATCH_GROUPS = prev_groups
        set_mesh_ctx(None)


# ---------------------------------------------------------------------------
# model-FLOPs accounting (6*N_active*D)
# ---------------------------------------------------------------------------
def active_param_count(cfg: ArchConfig) -> float:
    params = jax.eval_shape(functools.partial(lm.init_lm, cfg), jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    total = 0.0
    for path, leaf in flat:
        pstr = ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        n = float(np.prod(leaf.shape))
        if ".moe." in pstr and any(pstr.endswith(s) for s in ("wi", "wg", "wo")):
            n *= cfg.top_k / cfg.n_experts   # routed experts: only top-k active
        if "embed" in pstr or "head" in pstr:
            continue                          # embedding lookups are not matmul FLOPs
        total += n
    return total


# ---------------------------------------------------------------------------
# roofline probes (depth extrapolation)
# ---------------------------------------------------------------------------
def _probe_plan(cfg: ArchConfig):
    """[(probe_cfg, units)] + full_units; cost is linear in ``units``."""
    if cfg.family == "hybrid":
        n_groups, g, tail = cfg.n_layers // cfg.hybrid_group, cfg.hybrid_group, \
            cfg.n_layers % cfg.hybrid_group
        # 3 probes solve (fixed, per_mamba, per_shared); see solver below
        return "hybrid", [
            cfg.replace(n_layers=3, hybrid_group=3),   # 1 shared + 3 mamba
            cfg.replace(n_layers=6, hybrid_group=6),   # 1 shared + 6 mamba
            cfg.replace(n_layers=6, hybrid_group=3),   # 2 shared + 6 mamba
        ], (n_groups, cfg.n_layers)
    if cfg.local_global_period == 2:
        return "linear", [cfg.replace(n_layers=2), cfg.replace(n_layers=4)], \
            cfg.n_layers // 2  # units = pairs
    if cfg.family == "moe" and cfg.first_dense_layers:
        nd = cfg.first_dense_layers
        return "linear", [cfg.replace(n_layers=nd + 1), cfg.replace(n_layers=nd + 2)], \
            cfg.n_layers - nd  # units = moe layers
    return "linear", [cfg.replace(n_layers=1), cfg.replace(n_layers=2)], cfg.n_layers


def _cost_vector(compiled, lowered=None) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        **{f"coll_{k}": float(v) for k, v in coll.items()},
    }


def probe_roofline(cfg: ArchConfig, shape: ShapeConfig, mesh) -> Dict[str, float]:
    """Extrapolated full-depth per-device cost vector."""
    # probe chunk sizes: keep total FLOPs identical to production while
    # bounding unrolled body count (full-attention FLOPs are chunk-invariant)
    T = shape.seq_len
    chunks = {"q_chunk": min(4096, T), "kv_chunk": min(4096, T),
              "loss_chunk": min(4096, T), "ssd_chunk": 128}
    kind, probes, full = _probe_plan(cfg)
    vecs = []
    for pc in probes:
        _, compiled, dt = lower_and_compile(pc, shape, mesh, chunks=chunks,
                                            unroll=True)
        vecs.append(_cost_vector(compiled))
    keys = sorted(set().union(*[set(v) for v in vecs]))

    out = {}
    if kind == "linear":
        (ca, ua), (cb, ub) = (vecs[0], 1), (vecs[1], 2)
        for k in keys:
            per = (cb.get(k, 0.0) - ca.get(k, 0.0)) / (ub - ua)
            out[k] = ca.get(k, 0.0) + (full - ua) * per
    else:  # hybrid: cA = f + s + 3m ; cB = f + s + 6m ; cC = f + 2s + 6m
        cA, cB, cC = vecs
        n_shared, n_mamba = full
        for k in keys:
            m = (cB.get(k, 0.0) - cA.get(k, 0.0)) / 3.0
            s = cC.get(k, 0.0) - cB.get(k, 0.0)
            f = cA.get(k, 0.0) - s - 3 * m
            out[k] = f + n_shared * s + n_mamba * m
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, do_multipod=True, do_roofline=True
             ) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "kind": shape.kind}
    skip = shape_skips(cfg, shape)
    if skip:
        rec["skipped"] = skip
        return rec

    meshes = [("pod16x16", make_production_mesh(multi_pod=False))]
    if do_multipod:
        meshes.append(("multipod2x16x16", make_production_mesh(multi_pod=True)))

    for mname, mesh in meshes:
        chips = int(np.prod(list(mesh.shape.values())))
        lowered, compiled, dt = lower_and_compile(cfg, shape, mesh)
        ma = compiled.memory_analysis()
        print(f"[dryrun] {arch} x {shape_name} x {mname}: compile {dt:.1f}s")
        print(f"         memory_analysis: args={ma.argument_size_in_bytes/1e9:.3f}GB "
              f"out={ma.output_size_in_bytes/1e9:.3f}GB "
              f"temp={ma.temp_size_in_bytes/1e9:.3f}GB (per device)")
        cv = _cost_vector(compiled)
        print(f"         rolled-scan cost (body-once): flops={cv['flops']:.3e} "
              f"bytes={cv['bytes']:.3e} coll={cv['coll']:.3e}")
        rec[mname] = {
            "compile_s": dt,
            "argument_bytes_per_dev": ma.argument_size_in_bytes,
            "output_bytes_per_dev": ma.output_size_in_bytes,
            "temp_bytes_per_dev": ma.temp_size_in_bytes,
            "rolled_cost": cv,
            "chips": chips,
        }

    if do_roofline:
        mesh = make_production_mesh(multi_pod=False)
        chips = 256
        full_cost = probe_roofline(cfg, shape, mesh)
        n_act = active_param_count(cfg)
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind in ("train", "prefill") else shape.global_batch)
        mf = model_flops_estimate(n_act, tokens,
                                  "train" if shape.kind == "train" else "infer")
        rl = roofline_terms({"flops": full_cost["flops"],
                             "bytes accessed": full_cost["bytes"]},
                            "", chips=chips, model_flops=mf)
        # collective bytes already summed in probe extrapolation
        rl.bytes_coll = full_cost["coll"]
        rl.collective_s = full_cost["coll"] / 50e9
        terms = {"compute": rl.compute_s, "memory": rl.memory_s,
                 "collective": rl.collective_s}
        rl.bottleneck = max(terms, key=terms.get)
        rec["roofline"] = {**rl.to_row(),
                           "coll_breakdown": {k[5:]: v for k, v in full_cost.items()
                                              if k.startswith("coll_")},
                           "active_params": n_act, "tokens": tokens}
        print(f"         roofline: compute={rl.compute_s*1e3:.2f}ms "
              f"memory={rl.memory_s*1e3:.2f}ms collective={rl.collective_s*1e3:.2f}ms "
              f"-> {rl.bottleneck}-bound; useful={rl.useful_ratio:.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-multipod", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES_BY_NAME:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    results = []
    for a, s in cells:
        try:
            results.append(run_cell(a, s, do_multipod=not args.no_multipod,
                                    do_roofline=not args.no_roofline))
        except Exception as e:  # a failing cell is a bug — record loudly
            traceback.print_exc()
            results.append({"arch": a, "shape": s, "error": repr(e)})
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    # merge with existing results (per-cell reruns update in place)
    merged: Dict[Tuple[str, str], Dict] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            for r in json.load(f):
                merged[(r["arch"], r["shape"])] = r
    for r in results:
        merged[(r["arch"], r["shape"])] = r
    with open(args.out, "w") as f:
        json.dump(list(merged.values()), f, indent=1)
    n_err = sum("error" in r for r in results)
    print(f"[dryrun] wrote {args.out}; {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
