"""Production mesh construction (DESIGN.md §6).

A function, not a module-level constant, so importing never touches jax
device state. Target: TPU v5e, 256 chips/pod; multi-pod adds a leading "pod"
axis for hierarchical (ICI-within-pod / DCN-across-pod) collectives.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
