"""Production mesh construction (DESIGN.md §6).

A function, not a module-level constant, so importing never touches jax
device state. Target: TPU v5e, 256 chips/pod; multi-pod adds a leading "pod"
axis for hierarchical (ICI-within-pod / DCN-across-pod) collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"model axis size must be >= 1, got {model}")
    if n % model != 0:
        raise ValueError(
            f"cannot build a ({n // model if model else 0}, {model}) host "
            f"mesh: {n} available device(s) not divisible by model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))


def multihost_graph_mesh() -> Mesh:
    """Global 1-D serving mesh spanning EVERY process's devices.

    The cross-host analogue of :func:`graph_mesh`: one flat "dev" axis over
    ``jax.devices()`` — which, after ``jax.distributed.initialize``, is the
    union of all processes' local devices in process-major order. Any
    computation over this mesh is SPMD-collective: every process must enter
    it with the same program (the ``MultihostGraphEngine.serve_global``
    contract). On a single process it degenerates to ``graph_mesh()``.
    """
    devices = jax.devices()
    if not devices:
        raise ValueError("multihost_graph_mesh found no devices")
    return Mesh(np.asarray(devices), ("dev",))


def graph_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D mesh for fleet graph serving: ``n_devices`` devices on axis "dev".

    Unlike the train meshes there is no data/model split — graph serving
    parallelism is the paper's column-dimension (feature) parallelism and
    block-level workload balancing lifted to device granularity, both of
    which want a flat device axis. Defaults to every visible device; a
    smaller ``n_devices`` takes a prefix (so a fleet engine can leave
    devices for other tenants).
    """
    avail = jax.devices()
    n = len(avail) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"graph_mesh needs >= 1 device, got n_devices={n}")
    if n > len(avail):
        raise ValueError(
            f"graph_mesh(n_devices={n}) exceeds the {len(avail)} visible "
            f"device(s)")
    return Mesh(np.asarray(avail[:n]), ("dev",))
