"""Stateless token-batch pipeline: batch = f(seed, step).

Restart-safe by construction (train/loop.py replays identical batches after a
resume). Synthetic data is a mixture of Markov-chain text (so a real LM can
actually learn next-token structure) and uniform noise.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import numpy as np


def _markov_row(seed: int, vocab: int, branch: int = 8):
    rng = np.random.default_rng(seed)
    # each symbol transitions to one of `branch` successors
    return rng.integers(0, vocab, size=(vocab, branch)).astype(np.int32)


@functools.lru_cache(maxsize=4)
def _table(vocab: int, seed: int):
    return _markov_row(seed, vocab)


def token_batch_fn(*, batch: int, seq: int, vocab: int, seed: int = 0
                   ) -> Callable[[int], Dict[str, np.ndarray]]:
    """Returns batch_fn(step) -> {"inputs": [B,T] i32, "labels": [B,T] i32}."""
    table = _table(vocab, seed)
    branch = table.shape[1]

    def batch_fn(step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((seed << 20) ^ step)
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        choices = rng.integers(0, branch, size=(batch, seq))
        for t in range(seq):
            toks[:, t + 1] = table[toks[:, t], choices[:, t]]
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:].copy()}

    return batch_fn
