from .graphs import make_power_law_graph, BENCHMARK_GRAPHS, make_benchmark_graph  # noqa: F401
from .graphs import seed_splits, seed_batches  # noqa: F401
from .tokens import token_batch_fn  # noqa: F401
