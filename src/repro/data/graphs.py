"""Graph data pipeline: the paper's 18 benchmark graphs as synthetic analogues.

Table I of the paper lists node/edge counts for 18 public graphs. Offline, we
generate power-law (preferential-attachment-style) graphs matched to those
counts — the degree distribution is the property that drives every effect the
paper measures (workload imbalance, locality). The three largest graphs
(PRODUCTS, Reddit, PPA) are generated at reduced edge counts on this host
(noted in ``scale``), keeping node counts and density character.

Node features and labels are synthetic (seeded), so every experiment is
reproducible bit-for-bit from (name, seed).
"""
from __future__ import annotations

from typing import Dict, Iterator, Sequence, Tuple

import numpy as np

from ..core.graph import CSRGraph, csr_from_edges

# name -> (n_nodes, n_edges, scale) ; scale<1 => edges reduced by that factor
BENCHMARK_GRAPHS: Dict[str, Tuple[int, int, float]] = {
    "am":              (881_680, 5_668_682, 1.0),
    "amazon0601":      (403_394, 5_478_357, 1.0),
    "Artist":          (50_515, 1_638_396, 1.0),
    "Arxiv":           (169_343, 1_166_243, 1.0),
    "Citation":        (2_927_963, 30_387_995, 0.25),
    "Collab":          (235_868, 2_358_104, 1.0),
    "com-amazon":      (334_863, 1_851_744, 1.0),
    "OVCAR-8H":        (1_889_542, 3_946_402, 1.0),
    "PRODUCTS":        (2_449_029, 123_718_280, 0.05),
    "Pubmed":          (19_717, 99_203, 1.0),
    "PPA":             (576_289, 42_463_862, 0.15),
    "Reddit":          (232_965, 114_615_891, 0.05),
    "SW-620H":         (1_888_584, 3_944_206, 1.0),
    "TWITTER-Partial": (580_768, 1_435_116, 1.0),
    "wikikg2":         (2_500_604, 16_109_182, 0.4),
    "Yelp":            (716_847, 13_954_819, 0.5),
    "Yeast":           (1_710_902, 3_636_546, 1.0),
    "youtube":         (1_138_499, 5_980_886, 1.0),
}


def make_power_law_graph(n: int, m_edges: int, seed: int = 0,
                         alpha: float = 1.8) -> CSRGraph:
    """Power-law multigraph: out-degrees ~ zipf(alpha) scaled to m_edges,
    endpoints preferential (zipf-ranked), O(E) construction."""
    rng = np.random.default_rng(seed)
    # zipf out-degrees, rescaled to hit the edge budget
    raw = rng.zipf(alpha, n).astype(np.float64)
    deg = np.maximum(1, np.round(raw * (m_edges / raw.sum()))).astype(np.int64)
    # exact edge budget
    diff = int(deg.sum() - m_edges)
    if diff > 0:
        idx = rng.choice(n, size=diff, replace=True, p=deg / deg.sum())
        np.subtract.at(deg, idx, 1)
        deg = np.maximum(deg, 0)
    elif diff < 0:
        idx = rng.integers(0, n, size=-diff)
        np.add.at(deg, idx, 1)
    E = int(deg.sum())
    src = np.repeat(np.arange(n), deg)
    # preferential endpoints: sample by rank-skewed distribution
    u = rng.random(E)
    dst = np.minimum((n * u ** 2.0).astype(np.int64), n - 1)  # quadratic skew
    dst = rng.permutation(n)[dst]  # decorrelate hub ids from small indices
    return csr_from_edges(src, dst, n)


def make_benchmark_graph(name: str, seed: int = 0) -> Tuple[CSRGraph, float]:
    n, e, scale = BENCHMARK_GRAPHS[name]
    g = make_power_law_graph(n, int(e * scale), seed=seed)
    return g, scale


def node_features(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    return rng.normal(size=(n, d)).astype(np.float32)


def node_labels(n: int, n_classes: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed + 2)
    return rng.integers(0, n_classes, size=n).astype(np.int32)


def seed_splits(n: int, fractions: Sequence[float],
                seed: int = 0) -> Tuple[np.ndarray, ...]:
    """Disjoint seed-node masks (train/val/test style) over ``n`` nodes.

    ``fractions`` are consumed in order off one seeded permutation, so the
    split is deterministic in (n, fractions, seed) and masks never overlap.
    ``sum(fractions)`` may be < 1 (the remainder is unassigned) but not > 1.
    Returns one bool[n] mask per fraction.
    """
    total = float(sum(fractions))
    if total > 1.0 + 1e-9:
        raise ValueError(f"fractions sum to {total} > 1")
    order = np.random.default_rng(seed).permutation(n)
    masks = []
    lo = 0
    for f in fractions:
        hi = lo + int(round(f * n))
        m = np.zeros(n, dtype=bool)
        m[order[lo:hi]] = True
        masks.append(m)
        lo = hi
    return tuple(masks)


def seed_batches(seeds: np.ndarray, batch_size: int, *, seed: int = 0,
                 epochs: int = 1,
                 shuffle: bool = True) -> Iterator[np.ndarray]:
    """Deterministic seed-node batch iterator for sampled inference/training.

    ``seeds`` is a node-id array or a bool mask (converted via flatnonzero).
    Each epoch reshuffles with rng([seed, epoch]), so the full batch
    sequence is reproducible from (seeds, batch_size, seed) alone. The last
    short batch of an epoch is yielded, never dropped.
    """
    ids = np.asarray(seeds)
    if ids.dtype == bool:
        ids = np.flatnonzero(ids)
    ids = ids.astype(np.int64)
    if batch_size <= 0:
        raise ValueError("batch_size must be positive")
    for epoch in range(epochs):
        order = (np.random.default_rng([seed, epoch]).permutation(len(ids))
                 if shuffle else np.arange(len(ids)))
        for lo in range(0, len(ids), batch_size):
            yield ids[order[lo:lo + batch_size]]
