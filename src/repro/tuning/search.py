"""Candidate space for the partition autotuner.

A candidate is a full dispatch recipe: a :class:`PartitionConfig` variant
(which changes the partition/slab STRUCTURE and therefore the plan cache
key) plus kernel-launch knobs (backend, grid order) that don't.  All
generated configs are admissible by construction — every ``warp_nzs``
table passes :func:`repro.core.partition.validate_warp_nzs_override`, so a
candidate plan always covers each row with one block and downstream
kernels need no changes.

Why these axes move the needle:

* ``max_rows_per_block`` — the default tpu-mode cap (``max_block_warps``)
  leaves a degree-1 slab only ``max_block_warps / deg_bound`` full; lifting
  the cap to ``deg_bound`` packs low-degree rows densely and can cut the
  block count (and kernel grid) several-fold on power-law graphs.
* ``warp_nzs`` table — a per-degree budget below ``max_warp_nzs`` splits a
  degree class over MORE, smaller blocks: worse density, more parallelism.
* ``max_warp_nzs`` (slab capacity ``C = max_block_warps * max_warp_nzs``)
  — trades per-block arithmetic intensity against padding waste and the
  split-row threshold.
* ``grid_order`` / ``backend`` — launch-shape knobs of
  :func:`repro.kernels.spmm_batched.spmm_batched`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from ..core.plan_cache import PartitionConfig

__all__ = ["TuningCandidate", "staircase_warp_nzs", "default_candidates"]


@dataclasses.dataclass(frozen=True)
class TuningCandidate:
    """One point in the tuner's search space.

    ``backend=None`` means "the engine's configured backend"; a concrete
    value pins the kernel regime for this plan's dispatches after
    promotion (recorded in ``plan.tuned``).
    """

    config: PartitionConfig
    backend: Optional[str] = None
    grid_order: str = "block_major"
    label: str = ""

    def tuned_hints(self) -> dict:
        """The JSON-able dispatch hints stored in ``plan.tuned``."""
        return {"backend": self.backend, "grid_order": self.grid_order,
                "label": self.label}


def staircase_warp_nzs(max_block_warps: int, max_warp_nzs: int,
                       base: int = 1) -> Tuple[int, ...]:
    """Smallest admissible per-degree warp_nzs table with a floor of ``base``.

    Entry ``d`` is ``clamp(ceil(d / max_block_warps), base, max_warp_nzs)``
    — the minimum budget that still satisfies ``max_block_warps *
    warp_nzs[d] >= d``, i.e. the most-parallel admissible table.  With
    ``base == max_warp_nzs`` this degenerates to the default table.
    """
    deg_bound = max_block_warps * max_warp_nzs
    base = max(1, min(int(base), max_warp_nzs))
    return tuple(
        min(max_warp_nzs, max(base, math.ceil(d / max_block_warps)))
        for d in range(1, deg_bound + 1))


def default_candidates(base: PartitionConfig,
                       backends: Tuple[Optional[str], ...] = (None,)
                       ) -> List[TuningCandidate]:
    """The deterministic default candidate list for ``base``.

    Ordered best-guess-first: a SMALLER slab (``half-slab``) leads because
    on the skewed low-degree graphs that dominate serving mixes most slab
    slots are padding, and shrinking ``C`` cuts the per-block dense work
    roughly in half for the jnp/blocked regime.  Capacity-preserving
    warp_nzs reshapes come next, then the dense row-packing and
    slab-doubling long shots.  Candidates identical to ``base`` are
    filtered out, so the list is always a set of genuine alternatives.
    """
    mbw, mwn = base.max_block_warps, base.max_warp_nzs
    variants: List[Tuple[PartitionConfig, str]] = []
    # slab capacity: half the non-zero budget per block (best prior)
    if mwn > 1:
        variants.append((dataclasses.replace(
            base, max_warp_nzs=mwn // 2, warp_nzs_table=None),
            "half-slab"))
    # warp_nzs reshapes: a half-way budget, then the most-parallel table
    if mwn >= 4:
        variants.append((dataclasses.replace(
            base, warp_nzs_table=staircase_warp_nzs(mbw, mwn, base=mwn // 2)),
            f"wnz-{mwn // 2}"))
    variants.append((dataclasses.replace(
        base, warp_nzs_table=staircase_warp_nzs(mbw, mwn, base=1)),
        "wnz-min"))
    if base.mode == "tpu":
        # pack as many rows as fit the slab (lifts the MXU-sized row cap)
        variants.append((dataclasses.replace(
            base, max_rows_per_block=base.deg_bound), "dense-rows"))
    variants.append((dataclasses.replace(
        base, max_warp_nzs=mwn * 2, warp_nzs_table=None), "2x-slab"))

    out: List[TuningCandidate] = []
    for be in backends:
        for cfg, label in variants:
            if cfg == base and be is None:
                continue
            tag = label if be is None else f"{label}+{be}"
            out.append(TuningCandidate(config=cfg, backend=be, label=tag))
    return out
