"""Online partition autotuning (AWB-GCN-style runtime rebalancing).

The partition pattern table is a hand-tuned constant — the upstream
Accel-GCN kernel carries five commented-out ``warp_nz`` "workload" vectors,
and AWB-GCN showed runtime rebalancing beats any static configuration
across graphs.  This package closes the loop for the serving stack:

* :mod:`repro.tuning.search` — the candidate space: per-degree
  ``warp_nzs`` override tables, slab capacity (``max_warp_nzs`` /
  ``deg_bound``), row-packing caps, grid order and backend — each an
  admissible :class:`~repro.core.plan_cache.PartitionConfig` variant.
* :mod:`repro.tuning.tuner` — :class:`PlanTuner`, the online policy: an
  EWMA request-rate tracker decides which graphs are hot enough to be
  worth tuning, a fraction of their live dispatches is SHADOWED onto a
  candidate plan off the critical path (the answer always comes from the
  incumbent — reads never pay for candidates), and a candidate that wins
  K consecutive comparisons by at least X% is promoted through the plan
  cache's versioned ``publish``/``retire`` chain.  ``tune_offline`` is the
  same measurement loop as a one-shot CLI-friendly function.

Tuned configs live in the :class:`~repro.core.plan_cache.PartitionPlan`
(``plan.tuned`` + the config inside ``plan.key``) and survive disk
spill/reload, so a graph learned once stays fast forever.
"""
from .search import (  # noqa: F401
    TuningCandidate,
    default_candidates,
    staircase_warp_nzs,
)
from .tuner import PlanTuner, tune_offline  # noqa: F401

__all__ = [
    "TuningCandidate",
    "default_candidates",
    "staircase_warp_nzs",
    "PlanTuner",
    "tune_offline",
]
