"""Online per-graph partition autotuning with shadow-measured rollout.

:class:`PlanTuner` is pure policy — it never touches the cache, kernels or
clocks on its own (the engine drives it from the dispatch path and a
shadow worker thread), which keeps it deterministic under a fake clock for
CI.  Protocol, per registered graph id:

1.  ``observe(gid, n)`` on every live dispatch feeds the EWMA rate
    tracker (the same estimator hot-plan replication uses); once the rate
    crosses ``hot_rate`` the graph enters tuning with a deterministic
    candidate list.
2.  ``next_shadow(gid)`` implements the shadow stride: every
    ``1/shadow_fraction``-th live dispatch of a hot graph returns the
    current candidate, asking the engine to DUPLICATE that dispatch onto
    the candidate plan off the critical path.  The live answer always
    comes from the incumbent — a mistuned candidate can never hurt p99.
3.  ``record_shadow(gid, cand, incumbent_s, candidate_s)`` scores one
    shadow comparison.  A win is ``candidate_s <= incumbent_s * (1 -
    min_improvement)``; ``win_streak`` CONSECUTIVE wins promote the
    candidate (returned to the engine, which publishes it through the plan
    cache's version chain); a loss resets the streak, and a candidate that
    burns ``max_trials`` comparisons without promoting is dropped for the
    next one.  When the list is exhausted the graph is marked done and
    never shadowed again (until ``reset``).

``tune_offline`` is the same measurement applied exhaustively: build and
time every candidate against the incumbent config, no shadowing involved.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

from ..core.graph import CSRGraph
from ..core.plan_cache import PartitionConfig, build_partition_plan
from ..distributed.replication import EwmaRate
from .search import TuningCandidate, default_candidates

__all__ = ["PlanTuner", "tune_offline"]


@dataclasses.dataclass
class _GraphTuneState:
    """Per-graph search progress (guarded by the tuner lock)."""

    base: PartitionConfig
    candidates: List[TuningCandidate]
    idx: int = 0                  # current candidate
    trials: int = 0               # comparisons burned on current candidate
    streak: int = 0               # consecutive wins of current candidate
    dispatches: int = 0           # live dispatches seen while tuning
    status: str = "shadowing"     # shadowing | promoted | exhausted

    @property
    def current(self) -> Optional[TuningCandidate]:
        if self.status != "shadowing" or self.idx >= len(self.candidates):
            return None
        return self.candidates[self.idx]


class PlanTuner:
    """Decide WHICH graphs to tune, WHEN to shadow, and WHO wins.

    All methods are thread-safe and O(1); the engine calls ``observe`` /
    ``next_shadow`` on its flush path and ``record_shadow`` from the
    shadow worker.  ``now_fn`` + a fixed ``candidates`` list make every
    decision reproducible in tests (no wall clock, no RNG — the shadow
    stride is a deterministic counter, not a coin flip).
    """

    def __init__(
        self,
        *,
        hot_rate: float = 20.0,
        shadow_fraction: float = 0.25,
        win_streak: int = 3,
        min_improvement: float = 0.02,
        max_trials: int = 12,
        halflife_s: float = 5.0,
        candidates: Optional[Sequence[TuningCandidate]] = None,
        candidates_fn: Callable[[PartitionConfig],
                                List[TuningCandidate]] = default_candidates,
        now_fn: Callable[[], float] = time.monotonic,
    ):
        if not 0.0 < shadow_fraction <= 1.0:
            raise ValueError("shadow_fraction must be in (0, 1]")
        if win_streak < 1 or max_trials < win_streak:
            raise ValueError("need max_trials >= win_streak >= 1")
        self.hot_rate = float(hot_rate)
        self.stride = max(1, round(1.0 / shadow_fraction))
        self.win_streak = int(win_streak)
        self.min_improvement = float(min_improvement)
        self.max_trials = int(max_trials)
        self._fixed = list(candidates) if candidates is not None else None
        self._candidates_fn = candidates_fn
        self.rates = EwmaRate(halflife_s=halflife_s, now_fn=now_fn)
        self._lock = threading.Lock()
        self._state: Dict[str, _GraphTuneState] = {}
        # monotone counters (snapshot via stats())
        self.comparisons = 0
        self.wins = 0
        self.promotions = 0
        self.exhausted = 0
        self.candidate_failures = 0

    # ------------------------------------------------------------ hot signal
    def observe(self, gid: str, n: int = 1) -> None:
        """Feed one live dispatch of ``n`` requests into the rate tracker."""
        self.rates.observe(gid, n)

    def next_shadow(self, gid: str,
                    base: PartitionConfig) -> Optional[TuningCandidate]:
        """The engine's one per-dispatch question: shadow this one?

        Starts tracking ``gid`` once its request rate crosses ``hot_rate``,
        then returns the current candidate every ``stride``-th dispatch.
        Returns None while cold, between strides, or once tuning finished.
        """
        with self._lock:
            st = self._state.get(gid)
            if st is None:
                if self.rates.rate(gid) < self.hot_rate:
                    return None
                cands = (list(self._fixed) if self._fixed is not None
                         else self._candidates_fn(base))
                cands = [c for c in cands
                         if not (c.config == base and c.backend is None
                                 and c.grid_order == "block_major")]
                if not cands:
                    return None
                st = self._state[gid] = _GraphTuneState(
                    base=base, candidates=cands)
            if st.status != "shadowing":
                return None
            st.dispatches += 1
            if st.dispatches % self.stride:
                return None
            return st.current

    # ------------------------------------------------------------- scoring
    def record_shadow(self, gid: str, cand: TuningCandidate,
                      incumbent_s: float, candidate_s: float
                      ) -> Optional[TuningCandidate]:
        """Score one shadow comparison; returns the candidate to PROMOTE
        (the engine publishes it) after ``win_streak`` consecutive wins."""
        with self._lock:
            st = self._state.get(gid)
            if st is None or st.current is not cand:
                return None         # stale shadow (candidate moved on)
            self.comparisons += 1
            st.trials += 1
            if candidate_s <= incumbent_s * (1.0 - self.min_improvement):
                self.wins += 1
                st.streak += 1
                if st.streak >= self.win_streak:
                    st.status = "promoted"
                    return cand
            else:
                st.streak = 0
            if st.trials >= self.max_trials:
                self._advance_locked(st)
            return None

    def candidate_failed(self, gid: str, cand: TuningCandidate) -> None:
        """A shadow build/dispatch raised: drop this candidate entirely."""
        with self._lock:
            st = self._state.get(gid)
            if st is None or st.current is not cand:
                return
            self.candidate_failures += 1
            self._advance_locked(st)

    def _advance_locked(self, st: _GraphTuneState) -> None:
        st.idx += 1
        st.trials = 0
        st.streak = 0
        if st.idx >= len(st.candidates):
            st.status = "exhausted"
            self.exhausted += 1

    # ------------------------------------------------------------ lifecycle
    def confirm_promoted(self, gid: str) -> None:
        """The engine published the winner (version chain advanced)."""
        with self._lock:
            self.promotions += 1

    def reset(self, gid: str) -> None:
        """Forget a graph's search (promotion raced a mutation, graph
        replaced, ...). It re-enters tuning if it stays hot."""
        with self._lock:
            self._state.pop(gid, None)

    # ---------------------------------------------------------------- stats
    def describe(self, gid: str) -> Optional[Dict]:
        with self._lock:
            st = self._state.get(gid)
            if st is None:
                return None
            cur = st.current
            return {"status": st.status, "candidate": cur.label if cur else None,
                    "idx": st.idx, "trials": st.trials, "streak": st.streak,
                    "dispatches": st.dispatches,
                    "n_candidates": len(st.candidates)}

    def stats(self) -> Dict[str, float]:
        with self._lock:
            by_status: Dict[str, int] = {}
            for st in self._state.values():
                by_status[st.status] = by_status.get(st.status, 0) + 1
            return {
                "tracked": len(self._state),
                "shadowing": by_status.get("shadowing", 0),
                "promoted": by_status.get("promoted", 0),
                "exhausted_graphs": by_status.get("exhausted", 0),
                "comparisons": self.comparisons,
                "wins": self.wins,
                "promotions": self.promotions,
                "exhausted": self.exhausted,
                "candidate_failures": self.candidate_failures,
            }


# --------------------------------------------------------------------- offline
def tune_offline(
    g: CSRGraph,
    base: Optional[PartitionConfig] = None,
    *,
    feat_dim: int = 32,
    repeats: int = 3,
    backend: str = "blocked",
    interpret: bool = True,
    candidates: Optional[Sequence[TuningCandidate]] = None,
    seed: int = 0,
) -> Dict:
    """One-shot exhaustive tuning of a single graph (no shadowing).

    Builds the incumbent plan plus every candidate, times a batched SpMM
    dispatch for each (1 warmup + best of ``repeats``), and returns a
    ranking.  ``backend`` is the measurement default; a candidate with its
    own ``backend`` overrides it.  Used by ``scripts/tune_partition.py``
    and the nightly tuning benchmark.
    """
    import numpy as np

    from ..kernels.spmm_batched import spmm_batched

    base = base or PartitionConfig()
    cands = (list(candidates) if candidates is not None
             else default_candidates(base))
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((g.n_cols, feat_dim)).astype(np.float32)

    def _measure(cfg: PartitionConfig, be: Optional[str],
                 grid_order: str) -> float:
        plan = build_partition_plan(g, cfg)
        kw = dict(backend=be or backend, interpret=interpret,
                  grid_order=grid_order)
        import jax
        jax.block_until_ready(
            spmm_batched([plan.slabs], [x], [plan.n_rows], **kw))
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(
                spmm_batched([plan.slabs], [x], [plan.n_rows], **kw))
            best = min(best, time.perf_counter() - t0)
        return best

    base_s = _measure(base, None, "block_major")
    rows: List[Dict] = []
    for c in cands:
        try:
            t = _measure(c.config, c.backend, c.grid_order)
        except Exception as e:  # noqa: BLE001 — a broken candidate is a result
            rows.append({"label": c.label, "error": repr(e)})
            continue
        rows.append({"label": c.label, "time_s": t,
                     "speedup_vs_base": base_s / t if t else float("inf"),
                     "config": dataclasses.asdict(c.config),
                     "backend": c.backend, "grid_order": c.grid_order})
    ranked = sorted((r for r in rows if "time_s" in r),
                    key=lambda r: r["time_s"])
    best = ranked[0] if ranked else None
    return {
        "base": {"time_s": base_s, "config": dataclasses.asdict(base)},
        "candidates": rows,
        "best": best,
        "best_speedup": (best["speedup_vs_base"] if best else 0.0),
    }
