"""Backend routing for the Accel-GCN SpMM kernels: pick the execution
strategy from the workload, not at build time.

The block-level partition fixes *how nonzeros are grouped*; it does not fix
*where the dense feature matrix lives*. Three kernel regimes exist (AWB-GCN
makes the same runtime-adaptation argument for varying workloads):

  regime      X placement                     per-grid-step VMEM cost
  ----------  ------------------------------  ------------------------------
  resident    whole [N_pad, f_tile] in VMEM   N_pad * f_tile * itemsize
  windowed    [window_rows, f_tile] window,   window_rows * f_tile * itemsize
              accumulated over num_windows      (x num_windows grid sweeps)
  hbm         X stays in HBM; C rows gathered C * f_tile * 4 scratch
              per block via double-buffer DMA   + 2 * f_tile row buffers

This module owns the arithmetic: a per-dispatch VMEM footprint estimate from
``(N_pad, F_pad, C, R, f_tile)`` and a :func:`route_spmm` that picks the
cheapest regime that fits the budget. Callers that *force* the resident
kernel on an oversized dispatch get an explicit :class:`VmemBudgetError`
at trace time instead of a silent interpret-mode slowdown that would be a
compile failure on real hardware.

Default thresholds (f32, f_tile=128, budget 2 MiB for the X tile):

  N_pad <= 4096           -> resident   (X tile <= 2 MiB)
  N_pad <= 4 * 4096       -> windowed   (<= MAX_WINDOWS full-grid sweeps)
  N_pad >  16384          -> hbm        (gather cost ~ nnz, independent of N)
"""
from __future__ import annotations

import dataclasses
import math

__all__ = [
    "VMEM_BYTES_PER_CORE",
    "X_TILE_BUDGET_BYTES",
    "TOTAL_VMEM_BUDGET_BYTES",
    "MAX_WINDOWS",
    "VmemBudgetError",
    "RoutingDecision",
    "pad_rows",
    "pad_features",
    "resident_window_rows",
    "estimate_vmem_bytes",
    "route_spmm",
    "assert_resident_fits",
    "FleetDecision",
    "route_fleet",
]

# TPU cores expose ~16 MiB of VMEM. Mosaic double-buffers every streamed
# block, the epilogue needs headroom, and the MXU operands (one-hot,
# gathered slab) live there too — so the X feature tile gets a 2 MiB
# PER-BUFFER slice, which at f32 x 128 lanes is the documented N_pad <=
# 4096 comfort zone of the resident kernel, and the total per-step
# footprint (all buffers of all operands) must stay within half the core.
#
# Note the windowed regime's total footprint (~4.4 MiB: two window buffers
# in flight) exceeds what a resident tile would cost for 4096 < N_pad <=
# 8192 — it is still the right call there because the compiled tile shape
# stays FIXED at [window, f_tile] for the whole regime (one jit cache entry
# serves any N; a budget-sized resident tile would recompile per N bucket
# and grow without bound), while everything stays under the total budget.
VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
X_TILE_BUDGET_BYTES = 2 * 1024 * 1024
TOTAL_VMEM_BUDGET_BYTES = VMEM_BYTES_PER_CORE // 2

# Each window is a full extra sweep of the (B, nf) grid; past a few windows
# the dead-gather work grows linearly with N while the HBM kernel's DMA cost
# stays proportional to nnz, so cap the windowed regime.
MAX_WINDOWS = 4

_SUBLANE = 8  # f32 sublane quantum: row counts pad to multiples of this


class VmemBudgetError(ValueError):
    """A forced-resident dispatch whose X tile exceeds the VMEM budget.

    Raised at trace time — on hardware the same call would be a Mosaic
    compile failure (or an OOM), not a graceful slowdown.
    """


def pad_rows(n: int) -> int:
    """Rows pad to the f32 sublane quantum (8)."""
    return ((int(n) + _SUBLANE - 1) // _SUBLANE) * _SUBLANE


def pad_features(f: int, f_tile: int) -> int:
    """Features pad to full 128-lane tiles (the combined-warp quantum)."""
    return max(f_tile, ((int(f) + f_tile - 1) // f_tile) * f_tile)


def resident_window_rows(f_tile: int = 128, itemsize: int = 4,
                         budget_bytes: int = X_TILE_BUDGET_BYTES) -> int:
    """Largest sublane-aligned row count whose X tile fits the budget.

    This is both the resident-regime cap and the window height of the
    windowed kernel (4096 at f32/128-lane defaults).
    """
    rows = budget_bytes // (f_tile * itemsize)
    return max(_SUBLANE, (rows // _SUBLANE) * _SUBLANE)


def estimate_vmem_bytes(backend: str, n_pad: int, C: int, R: int,
                        *, f_tile: int = 128, itemsize: int = 4,
                        window_rows: int | None = None) -> int:
    """Per-grid-step VMEM footprint estimate of one SpMM dispatch.

    Counts the X tile (regime-dependent), the double-buffered slab metadata
    and output block, and the MXU operands (gathered slab + one-hot). The
    grid dimensions (B blocks x F_pad/f_tile feature tiles) multiply the
    step *count*, not the per-step footprint, so they do not appear here.
    """
    meta = 2 * 3 * C * 4            # colidx/values/rowloc, double-buffered
    out = 2 * R * f_tile * 4        # output block, double-buffered
    gathered = C * f_tile * 4       # [C, f_tile] slab feeding the MXU
    onehot = C * R * 4              # [R, C] segment-reduction operand
    if backend == "resident":
        x_cost = n_pad * f_tile * itemsize
    elif backend == "windowed":
        w = window_rows or resident_window_rows(f_tile, itemsize)
        x_cost = 2 * min(n_pad, w) * f_tile * itemsize  # streamed -> 2 bufs
    elif backend == "hbm":
        x_cost = 2 * 1 * f_tile * itemsize              # 2 one-row DMA bufs
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return x_cost + meta + out + gathered + onehot


@dataclasses.dataclass(frozen=True)
class RoutingDecision:
    """One dispatch's routing outcome (also the stats/logging record)."""

    backend: str          # "resident" | "windowed" | "hbm"
    n_rows: int           # unpadded X rows of the dispatch (sum over batch)
    n_pad: int
    f_pad: int
    C: int
    R: int
    f_tile: int
    itemsize: int
    num_windows: int      # 1 for resident; >1 windowed; 0 for hbm
    window_rows: int
    vmem_bytes: int       # total per-step estimate for the chosen backend
    resident_bytes: int   # what the forced-resident tile would have cost
    budget_bytes: int     # per-buffer X-tile budget (resident/window cap)
    total_budget_bytes: int   # whole-step cap every regime must satisfy
    reason: str

    def describe(self) -> str:
        return (f"{self.backend}: N_pad={self.n_pad} F_pad={self.f_pad} "
                f"C={self.C} R={self.R} vmem~{self.vmem_bytes / 1024:.0f}KiB "
                f"({self.reason})")


def route_spmm(n_x_rows: int, n_features: int, C: int, R: int,
               *, f_tile: int = 128, itemsize: int = 4,
               budget_bytes: int = X_TILE_BUDGET_BYTES,
               max_windows: int = MAX_WINDOWS,
               force: str | None = None) -> RoutingDecision:
    """Pick the kernel regime for one dispatch.

    ``n_x_rows`` is the row count of the dense feature operand — for a
    batched dispatch that is ``sum(n_cols_g)`` of the concatenated batch,
    which is exactly how a batch of small graphs can overflow a budget each
    graph individually respects.

    Routing picks the first of resident -> windowed -> hbm whose X-tile
    constraint holds AND whose whole-step estimate fits the total VMEM
    budget; the fixed MXU operands (one-hot ``[R, C]``, gathered ``[C,
    f_tile]``) are regime-independent, so a partition capacity so large
    that even the HBM regime overflows raises :class:`VmemBudgetError`
    (the fix is a smaller ``max_block_warps x max_warp_nzs``, not a
    different kernel).

    ``force="resident"`` validates instead of routing: it raises
    :class:`VmemBudgetError` when the dispatch does not fit, making the
    failure mode of ``backend="pallas"`` explicit. ``force="windowed"`` /
    ``force="hbm"`` always succeed (both regimes are N-unbounded; windowed
    just degrades past ``max_windows`` sweeps) — forcing is the explicit
    escape hatch, so only the router-chosen path enforces the total budget.
    """
    n_pad = pad_rows(n_x_rows)
    f_pad = pad_features(n_features, f_tile)
    window = resident_window_rows(f_tile, itemsize, budget_bytes)
    resident_bytes = estimate_vmem_bytes(
        "resident", n_pad, C, R, f_tile=f_tile, itemsize=itemsize)

    def _decision(backend: str, num_windows: int, reason: str) -> RoutingDecision:
        return RoutingDecision(
            backend=backend, n_rows=int(n_x_rows), n_pad=n_pad, f_pad=f_pad,
            C=int(C), R=int(R), f_tile=f_tile, itemsize=itemsize,
            num_windows=num_windows, window_rows=window,
            vmem_bytes=estimate_vmem_bytes(
                backend, n_pad, C, R, f_tile=f_tile, itemsize=itemsize,
                window_rows=window),
            resident_bytes=resident_bytes, budget_bytes=budget_bytes,
            total_budget_bytes=TOTAL_VMEM_BUDGET_BYTES,
            reason=reason)

    if force is not None:
        if force == "resident":
            if n_pad > window:
                suggested = route_spmm(
                    n_x_rows, n_features, C, R, f_tile=f_tile,
                    itemsize=itemsize, budget_bytes=budget_bytes,
                    max_windows=max_windows).backend
                raise VmemBudgetError(
                    f"resident SpMM kernel forced on an oversized dispatch: "
                    f"X tile [N_pad={n_pad}, f_tile={f_tile}] x {itemsize}B "
                    f"= {n_pad * f_tile * itemsize / 1024:.0f} KiB exceeds "
                    f"the {budget_bytes // 1024} KiB VMEM budget "
                    f"(N_pad <= {window} fits; F_pad={f_pad}, C={C}, R={R}). "
                    f"Use backend='auto' or the '{suggested}' backend for "
                    f"this shape.")
            return _decision("resident", 1, "forced")
        if force == "windowed":
            return _decision(
                "windowed", max(1, math.ceil(n_pad / window)), "forced")
        if force == "hbm":
            return _decision("hbm", 0, "forced")
        raise ValueError(f"unknown forced backend {force!r}")

    num_windows = max(1, math.ceil(n_pad / window))
    candidates = []
    if n_pad <= window:
        candidates.append(
            ("resident", 1, f"X tile fits VMEM budget (N_pad <= {window})"))
    elif num_windows <= max_windows:
        candidates.append(
            ("windowed", num_windows,
             f"{num_windows} row windows of {window} (<= {max_windows})"))
    if num_windows > max_windows:
        hbm_reason = (f"N_pad={n_pad} needs {num_windows} windows "
                      f"(> {max_windows}); per-block DMA gather scales with "
                      f"nnz, not N")
    else:
        hbm_reason = (f"leaner regimes exceed the total VMEM budget at "
                      f"C={C}, R={R}")
    candidates.append(("hbm", 0, hbm_reason))

    for backend, nw, reason in candidates:
        if estimate_vmem_bytes(backend, n_pad, C, R, f_tile=f_tile,
                               itemsize=itemsize,
                               window_rows=window) <= TOTAL_VMEM_BUDGET_BYTES:
            return _decision(backend, nw, reason)
    hbm_bytes = estimate_vmem_bytes("hbm", n_pad, C, R, f_tile=f_tile,
                                    itemsize=itemsize)
    raise VmemBudgetError(
        f"no SpMM regime fits the total VMEM budget "
        f"({TOTAL_VMEM_BUDGET_BYTES // 1024} KiB): block capacity C={C}, "
        f"R={R} costs {hbm_bytes // 1024} KiB per grid step even with X in "
        f"HBM (one-hot [R, C] and gathered [C, {f_tile}] MXU operands are "
        f"regime-independent); repartition with a smaller "
        f"max_block_warps x max_warp_nzs.")


@dataclasses.dataclass(frozen=True)
class FleetDecision:
    """One dispatch's *fleet* routing outcome: how many devices it spans and
    how each device's share executes.

    ``per_device`` is the :class:`RoutingDecision` for ONE device's slice of
    the work (the whole dispatch for ``strategy="single"``); ``single`` is
    what one device alone would have run — keeping both makes the win
    legible in logs ("windowed alone, resident per-device once feature-
    sharded 8 ways"). ``n_hosts`` > 1 marks a GLOBAL-mesh dispatch: the
    devices span several processes and execution is SPMD-collective.
    """

    strategy: str             # "single" | "feature" | "block"
    n_devices: int            # devices the dispatch spans (1 for single)
    per_device: RoutingDecision
    single: RoutingDecision
    num_blocks: int
    reason: str
    n_hosts: int = 1          # processes the devices span (1 == one host)

    def describe(self) -> str:
        span = (f"x{self.n_devices}dev/{self.n_hosts}host"
                if self.n_hosts > 1 else f"x{self.n_devices}")
        return (f"{self.strategy}{span}: "
                f"per-device {self.per_device.backend} ({self.reason})")


def route_fleet(n_x_rows: int, n_features: int, C: int, R: int,
                num_blocks: int, n_devices: int,
                *, f_tile: int = 128, itemsize: int = 4,
                min_blocks_per_device: int = 4,
                n_hosts: int = 1) -> FleetDecision:
    """Pick single-device vs feature-sharded vs block-sharded execution.

    ``n_hosts > 1`` routes over the GLOBAL mesh (``n_devices`` then counts
    every process's devices). Two things change at host granularity:

    * **feature sharding is disabled** — its output comes back
      column-sharded across *hosts*, so every answer would pay a
      cross-host gather on the serving path; the per-request win the
      zero-communication column split buys within one host inverts once
      DCN sits between the shards. Wide dispatches stay single-host
      (the placement directory's owner serves them).
    * **block sharding stays eligible** — its ``psum`` combine returns a
      fully-replicated result on every host (each participant reads its
      answer locally), which is exactly the collective a giant graph
      must pay anyway to exceed one host's memory. The block threshold
      still applies per GLOBAL device.

    The fleet's aggregate VMEM/HBM budget is the single-device budget times
    the device count, and the two sharding strategies spend it differently:

    * **feature** — the paper's column-dimension parallelism at device
      granularity: each device owns ``F_pad / n_devices`` feature columns
      and runs the FULL block schedule on them. Zero cross-device
      communication; per-device grid steps (and the per-device slice of X)
      shrink by the device count. Chosen whenever the padded feature width
      carries at least one full ``f_tile`` per device — otherwise some
      devices would idle.
    * **block** — for one giant graph with narrow features — "giant"
      meaning the single-device VMEM estimate already demoted it off the
      resident regime: the partition's blocks go round-robin across devices
      (degree-sorted emission order means heavy blocks interleave, the
      AWB-GCN balancing argument), X is replicated/all-gathered, and
      per-device partial row results psum back. Needs enough blocks
      (``min_blocks_per_device`` per device) to be worth the collective.
    * **single** — everything else: a dispatch that fits one device's VMEM
      budget as a resident tile with narrow features gains nothing from the
      mesh; splitting it would trade zero VMEM pressure for collective and
      launch overhead.

    The per-device regime (resident / windowed / hbm) is still
    :func:`route_spmm` on the per-device share — feature sharding does not
    change the X *row* count, so a dispatch that is windowed alone stays
    windowed per device, just with 1/n-th of the feature sweeps.
    """
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    single = route_spmm(n_x_rows, n_features, C, R,
                        f_tile=f_tile, itemsize=itemsize)
    if n_devices <= 1:
        return FleetDecision("single", 1, single, single, num_blocks,
                             "one device")
    f_pad = pad_features(n_features, f_tile)
    f_tiles = f_pad // f_tile
    if f_tiles >= n_devices and n_hosts == 1:
        per = route_spmm(n_x_rows, f_pad // n_devices, C, R,
                         f_tile=f_tile, itemsize=itemsize)
        return FleetDecision(
            "feature", n_devices, per, single, num_blocks,
            f"{f_tiles} feature tiles over {n_devices} devices: "
            f"zero-communication column split, per-device F="
            f"{f_pad // n_devices}")
    if (single.backend != "resident"
            and num_blocks >= min_blocks_per_device * n_devices):
        # per-step footprint is block-count-independent: one device's share
        # routes exactly like the whole dispatch, with B/n grid steps
        span = (f"{n_devices} devices"
                if n_hosts == 1 else
                f"{n_devices} devices on {n_hosts} hosts (global mesh, "
                f"SPMD-collective)")
        feat_note = (
            f"features are narrow ({f_tiles} tile(s) < {n_devices} devices)"
            if f_tiles < n_devices else
            f"feature split is disabled across {n_hosts} hosts "
            f"({f_tiles} tiles would shard, but column-split answers pay "
            f"a cross-host gather)")
        return FleetDecision(
            "block", n_devices, single, single, num_blocks,
            f"single-device estimate demotes to {single.backend} and "
            f"{feat_note}: {num_blocks} blocks round-robin over {span}, "
            f"X replicated, partials psum", n_hosts=n_hosts)
    why_not_feature = ("" if f_tiles < n_devices else
                       "; feature split skipped: cross-host column "
                       "gather would tax every answer")
    return FleetDecision(
        "single", 1, single, single, num_blocks,
        f"{single.backend} on one device ({f_tiles} feature tile(s), "
        f"{num_blocks} block(s)): sharding would cost more than it "
        f"saves{why_not_feature}")


def assert_resident_fits(n_x_rows: int, n_features: int, C: int, R: int,
                         *, f_tile: int = 128, itemsize: int = 4,
                         budget_bytes: int = X_TILE_BUDGET_BYTES) -> None:
    """Raise :class:`VmemBudgetError` unless the resident X tile fits."""
    route_spmm(n_x_rows, n_features, C, R, f_tile=f_tile, itemsize=itemsize,
               budget_bytes=budget_bytes, force="resident")
