"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth for every kernel test: simple, obviously-correct
implementations with no tiling, padding, or layout tricks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["csr_spmm_ref", "slab_spmm_ref", "grouped_matmul_ref"]


def csr_spmm_ref(rowptr: np.ndarray, colidx: np.ndarray, values: np.ndarray,
                 x: jax.Array) -> jax.Array:
    """CSR SpMM oracle: out[r] = sum_k values[k] * x[colidx[k]] for k in row r.

    COO expansion + segment_sum — the canonical jnp formulation.
    """
    n = len(rowptr) - 1
    row_of = np.repeat(np.arange(n), np.diff(rowptr))
    if len(colidx) == 0:
        return jnp.zeros((n, x.shape[1]), dtype=jnp.promote_types(x.dtype, jnp.float32))
    contrib = values[:, None].astype(jnp.float32) * x[colidx].astype(jnp.float32)
    out = jax.ops.segment_sum(contrib, jnp.asarray(row_of), num_segments=n)
    return out


def slab_spmm_ref(colidx: jax.Array, values: jax.Array, rowloc: jax.Array,
                  out_row: jax.Array, x: jax.Array, n_rows: int) -> jax.Array:
    """Oracle for the slab layout (mirrors the kernel's math step by step).

    colidx/values/rowloc: [B, C]; out_row: [B, R]; x: [N, F].
    """
    B, C = colidx.shape
    R = out_row.shape[1]
    gathered = values[..., None].astype(jnp.float32) * x[colidx].astype(jnp.float32)
    onehot = jax.nn.one_hot(rowloc, R, dtype=jnp.float32)          # [B, C, R]
    slab_out = jnp.einsum("bcr,bcf->brf", onehot, gathered)         # [B, R, F]
    flat = slab_out.reshape(B * R, -1)
    seg = out_row.reshape(B * R)
    out = jax.ops.segment_sum(flat, seg, num_segments=n_rows + 1)
    return out[:n_rows]


def grouped_matmul_ref(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Grouped GEMM oracle: rows of x are grouped contiguously by expert.

    x: [M, K]; w: [E, K, N]; group_sizes: int32[E] summing to M.
    out[m] = x[m] @ w[e(m)] where e(m) is m's group.
    """
    M = x.shape[0]
    e_of_row = jnp.repeat(jnp.arange(w.shape[0]), group_sizes, total_repeat_length=M)
    w_rows = w[e_of_row]  # [M, K, N] — oracle only; memory-naive on purpose
    return jnp.einsum("mk,mkn->mn", x.astype(jnp.float32), w_rows.astype(jnp.float32))
