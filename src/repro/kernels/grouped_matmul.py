"""Grouped (expert-blocked) matmul — Accel-GCN block partitioning for MoE.

Token->expert dispatch is a sparse aggregation with power-law-ish "expert
degrees": exactly the workload shape Accel-GCN targets. We reuse the paper's
recipe one-to-one (DESIGN.md §4):

* degree sorting  -> sort tokens by assigned expert (stable);
* block partition -> cut the sorted token rows into fixed ``m_tile`` blocks,
  padding each expert's rows to a block multiple; one int32 metadata word per
  block (its expert id) is the analogue of the paper's 128-bit block record,
  and is *scalar-prefetched* so the weight BlockSpec index_map can read it —
  the TPU equivalent of the paper's metadata-driven warp workload deduction;
* combined warp   -> the expert weight matrix and the output are tiled at 128
  lanes; every grid step runs a dense, fully-aligned MXU matmul.

Every grid step has *identical* FLOPs — the workload-balance property the
paper's Algorithm 2 provides for SpMM.

VMEM per step (defaults, f32): x (128x512)=256 KiB, w (512x128)=256 KiB,
out (128x128)=64 KiB — comfortably within a v5e core's ~16 MiB VMEM, with
room for double-buffered DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(expert_ref, x_ref, w_ref, out_ref):
    """x_ref: [m_tile, k_tile]; w_ref: [1, k_tile, n_tile]; out: [m_tile, n_tile]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("m_tile", "k_tile", "n_tile", "interpret"))
def grouped_matmul(
    x: jax.Array,             # [M, K] rows sorted+padded by expert; M % m_tile == 0
    w: jax.Array,             # [E, K, N]
    block_expert: jax.Array,  # int32[M // m_tile] expert id per row block
    *,
    m_tile: int = 128,
    k_tile: int = 512,
    n_tile: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Block-balanced grouped GEMM; returns [M, N] float32.

    The w BlockSpec's index_map reads the scalar-prefetched ``block_expert``
    metadata, so each grid step DMAs exactly one expert's (k_tile x n_tile)
    weight tile — the same "all warps deduce their workload from one block
    record" trick as the paper's int4 metadata.
    """
    M, K = x.shape
    E, K2, N = w.shape
    assert K == K2 and M % m_tile == 0, (x.shape, w.shape, m_tile)
    nb = M // m_tile
    k_tile = min(k_tile, K)
    n_tile = min(n_tile, N)
    assert K % k_tile == 0 and N % n_tile == 0
    nk, nn = K // k_tile, N // n_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, nn, nk),
        in_specs=[
            pl.BlockSpec((m_tile, k_tile), lambda b, j, k, e: (b, k)),
            pl.BlockSpec((1, k_tile, n_tile), lambda b, j, k, e: (e[b], k, j)),
        ],
        out_specs=pl.BlockSpec((m_tile, n_tile), lambda b, j, k, e: (b, j)),
    )
    out = pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(block_expert, x, w)
    return out
