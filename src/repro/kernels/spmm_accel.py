"""Accel-GCN SpMM as a Pallas TPU kernel.

TPU mapping of the paper's design (DESIGN.md §2):

* one grid step == one *block* of the block-level partition: a fixed-capacity
  slab of ``C = deg_bound`` non-zeros covering up to ``R`` contiguous
  (degree-sorted) output rows;
* the dense feature dimension is tiled at 128 lanes and iterated by a second
  grid axis — the *combined warp*: every HBM<->VMEM transfer of a dense row is
  a full-lane contiguous vector;
* the intra-block segment reduction (the paper's shared-memory
  ``atomicAdd_block``) becomes a one-hot MXU matmul ``[R, C] @ [C, F_tile]``
  entirely in VMEM — no atomics exist or are needed;
* cross-block accumulation for split rows (degree > C) is a segment-sum
  epilogue over the packed block outputs (TPU grids are sequential, so a
  revisit-accumulate output alias is also legal; see ops.py notes).

VMEM budget per grid step (f32, defaults C=256, R=64, F_tile=128; the
routing arithmetic lives in ``router.py``):

  term                          resident          windowed         (hbm: see
  ----------------------------  ----------------  ---------------  spmm_hbm)
  X feature tile                [N_pad, F_tile]   [4096, F_tile]
                                N_pad<=4096: 2MiB  2 MiB x 2 bufs
  gathered slab [C, F_tile]     128 KiB           128 KiB
  out slab      [R, F_tile]     32 KiB (x2 bufs)  32 KiB (x2 bufs)
  colidx/values/rowloc [C]      3 KiB  (x2 bufs)  3 KiB  (x2 bufs)
  one-hot       [C, R]          64 KiB            64 KiB

* ``spmm_block_slabs`` (resident): the whole X tile sits in VMEM. Guarded —
  N_pad over the 2 MiB tile budget raises ``VmemBudgetError`` at trace time
  (on hardware it would be a Mosaic compile failure, not a slowdown).
* ``spmm_block_slabs_windowed``: X streams through VMEM in row windows of
  ``window_rows`` (default 4096); a third grid axis sweeps the windows and
  accumulates into the revisited output block (TPU grids are sequential, so
  revisit accumulation is legal). Middle regime: N_pad <= 4 windows.
* beyond that, ``spmm_hbm.spmm_block_slabs_hbm`` gathers rows straight from
  HBM. ``router.route_spmm`` picks between the three automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .router import (
    assert_resident_fits,
    pad_features,
    pad_rows,
    resident_window_rows,
)


DEFAULT_F_TILE = 128  # lane width — the "combined warp" quantum on TPU


def scatter_block_rows(out_slabs: jax.Array, out_row: jax.Array,
                       n_rows: int, n_features: int) -> jax.Array:
    """Shared scatter epilogue of every slab kernel: packed [B, R, F_pad]
    block rows -> global [n_rows, n_features]. Non-split blocks write
    disjoint rows; split-row blocks accumulate; slot n_rows is the padding
    sentinel and is dropped (sequential-grid revisit accumulation is the
    real-TPU alternative; see DESIGN.md §2)."""
    B, R, F_pad = out_slabs.shape
    flat = out_slabs.reshape(B * R, F_pad)
    seg = out_row.reshape(B * R)
    out = jax.ops.segment_sum(flat, seg, num_segments=n_rows + 1)
    return out[:n_rows, :n_features]


def _spmm_kernel(colidx_ref, values_ref, rowloc_ref, x_ref, out_ref, *, C, R):
    """One block x one feature tile.

    colidx_ref: int32[1, C]; values_ref: f32[1, C]; rowloc_ref: int32[1, C]
    x_ref: [N_pad, F_tile] feature tile (VMEM resident)
    out_ref: [1, R, F_tile]
    """
    cols = colidx_ref[0, :]                      # [C]
    vals = values_ref[0, :].astype(jnp.float32)  # [C]
    rloc = rowloc_ref[0, :]                      # [C]

    # Gather C dense rows from the feature tile. On TPU this lowers to C
    # dynamic VMEM reads of one (8x128-aligned) row each; lanes are fully
    # coalesced because the feature tile is the minor dimension.
    gathered = x_ref[cols, :].astype(jnp.float32)            # [C, F_tile]
    gathered = gathered * vals[:, None]

    # Intra-block segment reduction as a one-hot MXU matmul (replaces
    # shared-memory atomics). Padding slots carry value 0 so their one-hot
    # row contributes nothing.
    onehot = (rloc[None, :] == jax.lax.broadcasted_iota(jnp.int32, (R, C), 0)
              ).astype(jnp.float32)                          # [R, C]
    out_ref[0, :, :] = jax.lax.dot_general(
        onehot, gathered, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "interpret", "f_tile", "grid_order"),
)
def spmm_block_slabs(
    colidx: jax.Array,   # int32[B, C]
    values: jax.Array,   # f32[B, C]
    rowloc: jax.Array,   # int32[B, C]
    out_row: jax.Array,  # int32[B, R]
    x: jax.Array,        # [N, F]
    n_rows: int,
    *,
    f_tile: int = DEFAULT_F_TILE,
    interpret: bool = True,
    grid_order: str = "block_major",
) -> jax.Array:
    """Run the Accel-GCN SpMM kernel over packed slabs; returns [n_rows, F].

    ``grid_order`` picks the iteration order of the 2D grid (the ROADMAP
    grid-order experiment; every (block, feature-tile) pair runs exactly
    once either way, so outputs are identical):

    * ``"block_major"`` (default): grid ``(B, nf)`` — the feature-tile
      axis is innermost, so one block's slab metadata stays put while its
      feature tiles sweep (one slab fetch per block, nf X-tile switches).
    * ``"ft_major"``: grid ``(nf, B)`` — the block axis is innermost, so
      ONE X feature tile stays resident across the whole block sweep; the
      per-step revisit cost moves to the (much smaller) slab metadata.
      This is the order that should win on real hardware once the X tile
      dominates the per-step DMA traffic.

    Raises :class:`repro.kernels.router.VmemBudgetError` when the resident
    X tile would not fit the VMEM budget (N_pad > 4096 at f32 defaults);
    oversized graphs belong to ``spmm_block_slabs_windowed`` or the HBM
    gather kernel — ``backend="auto"`` picks for you.
    """
    if grid_order not in ("block_major", "ft_major"):
        raise ValueError(
            f"grid_order must be block_major|ft_major, got {grid_order!r}")
    B, C = colidx.shape
    R = out_row.shape[1]
    N, F = x.shape
    assert_resident_fits(N, F, C, R, f_tile=f_tile,
                         itemsize=jnp.dtype(x.dtype).itemsize)

    # Combined-warp alignment: pad F to the lane width (paper's pad-to-32,
    # scaled to TPU's 128 lanes), pad N to sublane multiple.
    F_pad = pad_features(F, f_tile)
    N_pad = pad_rows(N)
    x_p = jnp.zeros((N_pad, F_pad), x.dtype).at[:N, :F].set(x)
    nf = F_pad // f_tile

    if grid_order == "block_major":
        grid = (B, nf)
        block_ix = lambda b, j: (b, 0)          # noqa: E731
        x_ix = lambda b, j: (0, j)              # noqa: E731
        out_ix = lambda b, j: (b, 0, j)         # noqa: E731
    else:  # ft_major: (feature-tile, block) — block axis innermost
        grid = (nf, B)
        block_ix = lambda j, b: (b, 0)          # noqa: E731
        x_ix = lambda j, b: (0, j)              # noqa: E731
        out_ix = lambda j, b: (b, 0, j)         # noqa: E731
    out_slabs = pl.pallas_call(
        functools.partial(_spmm_kernel, C=C, R=R),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C), block_ix),
            pl.BlockSpec((1, C), block_ix),
            pl.BlockSpec((1, C), block_ix),
            pl.BlockSpec((N_pad, f_tile), x_ix),
        ],
        out_specs=pl.BlockSpec((1, R, f_tile), out_ix),
        out_shape=jax.ShapeDtypeStruct((B, R, F_pad), jnp.float32),
        interpret=interpret,
    )(colidx, values, rowloc, x_p)

    return scatter_block_rows(out_slabs, out_row, n_rows, F)


def _spmm_kernel_windowed(colidx_ref, values_ref, rowloc_ref, x_ref, out_ref,
                          *, C, R, window):
    """One block x one feature tile x one row window of X.

    x_ref: [window, F_tile] — the w-th row window of the padded features.
    Slots whose column falls outside the window contribute zero this sweep
    and are picked up by the sweep that owns them; the revisited output
    block accumulates across the (sequential) window axis.
    """
    w = pl.program_id(2)
    cols = colidx_ref[0, :]                      # [C] global column indices
    vals = values_ref[0, :].astype(jnp.float32)  # [C]
    rloc = rowloc_ref[0, :]                      # [C]

    local = cols - w * window
    in_window = ((local >= 0) & (local < window)).astype(jnp.float32)
    local = jnp.clip(local, 0, window - 1)       # keep the gather in bounds

    gathered = x_ref[local, :].astype(jnp.float32)           # [C, F_tile]
    gathered = gathered * (vals * in_window)[:, None]

    onehot = (rloc[None, :] == jax.lax.broadcasted_iota(jnp.int32, (R, C), 0)
              ).astype(jnp.float32)                          # [R, C]
    contrib = jax.lax.dot_general(
        onehot, gathered, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(w == 0)
    def _init():
        out_ref[0, :, :] = contrib

    @pl.when(w > 0)
    def _accumulate():
        out_ref[0, :, :] += contrib


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "interpret", "f_tile", "window_rows"),
)
def spmm_block_slabs_windowed(
    colidx: jax.Array,   # int32[B, C]
    values: jax.Array,   # f32[B, C]
    rowloc: jax.Array,   # int32[B, C]
    out_row: jax.Array,  # int32[B, R]
    x: jax.Array,        # [N, F]
    n_rows: int,
    *,
    f_tile: int = DEFAULT_F_TILE,
    window_rows: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Row-window streaming variant: X visits VMEM one ``window_rows`` tile
    at a time (grid axis 2), so any N fits in the resident budget at the
    price of one full (B, nf) grid sweep per window. Returns [n_rows, F].
    """
    B, C = colidx.shape
    R = out_row.shape[1]
    N, F = x.shape
    window = window_rows or resident_window_rows(
        f_tile, jnp.dtype(x.dtype).itemsize)

    F_pad = pad_features(F, f_tile)
    num_windows = max(1, (N + window - 1) // window)
    N_pad = num_windows * window
    x_p = jnp.zeros((N_pad, F_pad), x.dtype).at[:N, :F].set(x)
    nf = F_pad // f_tile

    grid = (B, nf, num_windows)  # window axis innermost: consecutive
    out_slabs = pl.pallas_call(  # revisits of one output block accumulate
        functools.partial(_spmm_kernel_windowed, C=C, R=R, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C), lambda b, j, w: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j, w: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j, w: (b, 0)),
            pl.BlockSpec((window, f_tile), lambda b, j, w: (w, j)),
        ],
        out_specs=pl.BlockSpec((1, R, f_tile), lambda b, j, w: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, R, F_pad), jnp.float32),
        interpret=interpret,
    )(colidx, values, rowloc, x_p)

    return scatter_block_rows(out_slabs, out_row, n_rows, F)
