"""Accel-GCN SpMM as a Pallas TPU kernel.

TPU mapping of the paper's design (DESIGN.md §2):

* one grid step == one *block* of the block-level partition: a fixed-capacity
  slab of ``C = deg_bound`` non-zeros covering up to ``R`` contiguous
  (degree-sorted) output rows;
* the dense feature dimension is tiled at 128 lanes and iterated by a second
  grid axis — the *combined warp*: every HBM<->VMEM transfer of a dense row is
  a full-lane contiguous vector;
* the intra-block segment reduction (the paper's shared-memory
  ``atomicAdd_block``) becomes a one-hot MXU matmul ``[R, C] @ [C, F_tile]``
  entirely in VMEM — no atomics exist or are needed;
* cross-block accumulation for split rows (degree > C) is a segment-sum
  epilogue over the packed block outputs (TPU grids are sequential, so a
  revisit-accumulate output alias is also legal; see ops.py notes).

VMEM budget per grid step (f32, defaults C=256, R=64, F_tile=128):
  x slab        [C, F_tile]   128 KiB   (gather staging, scratch)
  out slab      [R, F_tile]    32 KiB
  colidx/values/rowloc [C]      3 KiB
  one-hot       [C, R]         64 KiB
  X feature tile [N_pad, F_tile] — resident path; for N_pad <= 4096 this is
  <= 2 MiB and fits comfortably; larger graphs use the row-window variant
  (``num_windows > 1``) which streams X in row windows and accumulates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_F_TILE = 128  # lane width — the "combined warp" quantum on TPU


def _spmm_kernel(colidx_ref, values_ref, rowloc_ref, x_ref, out_ref, *, C, R):
    """One block x one feature tile.

    colidx_ref: int32[1, C]; values_ref: f32[1, C]; rowloc_ref: int32[1, C]
    x_ref: [N_pad, F_tile] feature tile (VMEM resident)
    out_ref: [1, R, F_tile]
    """
    cols = colidx_ref[0, :]                      # [C]
    vals = values_ref[0, :].astype(jnp.float32)  # [C]
    rloc = rowloc_ref[0, :]                      # [C]

    # Gather C dense rows from the feature tile. On TPU this lowers to C
    # dynamic VMEM reads of one (8x128-aligned) row each; lanes are fully
    # coalesced because the feature tile is the minor dimension.
    gathered = x_ref[cols, :].astype(jnp.float32)            # [C, F_tile]
    gathered = gathered * vals[:, None]

    # Intra-block segment reduction as a one-hot MXU matmul (replaces
    # shared-memory atomics). Padding slots carry value 0 so their one-hot
    # row contributes nothing.
    onehot = (rloc[None, :] == jax.lax.broadcasted_iota(jnp.int32, (R, C), 0)
              ).astype(jnp.float32)                          # [R, C]
    out_ref[0, :, :] = jax.lax.dot_general(
        onehot, gathered, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "interpret", "f_tile"),
)
def spmm_block_slabs(
    colidx: jax.Array,   # int32[B, C]
    values: jax.Array,   # f32[B, C]
    rowloc: jax.Array,   # int32[B, C]
    out_row: jax.Array,  # int32[B, R]
    x: jax.Array,        # [N, F]
    n_rows: int,
    *,
    f_tile: int = DEFAULT_F_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Run the Accel-GCN SpMM kernel over packed slabs; returns [n_rows, F]."""
    B, C = colidx.shape
    R = out_row.shape[1]
    N, F = x.shape

    # Combined-warp alignment: pad F to the lane width (paper's pad-to-32,
    # scaled to TPU's 128 lanes), pad N to sublane multiple.
    F_pad = max(f_tile, ((F + f_tile - 1) // f_tile) * f_tile)
    N_pad = ((N + 7) // 8) * 8
    x_p = jnp.zeros((N_pad, F_pad), x.dtype).at[:N, :F].set(x)
    nf = F_pad // f_tile

    grid = (B, nf)
    out_slabs = pl.pallas_call(
        functools.partial(_spmm_kernel, C=C, R=R),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec((N_pad, f_tile), lambda b, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, R, f_tile), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, R, F_pad), jnp.float32),
        interpret=interpret,
    )(colidx, values, rowloc, x_p)

    # Epilogue: scatter packed block rows to global rows. Non-split blocks
    # write disjoint rows; split-row blocks accumulate here (sequential-grid
    # revisit accumulation is the real-TPU alternative; see DESIGN.md §2).
    flat = out_slabs.reshape(B * R, F_pad)
    seg = out_row.reshape(B * R)
    out = jax.ops.segment_sum(flat, seg, num_segments=n_rows + 1)
    return out[:n_rows, :F]
