"""Accel-GCN SpMM — HBM-resident feature matrix variant.

``spmm_accel.py`` keeps the feature tile VMEM-resident, which bounds the
graph at N_pad x 128 x 4B <= 2 MiB per tile (fine for layer-wise GCN
batches, not for web-scale graphs). This variant keeps X in HBM
(``memory_space=ANY``) and gathers the C rows a block needs with explicit
double-buffered DMA — the TPU embedding-gather pattern, driven by the same
block-partition metadata. VMEM cost is independent of N, so this is the
fallback regime of ``router.route_spmm`` (N_pad > MAX_WINDOWS x 4096 at
defaults); cost scales with nnz instead.

Per grid step (C=256, R=64 defaults, f32):
  row buffers (2 slots)  [2, 1, F_tile]    1 KiB   (one-ROW DMA granularity:
                                           gathered rows are scattered, so an
                                           8-row slab copy would move 8x the
                                           bytes for one useful row unless
                                           column indices happen to cluster)
  gathered slab          [C, F_tile]     128 KiB
  out slab               [R, F_tile]      32 KiB  (x2 pipeline buffers)
  colidx/values/rowloc   3 x [C]           3 KiB  (x2 pipeline buffers)
  one-hot                [C, R]            64 KiB

Batched multi-graph slabs (``spmm_batched`` merge) run unchanged: column
indices arrive pre-shifted into the concatenated feature rows, padded slab
slots carry value 0 with an in-bounds index, and fully-padded bucket blocks
(all values zero) skip their DMA loop entirely and write a zero output
block — so block-count bucketing costs bandwidth only for live blocks.

Validated in interpret mode against the same oracle as the resident-X
kernel; on hardware the DMA issue loop overlaps the one-hot MXU matmul of
the previous block (grid-level pipelining is left to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .router import pad_features, pad_rows
from .spmm_accel import scatter_block_rows

DEFAULT_F_TILE = 128


def _kernel(colidx_ref, values_ref, rowloc_ref, x_hbm, out_ref,
            gathered, row_buf, sem, *, C, R):
    """colidx/values/rowloc: [1, C] VMEM; x_hbm: [N_pad, F_pad] ANY (the
    UNTILED padded features — ANY refs see the whole array, so each DMA
    slices its own [1, F_tile] lane window at grid axis 1);
    out_ref: [1, R, F_tile]; gathered: [C, F_tile] VMEM scratch;
    row_buf: [2, 1, F_tile] VMEM scratch; sem: DMA semaphores [2]."""
    j = pl.program_id(1)                 # which feature tile this step owns
    f_tile = row_buf.shape[-1]
    cols = colidx_ref[0, :]
    vals = values_ref[0, :].astype(jnp.float32)
    rloc = rowloc_ref[0, :]

    # Bucket-padding blocks carry all-zero values: skip their C-row DMA loop
    # (and never read the uninitialized gather scratch) — a padded dispatch
    # pays grid-step overhead for dead blocks, not HBM bandwidth.
    live = jnp.any(vals != 0.0)

    @pl.when(live)
    def _gather_and_reduce():
        def issue(slot, k):
            cp = pltpu.make_async_copy(
                x_hbm.at[pl.ds(cols[k], 1), pl.ds(j * f_tile, f_tile)],
                row_buf.at[slot],
                sem.at[slot],
            )
            cp.start()

        def wait(slot, k):
            cp = pltpu.make_async_copy(
                x_hbm.at[pl.ds(cols[k], 1), pl.ds(j * f_tile, f_tile)],
                row_buf.at[slot],
                sem.at[slot],
            )
            cp.wait()

        # double-buffered gather: issue k+1 while storing k
        issue(0, 0)

        def body(k, _):
            slot = jax.lax.rem(k, 2)
            nxt = jax.lax.rem(k + 1, 2)

            @pl.when(k + 1 < C)
            def _pre():
                issue(nxt, k + 1)

            wait(slot, k)
            gathered[pl.ds(k, 1), :] = row_buf[slot].astype(jnp.float32)
            return ()

        jax.lax.fori_loop(0, C, body, ())

        g = gathered[...] * vals[:, None]
        onehot = (rloc[None, :] ==
                  jax.lax.broadcasted_iota(jnp.int32, (R, C), 0)
                  ).astype(jnp.float32)
        out_ref[0, :, :] = jax.lax.dot_general(
            onehot, g, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_not(live))
    def _dead_block():
        out_ref[0, :, :] = jnp.zeros_like(out_ref[0, :, :])


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret", "f_tile"))
def spmm_block_slabs_hbm(colidx, values, rowloc, out_row, x, n_rows,
                         *, f_tile: int = DEFAULT_F_TILE, interpret: bool = True):
    """HBM-gather SpMM over packed slabs; returns [n_rows, F] float32."""
    B, C = colidx.shape
    R = out_row.shape[1]
    N, F = x.shape
    F_pad = pad_features(F, f_tile)
    N_pad = pad_rows(N)
    x_p = jnp.zeros((N_pad, F_pad), x.dtype).at[:N, :F].set(x)
    nf = F_pad // f_tile

    out_slabs = pl.pallas_call(
        functools.partial(_kernel, C=C, R=R),
        grid=(B, nf),
        in_specs=[
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # X stays in HBM
        ],
        out_specs=pl.BlockSpec((1, R, f_tile), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, R, F_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((C, f_tile), jnp.float32),
            pltpu.VMEM((2, 1, f_tile), x_p.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(colidx, values, rowloc, x_p)

    return scatter_block_rows(out_slabs, out_row, n_rows, F)
