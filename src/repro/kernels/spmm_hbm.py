"""Accel-GCN SpMM — HBM-resident feature matrix variant.

``spmm_accel.py`` keeps the feature tile VMEM-resident, which bounds the
graph at N_pad x 128 x 4B <= ~2 MiB per tile (fine for layer-wise GCN
batches, not for web-scale graphs). This variant keeps X in HBM
(``memory_space=ANY``) and gathers the C rows a block needs with explicit
double-buffered DMA — the TPU embedding-gather pattern, driven by the same
block-partition metadata.

Per grid step (C=256 defaults, f32):
  row slabs (2 buffers)  2 x [8, F_tile]   8 KiB   (8-row DMA granularity)
  gathered slab          [C, F_tile]     128 KiB
  out slab               [R, F_tile]      <=32 KiB

Validated in interpret mode against the same oracle as the resident-X
kernel; on hardware the DMA issue loop overlaps the one-hot MXU matmul of
the previous block (grid-level pipelining is left to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_F_TILE = 128


def _kernel(colidx_ref, values_ref, rowloc_ref, x_hbm, out_ref,
            gathered, row_buf, sem, *, C, R):
    """colidx/values/rowloc: [1, C] VMEM; x_hbm: [N_pad, F_tile] ANY;
    out_ref: [1, R, F_tile]; gathered: [C, F_tile] VMEM scratch;
    row_buf: [2, 1, F_tile] VMEM scratch; sem: DMA semaphores [2]."""
    cols = colidx_ref[0, :]
    vals = values_ref[0, :].astype(jnp.float32)
    rloc = rowloc_ref[0, :]

    def issue(slot, k):
        cp = pltpu.make_async_copy(
            x_hbm.at[pl.ds(cols[k], 1), :],
            row_buf.at[slot],
            sem.at[slot],
        )
        cp.start()

    def wait(slot, k):
        cp = pltpu.make_async_copy(
            x_hbm.at[pl.ds(cols[k], 1), :],
            row_buf.at[slot],
            sem.at[slot],
        )
        cp.wait()

    # double-buffered gather: issue k+1 while storing k
    issue(0, 0)

    def body(k, _):
        slot = jax.lax.rem(k, 2)
        nxt = jax.lax.rem(k + 1, 2)

        @pl.when(k + 1 < C)
        def _pre():
            issue(nxt, k + 1)

        wait(slot, k)
        gathered[pl.ds(k, 1), :] = row_buf[slot].astype(jnp.float32)
        return ()

    jax.lax.fori_loop(0, C, body, ())

    g = gathered[...] * vals[:, None]
    onehot = (rloc[None, :] == jax.lax.broadcasted_iota(jnp.int32, (R, C), 0)
              ).astype(jnp.float32)
    out_ref[0, :, :] = jax.lax.dot_general(
        onehot, g, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret", "f_tile"))
def spmm_block_slabs_hbm(colidx, values, rowloc, out_row, x, n_rows,
                         *, f_tile: int = DEFAULT_F_TILE, interpret: bool = True):
    """HBM-gather SpMM over packed slabs; returns [n_rows, F] float32."""
    B, C = colidx.shape
    R = out_row.shape[1]
    N, F = x.shape
    F_pad = max(f_tile, ((F + f_tile - 1) // f_tile) * f_tile)
    N_pad = ((N + 7) // 8) * 8
    x_p = jnp.zeros((N_pad, F_pad), x.dtype).at[:N, :F].set(x)
    nf = F_pad // f_tile

    out_slabs = pl.pallas_call(
        functools.partial(_kernel, C=C, R=R),
        grid=(B, nf),
        in_specs=[
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec((1, C), lambda b, j: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # X stays in HBM
        ],
        out_specs=pl.BlockSpec((1, R, f_tile), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, R, F_pad), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((C, f_tile), jnp.float32),
            pltpu.VMEM((2, 1, f_tile), x_p.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(colidx, values, rowloc, x_p)

    flat = out_slabs.reshape(B * R, F_pad)
    seg = out_row.reshape(B * R)
    out = jax.ops.segment_sum(flat, seg, num_segments=n_rows + 1)
    return out[:n_rows, :F]
