"""Jit'd wrappers around the Pallas kernels + portable jnp twins.

Every kernel has three callables:
  * ``*_pallas``  — the Pallas kernel (interpret=True on CPU, compiled on TPU)
  * ``*_blocked`` — a pure-jnp twin with the *same* slab layout and math
                    (the portable production path; XLA fuses it well)
  * oracle        — in ref.py (layout-free ground truth)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .router import route_spmm
from .spmm_accel import spmm_block_slabs, spmm_block_slabs_windowed
from .spmm_hbm import spmm_block_slabs_hbm
from .grouped_matmul import grouped_matmul

__all__ = ["spmm_pallas", "spmm_pallas_windowed", "spmm_pallas_hbm",
           "spmm_auto", "spmm_blocked", "spmm_batched",
           "grouped_matmul_pallas", "grouped_matmul_blocked"]


def spmm_batched(slab_list, x_list, n_rows_list, *, backend="pallas",
                 interpret=True, pad_blocks_to=None, return_decision=False):
    """Fused multi-graph SpMM (one pallas_call for the whole batch)."""
    from .spmm_batched import spmm_batched as _batched
    return _batched(slab_list, x_list, n_rows_list, backend=backend,
                    interpret=interpret, pad_blocks_to=pad_blocks_to,
                    return_decision=return_decision)


def spmm_pallas(slabs, x, n_rows, *, interpret=True):
    """Resident-X kernel; raises VmemBudgetError past N_pad <= 4096 (f32)."""
    return spmm_block_slabs(
        slabs["colidx"], slabs["values"], slabs["rowloc"], slabs["out_row"],
        x, n_rows, interpret=interpret,
    )


def spmm_pallas_windowed(slabs, x, n_rows, *, interpret=True,
                         window_rows=None):
    """Row-window streaming variant: X visits VMEM one window at a time."""
    return spmm_block_slabs_windowed(
        slabs["colidx"], slabs["values"], slabs["rowloc"], slabs["out_row"],
        x, n_rows, interpret=interpret, window_rows=window_rows,
    )


def spmm_pallas_hbm(slabs, x, n_rows, *, interpret=True):
    """HBM-resident X variant (double-buffered DMA gather) for graphs whose
    feature tile exceeds VMEM."""
    return spmm_block_slabs_hbm(
        slabs["colidx"], slabs["values"], slabs["rowloc"], slabs["out_row"],
        x, n_rows, interpret=interpret,
    )


def spmm_auto(slabs, x, n_rows, *, interpret=True, return_decision=False):
    """VMEM-routed single-graph dispatch: resident / windowed / hbm chosen
    from the feature-operand shape (see ``router.route_spmm``)."""
    decision = route_spmm(
        int(x.shape[0]), int(x.shape[1]),
        int(slabs["C"]), int(slabs["R"]),
        itemsize=jnp.dtype(x.dtype).itemsize)
    fn = {"resident": spmm_pallas, "windowed": spmm_pallas_windowed,
          "hbm": spmm_pallas_hbm}[decision.backend]
    out = fn(slabs, x, n_rows, interpret=interpret)
    return (out, decision) if return_decision else out


@functools.partial(jax.jit, static_argnames=("n_rows", "block_chunk"))
def spmm_blocked(colidx, values, rowloc, out_row, x, n_rows, block_chunk: int = 1024):
    """jnp twin of the Pallas kernel: identical slab math, chunked over blocks
    to bound the gathered-intermediate footprint (the VMEM analogue)."""
    B, C = colidx.shape
    R = out_row.shape[1]
    F = x.shape[1]
    bc = min(block_chunk, B) if B else 1
    Bp = ((B + bc - 1) // bc) * bc if B else bc
    pad = Bp - B

    def padded(a, fill):
        return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1), constant_values=fill)

    ci = padded(colidx, 0).reshape(-1, bc, C)
    va = padded(values, 0).reshape(-1, bc, C)
    rl = padded(rowloc, R - 1).reshape(-1, bc, C)

    def chunk_fn(args):
        ci_c, va_c, rl_c = args
        gathered = va_c[..., None].astype(jnp.float32) * x[ci_c].astype(jnp.float32)
        onehot = jax.nn.one_hot(rl_c, R, dtype=jnp.float32)
        return jnp.einsum("bcr,bcf->brf", onehot, gathered)

    slab_out = jax.lax.map(chunk_fn, (ci, va, rl))          # [nc, bc, R, F]
    flat = slab_out.reshape(Bp * R, F)[: B * R]
    seg = out_row.reshape(B * R)
    out = jax.ops.segment_sum(flat, seg, num_segments=n_rows + 1)
    return out[:n_rows]


def grouped_matmul_pallas(x, w, block_expert, *, interpret=True, **tiles):
    return grouped_matmul(x, w, block_expert, interpret=interpret, **tiles)


@functools.partial(jax.jit, static_argnames=("m_tile",))
def grouped_matmul_blocked(x, w, block_expert, m_tile: int = 128):
    """jnp twin: per-block dynamic weight pick + dense matmul, scanned."""
    M, K = x.shape
    nb = M // m_tile
    xb = x.reshape(nb, m_tile, K)

    def step(_, args):
        xt, e = args
        return None, jnp.dot(xt.astype(jnp.float32), w[e].astype(jnp.float32),
                             preferred_element_type=jnp.float32)

    _, out = jax.lax.scan(step, None, (xb, block_expert))
    return out.reshape(M, -1)
