"""Batched multi-graph SpMM: many graphs through ONE ``pallas_call``.

Serving traffic arrives as independent per-graph requests, but each graph's
block partition is just a ``[B_g, C_g]`` slab stack — a shape the kernel grid
already iterates block-by-block. So a batch of graphs fuses by construction:

1. pad every graph's slabs to the batch-wide ``(C, R)`` capacity;
2. shift each graph's ``colidx`` by its feature-row offset and its ``out_row``
   by its output-row offset (the per-graph drop sentinel ``n_rows_g`` is
   remapped to the single batch-wide sentinel ``N_out``), then concatenate
   along the block axis;
3. route the merged ``[B_total, C]`` slabs + row-concatenated features to a
   single-graph kernel — ONE compilation, one dispatch, one scatter
   epilogue. The concatenated feature matrix is where a batch of
   individually-fine graphs silently overflows the resident kernel's VMEM
   budget (N_pad multiplies by batch size!), so ``backend="auto"`` asks
   ``router.route_spmm`` to pick resident / windowed / HBM-gather from the
   merged shape, and ``backend="pallas"`` (forced resident) raises
   ``VmemBudgetError`` instead of silently compiling an oversized tile;
4. slice each graph's rows back out of the batched output.

Padding slab slots carry value 0 and padding block rows scatter to the
sentinel row, so fused outputs are bit-identical in structure to per-graph
runs (fp32 reduction order within a block is unchanged).

``pad_blocks_to`` rounds the merged block count up to a bucket so repeated
batches with different graph mixes reuse one compiled kernel.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .router import RoutingDecision, route_spmm
from .spmm_accel import spmm_block_slabs, spmm_block_slabs_windowed
from .spmm_hbm import spmm_block_slabs_hbm

__all__ = ["batch_graph_slabs", "spmm_batched", "bucket_blocks"]


def bucket_blocks(b_total: int, min_bucket: int = 8) -> int:
    """Next power-of-two block bucket (>= min_bucket) for jit-cache reuse.

    Power-of-two tiers bound padding waste below 2x the live block count
    (for ``b_total >= min_bucket``); the old fixed 256 floor padded a
    3-block batch to 256 blocks — 85x dead grid steps. Raise ``min_bucket``
    only to trade those dead steps for fewer compiled grid shapes.
    """
    bucket = min_bucket
    while bucket < b_total:
        bucket *= 2
    return bucket


def batch_graph_slabs(
    slab_list: Sequence[Dict],
    n_rows_list: Sequence[int],
    n_cols_list: Sequence[int],
    pad_blocks_to: Optional[int] = None,
) -> Tuple[Dict, np.ndarray, np.ndarray, int]:
    """Merge per-graph slab dicts into one batch-wide slab dict.

    Returns ``(merged, out_offsets, col_offsets, n_out_total)`` where
    ``merged`` has the same keys as a single-graph slab dict (colidx, values,
    rowloc, out_row, R, C) and graph ``i``'s output rows live at
    ``[out_offsets[i], out_offsets[i] + n_rows_list[i])`` of the batched
    result. Host-side numpy; cost is O(sum B_g * C) copies, far below a
    partition rebuild.
    """
    G = len(slab_list)
    assert G == len(n_rows_list) == len(n_cols_list) and G > 0
    C = max(int(s["C"]) for s in slab_list)
    R = max(int(s["R"]) for s in slab_list)
    out_offsets = np.concatenate(([0], np.cumsum(n_rows_list)))
    col_offsets = np.concatenate(([0], np.cumsum(n_cols_list)))
    n_out = int(out_offsets[-1])

    cols, vals, rlocs, orows = [], [], [], []
    for i, s in enumerate(slab_list):
        ci = np.asarray(s["colidx"], dtype=np.int32)
        va = np.asarray(s["values"], dtype=np.float32)
        rl = np.asarray(s["rowloc"], dtype=np.int32)
        orw = np.asarray(s["out_row"], dtype=np.int32)
        Bg, Cg = ci.shape
        Rg = orw.shape[1]
        # out_row: per-graph sentinel n_rows_g -> batch sentinel n_out, live
        # rows shift by the graph's output offset.
        orw = np.where(orw == n_rows_list[i],
                       n_out, orw + out_offsets[i]).astype(np.int32)
        # colidx shifts into the concatenated feature rows; padding slots
        # (value 0) keep a valid index so the gather stays in bounds.
        ci = ci + np.int32(col_offsets[i])
        if Cg < C:
            ci = np.pad(ci, ((0, 0), (0, C - Cg)),
                        constant_values=int(col_offsets[i]))
            va = np.pad(va, ((0, 0), (0, C - Cg)))
            rl = np.pad(rl, ((0, 0), (0, C - Cg)), constant_values=R - 1)
        if Rg < R:
            orw = np.pad(orw, ((0, 0), (0, R - Rg)), constant_values=n_out)
        cols.append(ci)
        vals.append(va)
        rlocs.append(rl)
        orows.append(orw)

    colidx = np.concatenate(cols)
    values = np.concatenate(vals)
    rowloc = np.concatenate(rlocs)
    out_row = np.concatenate(orows)

    B = colidx.shape[0]
    if pad_blocks_to is not None and pad_blocks_to > B:
        pad = pad_blocks_to - B
        colidx = np.pad(colidx, ((0, pad), (0, 0)))
        values = np.pad(values, ((0, pad), (0, 0)))
        rowloc = np.pad(rowloc, ((0, pad), (0, 0)), constant_values=R - 1)
        out_row = np.pad(out_row, ((0, pad), (0, 0)), constant_values=n_out)

    merged = {"colidx": colidx, "values": values, "rowloc": rowloc,
              "out_row": out_row, "R": R, "C": C}
    return merged, out_offsets, col_offsets, n_out


_PALLAS_KERNELS = {
    "resident": spmm_block_slabs,
    "windowed": spmm_block_slabs_windowed,
    "hbm": spmm_block_slabs_hbm,
}


def spmm_batched(
    slab_list: Sequence[Dict],
    x_list: Sequence[jax.Array],
    n_rows_list: Sequence[int],
    *,
    backend: str = "pallas",
    interpret: bool = True,
    pad_blocks_to: Optional[int] = None,
    return_decision: bool = False,
    grid_order: str = "block_major",
) -> List[jax.Array] | Tuple[List[jax.Array], Optional[RoutingDecision]]:
    """Fused SpMM over several graphs; returns one ``[n_rows_g, F_g]`` output
    per graph (degree-sorted row order, same as the single-graph kernel).

    Feature matrices may differ in width; they are right-padded to the batch
    max ``F`` (padding columns are sliced off on the way out).

    Backends: ``auto`` routes the merged dispatch (resident / windowed /
    hbm) by VMEM footprint; ``pallas`` forces the resident kernel and raises
    ``VmemBudgetError`` when the concatenated features exceed its budget;
    ``windowed`` / ``hbm`` force those variants; ``blocked`` is the portable
    jnp twin. With ``return_decision=True`` the routing record (or ``None``
    for ``blocked``) comes back alongside the outputs.

    ``grid_order`` ("block_major" | "ft_major") selects the resident
    kernel's grid iteration order (see
    :func:`repro.kernels.spmm_accel.spmm_block_slabs`); dispatches that
    route to the windowed/HBM kernels ignore it.
    """
    G = len(slab_list)
    assert G == len(x_list) == len(n_rows_list) and G > 0
    n_cols_list = [int(x.shape[0]) for x in x_list]
    f_list = [int(x.shape[1]) for x in x_list]
    F = max(f_list)

    merged, out_off, _, n_out = batch_graph_slabs(
        slab_list, list(n_rows_list), n_cols_list, pad_blocks_to=pad_blocks_to)

    x_cat = jnp.concatenate(
        [jnp.pad(jnp.asarray(x, dtype=jnp.float32),
                 ((0, 0), (0, F - f))) if f < F
         else jnp.asarray(x, dtype=jnp.float32)
         for x, f in zip(x_list, f_list)], axis=0)

    decision: Optional[RoutingDecision] = None
    n_x = int(x_cat.shape[0])  # sum of n_cols — the quantity that overflows
    if backend in ("pallas", "windowed", "hbm", "auto"):
        force = {"pallas": "resident",
                 "windowed": "windowed", "hbm": "hbm"}.get(backend)
        decision = route_spmm(n_x, F, int(merged["C"]),
                              int(merged["R"]), force=force)
        kernel = _PALLAS_KERNELS[decision.backend]
        kernel_kwargs = ({"grid_order": grid_order}
                         if decision.backend == "resident" else {})
        out = kernel(
            jnp.asarray(merged["colidx"]), jnp.asarray(merged["values"]),
            jnp.asarray(merged["rowloc"]), jnp.asarray(merged["out_row"]),
            x_cat, n_out, interpret=interpret, **kernel_kwargs)
    elif backend == "blocked":
        from .ops import spmm_blocked  # deferred: ops re-exports this module
        out = spmm_blocked(
            jnp.asarray(merged["colidx"]), jnp.asarray(merged["values"]),
            jnp.asarray(merged["rowloc"]), jnp.asarray(merged["out_row"]),
            x_cat, n_out)
    else:
        raise ValueError(f"batched spmm backend must be "
                         f"auto|pallas|windowed|hbm|blocked, got {backend!r}")

    outs = [out[int(out_off[i]):int(out_off[i + 1]), :f_list[i]]
            for i in range(G)]
    return (outs, decision) if return_decision else outs
