"""AdamW with fp32 master weights over bf16 params, global-norm clipping,
cosine LR schedule, and a bf16 gradient-compression hook for the cross-pod
all-reduce (DESIGN.md §6: distributed-optimization tricks).

State layout (all sharded like the params via `sharding.param_specs`):
  m, v      fp32 moments
  master    fp32 master copy (only when params are bf16)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 copy of params (or None-like empty dict)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros), master)


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup, warm, cos)


def compress_grads(grads):
    """bf16 gradient compression for the cross-pod reduce: halves the
    collective payload; moments/updates stay fp32."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, max_grad_norm: Optional[float] = 1.0):
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.zeros(())
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)

    def upd(master, mm, vv):
        mh = mm / b1c
        vh = vv / b2c
        return master - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * master)

    master = jax.tree.map(upd, state.master, m, v)
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), master, params)
    return new_params, AdamWState(step, m, v, master), {"grad_norm": gnorm}
