"""SamplingService: sampled GCN inference through the serving engine.

The bridge between the store/sampler layers and the plan-cache/SpMM
serving path. Per seed batch:

1. the k-hop frontier is sampled (or found in the frontier LRU — seed
   batches recur heavily in production streams, so the sampled frontier
   AND its partition plans amortize);
2. every hop's induced bipartite block registers with the engine under a
   CONTENT-derived id (:meth:`GraphServeEngine.register_subgraph`), so
   identical frontiers — across batches, callers, or service restarts —
   partition exactly once;
3. inference runs the blocks outermost-first through
   ``engine.submit()``: each hop is one batched-SpMM dispatch, fused by
   the engine with whatever else is in flight; the final hop uses the
   gather epilogue (:meth:`GraphServeEngine.submit_gather`) to return
   per-seed rows only.

Liveness: the service subscribes to the store's delta feed. A delta whose
touched aggregation rows intersect a cached frontier's receptive field
either RIDES THE PR-7 REPAIR PATH — for full-fanout frontiers whose id
maps can express every changed edge, the delta is relabeled per block and
routed through ``engine.mutate()``, incrementally repairing the cached
plans — or, when the change cannot be expressed (capped fanout, or an
insert from a node outside the frontier), the entry is dropped and
resampled on next use. Either way the service never serves a stale
frontier. Untouched frontiers are untouched.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.plan_repair import EdgeDelta
from .sampler import Frontier, sample_frontier

__all__ = ["SamplingService"]


def _intersects(a: np.ndarray, b: np.ndarray) -> bool:
    """Do two sorted-unique id arrays share an element?"""
    if len(a) == 0 or len(b) == 0:
        return False
    idx = np.searchsorted(b, a)
    idx = np.clip(idx, 0, len(b) - 1)
    return bool((b[idx] == a).any())


def _member(sorted_ids: np.ndarray, nodes: np.ndarray) -> np.ndarray:
    """Membership mask of ``nodes`` in a sorted-unique id array."""
    if len(sorted_ids) == 0:
        return np.zeros(len(nodes), dtype=bool)
    idx = np.clip(np.searchsorted(sorted_ids, nodes), 0,
                  len(sorted_ids) - 1)
    return sorted_ids[idx] == nodes


class SamplingService:
    """Serve seed-node batches of ONE huge graph by sampled inference.

    ``sampler`` is anything with the store's ``sample_in_neighbors``
    signature: a :class:`~repro.sampling.store.GraphStore`, a
    :class:`~repro.sampling.store.PartitionedStoreClient` routing remote
    hops over the peer data plane, or a test double. When it exposes
    ``add_listener`` (the local store case), the service subscribes for
    frontier invalidation; a partitioned client's LOCAL shard can be
    passed as ``store=`` to get the same liveness.

    ``fanouts[k]`` caps hop k (``None`` = all in-edges). The frontier LRU
    holds ``max_cached_frontiers`` entries keyed by the SET of seed nodes
    (order-insensitive — per-call seed order is restored by the gather
    epilogue), the fanout spec and the sampling seed.
    """

    def __init__(self, engine, sampler, fanouts: Sequence[Optional[int]],
                 *, sample_seed: int = 0, replace: bool = False,
                 max_cached_frontiers: int = 64,
                 store=None, klass: str = "default"):
        if not len(fanouts):
            raise ValueError("need at least one hop")
        self.engine = engine
        self.sampler = sampler
        self.fanouts = tuple(fanouts)
        self.sample_seed = int(sample_seed)
        self.replace = bool(replace)
        self.max_cached_frontiers = int(max_cached_frontiers)
        self.klass = klass
        # key -> {"frontier": Frontier, "gids": [gid per block]}
        self._cache: "OrderedDict[tuple, Dict]" = OrderedDict()
        self._lock = threading.Lock()
        self.frontier_hits = 0
        self.frontier_misses = 0
        self.frontiers_evicted = 0
        self.frontiers_invalidated = 0
        self.frontier_mutations = 0
        self.sampled_edges = 0
        listen_on = store if store is not None else sampler
        if hasattr(listen_on, "add_listener"):
            listen_on.add_listener(self._on_delta)

    # ------------------------------------------------------------- frontier
    def _key(self, seed_set: np.ndarray) -> tuple:
        return (seed_set.tobytes(), self.fanouts, self.replace,
                self.sample_seed)

    def frontier_for(self, seeds: np.ndarray) -> Frontier:
        """The (cached) frontier serving this seed batch. Public so
        benchmarks/tests can inspect layer sizes and content keys."""
        return self._lookup(np.asarray(seeds, dtype=np.int64))["frontier"]

    def _lookup(self, seeds: np.ndarray) -> Dict:
        seed_set = np.unique(seeds)
        key = self._key(seed_set)
        with self._lock:
            entry = self._cache.get(key)
            if entry is not None:
                self._cache.move_to_end(key)
                self.frontier_hits += 1
                return entry
            self.frontier_misses += 1
        # sample outside the lock: slow, touches the (possibly remote)
        # store; a racing duplicate miss just re-registers idempotently
        frontier = sample_frontier(
            self.sampler.sample_in_neighbors, seed_set, self.fanouts,
            seed=self.sample_seed, replace=self.replace)
        gids = [self.engine.register_subgraph(b.graph, prefix="frontier")
                for b in frontier.blocks]
        entry = {"frontier": frontier, "gids": gids}
        evicted: List[Dict] = []
        with self._lock:
            self.sampled_edges += sum(b.n_edges for b in frontier.blocks)
            self._cache[key] = entry
            self._cache.move_to_end(key)
            while len(self._cache) > self.max_cached_frontiers:
                _, old = self._cache.popitem(last=False)
                evicted.append(old)
                self.frontiers_evicted += 1
            live = {g for e in self._cache.values() for g in e["gids"]}
        for old in evicted:
            for gid in old["gids"]:
                if gid not in live:   # content-derived ids can be shared
                    self.engine.unregister_graph(gid)
        return entry

    # ------------------------------------------------------------ inference
    def aggregate(self, seeds: np.ndarray, x: np.ndarray) -> np.ndarray:
        """Pure k-hop aggregation (no weights): ``(A'^k x)[seeds]`` under
        full fanout, its sampled estimate otherwise. One engine dispatch
        per hop, outermost block first; the last hop gathers seed rows.
        """
        seeds = np.asarray(seeds, dtype=np.int64)
        entry = self._lookup(seeds)
        frontier: Frontier = entry["frontier"]
        h = jnp.asarray(np.asarray(x)[frontier.input_nodes])
        for k in range(frontier.num_hops - 1, 0, -1):
            h = self.engine.submit(entry["gids"][k], h,
                                   klass=self.klass).result()
        rows = np.searchsorted(frontier.layers[0], seeds)
        return np.asarray(self.engine.submit_gather(
            entry["gids"][0], h, rows, klass=self.klass).result())

    def infer(self, seeds: np.ndarray, x: np.ndarray, params: List[Dict],
              *, act=jax.nn.relu) -> np.ndarray:
        """Sampled GCN forward pass, mirroring
        :func:`repro.models.gcn.gcn_forward` layer semantics exactly
        (``h = aggr(h @ W) + b``, activation between layers): under full
        fanout the result is BIT-identical to running the full graph and
        gathering seed rows. ``len(params)`` must equal the hop count.
        Returns ``[len(seeds), out_dim]`` in the caller's seed order.
        """
        seeds = np.asarray(seeds, dtype=np.int64)
        entry = self._lookup(seeds)
        frontier: Frontier = entry["frontier"]
        L = frontier.num_hops
        if len(params) != L:
            raise ValueError(f"{len(params)} layers for {L} sampled hops")
        rows = np.searchsorted(frontier.layers[0], seeds)
        h = jnp.asarray(np.asarray(x)[frontier.input_nodes])
        for i, p in enumerate(params):
            gid = entry["gids"][L - 1 - i]
            z = jnp.dot(h, p["w"])
            if i == L - 1:
                agg = self.engine.submit_gather(gid, z, rows,
                                                klass=self.klass).result()
            else:
                agg = self.engine.submit(gid, z, klass=self.klass).result()
            h = agg + p["b"]
            if i < L - 1:
                h = act(h)
        return np.asarray(h)

    # ---------------------------------------------------------- invalidation
    def _on_delta(self, touched: np.ndarray, delta: EdgeDelta) -> None:
        """Store-delta hook: repair or drop every cached frontier whose
        receptive field the delta touches (see module docstring)."""
        with self._lock:
            items = list(self._cache.items())
        mutated_gids: set = set()
        for key, entry in items:
            frontier: Frontier = entry["frontier"]
            # layers nest, so the union of all destination sets is the
            # second-outermost layer
            receptive = frontier.layers[frontier.num_hops - 1]
            if not _intersects(touched, receptive):
                continue
            if self._repairable(frontier, delta):
                self._mutate_entry(entry, delta, mutated_gids)
                with self._lock:
                    self.frontier_mutations += 1
            else:
                self._drop(key)

    def _repairable(self, frontier: Frontier, delta: EdgeDelta) -> bool:
        """Can every changed edge be expressed inside the cached frontier?

        Only full-fanout frontiers qualify (a capped frontier is a sample
        of the pre-delta graph; its edge set must be redrawn). An insert
        ``u -> v`` qualifies iff ``u`` already sits in the source layer of
        v's FIRST hop — then every deeper hop already aggregates u's own
        neighborhood (layers nest), so no cascade is needed. Deletes
        always qualify (a frontier can only lose edges it has).
        """
        if any(f is not None for f in self.fanouts):
            return False
        for u, v in zip(delta.insert_src, delta.insert_dst):
            for k in range(frontier.num_hops):
                if _member(frontier.layers[k], np.asarray([v]))[0]:
                    if not _member(frontier.layers[k + 1],
                                   np.asarray([u]))[0]:
                        return False
                    break
        return True

    def _mutate_entry(self, entry: Dict, delta: EdgeDelta,
                      mutated_gids: set) -> None:
        """Relabel the delta per block and route it through the PR-7
        ``engine.mutate()`` repair path; the cached block graphs advance
        in lockstep so later repairs see current content."""
        frontier: Frontier = entry["frontier"]
        for k, block in enumerate(frontier.blocks):
            local = self._localize(block, delta)
            if local is None:
                continue
            gid = entry["gids"][k]
            if gid not in mutated_gids:   # shared-content id: apply once
                mutated_gids.add(gid)
                self.engine.mutate(gid, local, klass=self.klass).result()
            block.graph = local.apply(block.graph)

    @staticmethod
    def _localize(block, delta: EdgeDelta) -> Optional[EdgeDelta]:
        """The delta in one block's local coordinates (aggregation rows =
        destinations), keeping only edges both id maps can express.
        Returns None when nothing translates."""
        def pick(src, dst):
            keep = (_member(block.dst_nodes, dst)
                    & _member(block.src_nodes, src))
            return (block.to_local_dst(dst[keep]),
                    block.to_local_src(src[keep]), keep)

        ins_r, ins_c, ins_keep = pick(delta.insert_src, delta.insert_dst)
        del_r, del_c, _ = pick(delta.delete_src, delta.delete_dst)
        if len(ins_r) == 0 and len(del_r) == 0:
            return None
        val = (delta.insert_val[ins_keep]
               if delta.insert_val is not None else None)
        # on_missing is forgiving here by design: an edge the frontier
        # never sampled simply isn't there to delete
        return EdgeDelta(insert_src=ins_r, insert_dst=ins_c,
                         insert_val=val, delete_src=del_r,
                         delete_dst=del_c,
                         on_duplicate=delta.on_duplicate,
                         on_missing="ignore")

    def _drop(self, key: tuple) -> None:
        with self._lock:
            entry = self._cache.pop(key, None)
            if entry is None:
                return
            self.frontiers_invalidated += 1
            live = {g for e in self._cache.values() for g in e["gids"]}
        for gid in entry["gids"]:
            if gid not in live:
                self.engine.unregister_graph(gid)

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        with self._lock:
            lookups = self.frontier_hits + self.frontier_misses
            return {
                "frontier_hits": self.frontier_hits,
                "frontier_misses": self.frontier_misses,
                "frontier_hit_rate": (self.frontier_hits / lookups
                                      if lookups else 0.0),
                "frontiers_cached": len(self._cache),
                "frontiers_evicted": self.frontiers_evicted,
                "frontiers_invalidated": self.frontiers_invalidated,
                "frontier_mutations": self.frontier_mutations,
                "sampled_edges": self.sampled_edges,
            }
