"""GraphStore: ONE giant evolving graph behind the neighbor sampler.

The serving scenarios so far register many small-to-mid graphs; the
production GNN workload (recommendation, fraud, social) is a single huge
graph served by sampled inference. The store holds that graph in BOTH
orientations:

* ``out_adj`` — the edge-stream CSR (row ``u`` lists u's OUT-neighbors),
  the orientation :class:`~repro.core.plan_repair.EdgeDelta` streams in;
* ``in_adj``  — :func:`~repro.core.graph.csr_transpose` of it (row ``v``
  lists v's IN-neighbors), the orientation GCN aggregation reads and the
  sampler walks: sampling the k-hop receptive field of a seed means
  walking in-edges.

``apply_delta`` keeps the two views consistent (the delta applies directly
to ``out_adj`` and transposed to ``in_adj``) and notifies listeners with
the touched AGGREGATION rows — the hook the sampling service uses to
invalidate or mutate cached frontier plans instead of serving stale ones.

``partition(n_parts)`` splits the store into contiguous-node-range shards
for the fleet's hosts. Shards keep FULL-HEIGHT matrices (rows outside the
owned range are empty), so global node ids stay valid everywhere and the
cross-partition exchange never translates ids.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import (
    CSRGraph, csr_transpose, gcn_normalize, _concat_ranges,
)
from ..core.plan_repair import EdgeDelta

__all__ = ["GraphStore", "PartitionedStoreClient", "SampleFn"]

# (nodes, fanout, seed, hop, replace) -> (src, dst, val); the shape every
# sampling backend shares: the local store method, a partition client, and
# the remote end of a FrontierExchange channel
SampleFn = Callable[..., Tuple[np.ndarray, np.ndarray, np.ndarray]]


def _sample_rows(rowptr: np.ndarray, colidx: np.ndarray,
                 values: np.ndarray, nodes: np.ndarray,
                 fanout: Optional[int], seed: int, hop: int,
                 replace: bool) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic per-node neighbor sampling over CSR rows.

    The rng for node ``v`` at hop ``k`` is ``default_rng([seed, k, v])`` —
    a pure function of (seed, hop, node), independent of batch composition
    and of which shard executes it, so a partitioned store samples
    bit-identically to the monolithic one and a numpy reference sampler
    can reproduce the service exactly. Chosen slots are sorted, keeping
    every row's edges in parent-CSR relative order (compaction stays
    stable). Nodes with degree <= fanout (without replacement) take ALL
    edges — full fanout (``fanout=None``) is the exact-aggregation path.
    """
    nodes = np.asarray(nodes, dtype=np.int64)
    starts, ends = rowptr[nodes], rowptr[nodes + 1]
    degs = ends - starts
    if fanout is None:
        total = int(degs.sum())
        idx = _concat_ranges(starts, degs, total)
        src = colidx[idx].astype(np.int64)
        dst = np.repeat(nodes, degs)
        return src, dst, values[idx]
    src_parts: List[np.ndarray] = []
    dst_parts: List[np.ndarray] = []
    val_parts: List[np.ndarray] = []
    for v, lo, d in zip(nodes, starts, degs):
        d = int(d)
        if d == 0:
            continue
        if not replace and d <= fanout:
            idx = np.arange(lo, lo + d)
        else:
            rng = np.random.default_rng([seed, hop, int(v)])
            idx = lo + np.sort(rng.choice(d, size=fanout, replace=replace))
        src_parts.append(colidx[idx].astype(np.int64))
        dst_parts.append(np.full(len(idx), v, dtype=np.int64))
        val_parts.append(values[idx])
    if not src_parts:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), np.zeros(0, dtype=np.float32)
    return (np.concatenate(src_parts), np.concatenate(dst_parts),
            np.concatenate(val_parts))


@dataclasses.dataclass
class GraphStore:
    """Both orientations of one (possibly sharded) graph + delta plumbing.

    ``node_range`` is the contiguous ``[lo, hi)`` range of aggregation
    rows this store owns. The monolithic store owns everything; shards
    from :meth:`partition` own their slice but keep full-height matrices.
    """

    out_adj: CSRGraph
    in_adj: CSRGraph
    node_range: Tuple[int, int]
    version: int = 0

    def __post_init__(self):
        self._listeners: List[Callable[[np.ndarray, EdgeDelta], None]] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------- building
    @classmethod
    def build(cls, g_out: CSRGraph, *, normalize: bool = False,
              add_self_loops: bool = True) -> "GraphStore":
        """Build from an edge-stream CSR (row u -> out-neighbors).

        With ``normalize=True`` the store holds the GCN-normalized
        operator: ``in_adj`` carries ``D^-1/2 (A+I) D^-1/2`` values (what
        aggregation dispatches), and ``out_adj`` is re-derived by
        transposing BACK so the two views stay exact mirrors — including
        the added self-loop edges.
        """
        in_adj = csr_transpose(g_out)
        if normalize:
            in_adj = gcn_normalize(in_adj, add_self_loops=add_self_loops)
        out_adj = csr_transpose(in_adj)
        return cls(out_adj=out_adj, in_adj=in_adj,
                   node_range=(0, in_adj.n_rows))

    @property
    def n_nodes(self) -> int:
        return self.in_adj.n_rows

    @property
    def n_edges(self) -> int:
        return self.in_adj.nnz

    def owns(self, nodes: np.ndarray) -> np.ndarray:
        lo, hi = self.node_range
        nodes = np.asarray(nodes)
        return (nodes >= lo) & (nodes < hi)

    def in_degrees(self, nodes: np.ndarray) -> np.ndarray:
        nodes = np.asarray(nodes, dtype=np.int64)
        return (self.in_adj.rowptr[nodes + 1]
                - self.in_adj.rowptr[nodes]).astype(np.int64)

    # ------------------------------------------------------------- sampling
    def sample_in_neighbors(self, nodes: np.ndarray,
                            fanout: Optional[int] = None, *,
                            seed: int = 0, hop: int = 0,
                            replace: bool = False
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Sample up to ``fanout`` in-edges per node; returns the sampled
        COO triple ``(src, dst, val)`` grouped by ``dst`` in input-node
        order. Nodes outside this shard's owned range are a caller bug
        (they would silently sample an empty row) and raise.
        """
        nodes = np.asarray(nodes, dtype=np.int64)
        if len(nodes) and not self.owns(nodes).all():
            bad = nodes[~self.owns(nodes)][:5]
            raise ValueError(
                f"nodes {bad.tolist()} outside owned range "
                f"{self.node_range} — route via PartitionedStoreClient")
        a = self.in_adj
        return _sample_rows(a.rowptr, a.colidx, a.values, nodes,
                            fanout, seed, hop, replace)

    # ------------------------------------------------------------- mutation
    def add_listener(self, fn: Callable[[np.ndarray, EdgeDelta], None]
                     ) -> None:
        """``fn(touched_agg_rows, delta)`` runs after every applied delta
        (same thread, store already updated). The sampling service hangs
        its frontier invalidation here."""
        with self._lock:
            self._listeners.append(fn)

    def apply_delta(self, delta: EdgeDelta) -> int:
        """Apply an edge-stream delta (``u -> v`` orientation, exactly what
        engines' ``mutate()`` takes) to BOTH views and bump the version.

        The delta applies directly to ``out_adj`` and transposed to
        ``in_adj`` — the touched AGGREGATION rows are the delta's dst
        nodes, which is what listeners receive. Values ride verbatim (the
        PR-7 streaming convention: a delta never re-normalizes).
        Returns the new version.
        """
        flipped = EdgeDelta(
            insert_src=delta.insert_dst, insert_dst=delta.insert_src,
            insert_val=delta.insert_val,
            delete_src=delta.delete_dst, delete_dst=delta.delete_src,
            on_duplicate=delta.on_duplicate, on_missing=delta.on_missing)
        with self._lock:
            # in_adj first: if the delta is invalid (strict policies), the
            # store is untouched; out_adj apply then cannot fail on policy
            self.in_adj = flipped.apply(self.in_adj)
            self.out_adj = delta.apply(self.out_adj)
            self.version += 1
            version = self.version
            listeners = list(self._listeners)
        touched = flipped.touched_rows()
        for fn in listeners:
            fn(touched, delta)
        return version

    # ---------------------------------------------------------- partitioning
    def partition(self, n_parts: int) -> List["GraphStore"]:
        """Contiguous-range shards, one per host: shard ``p`` owns rows
        ``[bounds[p], bounds[p+1])`` of ``in_adj``. Rows outside the range
        are EMPTY (full-height matrices), so global ids work unchanged on
        every shard and sampling an owned node returns bit-identical
        results to the monolithic store.
        """
        n = self.n_nodes
        bounds = np.linspace(0, n, n_parts + 1).astype(np.int64)
        shards = []
        for p in range(n_parts):
            lo, hi = int(bounds[p]), int(bounds[p + 1])
            in_shard = _slice_rows(self.in_adj, lo, hi)
            shards.append(GraphStore(
                out_adj=csr_transpose(in_shard), in_adj=in_shard,
                node_range=(lo, hi), version=self.version))
        return shards


def _slice_rows(g: CSRGraph, lo: int, hi: int) -> CSRGraph:
    """Full-height copy of ``g`` keeping only rows ``[lo, hi)``. O(E_kept).

    Row slices of a CSR are one contiguous nnz slice, so the new rowptr is
    a single clip-and-shift.
    """
    s, e = int(g.rowptr[lo]), int(g.rowptr[hi])
    rowptr = (np.clip(g.rowptr, s, e) - s).astype(np.int64)
    return CSRGraph(rowptr, g.colidx[s:e].copy(), g.values[s:e].copy(),
                    g.n_cols)


class PartitionedStoreClient:
    """Ownership-routed sampling over a partitioned store.

    One per querying host: samples nodes the local shard owns directly and
    sends each remote run to its owner's sampler (a
    :class:`~repro.distributed.multihost.FrontierExchange` channel in the
    fleet, or another in-process shard in tests — anything matching
    :data:`SampleFn`). Because node ranges are contiguous and ascending
    by rank, concatenating per-owner results in rank order restores the
    dst-grouped order of the monolithic store, and the deterministic
    per-(seed, hop, node) rng makes the merged result BIT-IDENTICAL to
    sampling the whole graph locally.
    """

    def __init__(self, local: GraphStore,
                 bounds: Sequence[int],
                 remote: "dict[int, SampleFn]",
                 local_rank: int):
        self.local = local
        self.bounds = np.asarray(bounds, dtype=np.int64)  # len n_parts + 1
        self.remote = dict(remote)
        self.local_rank = local_rank
        self.remote_edges = 0    # edges sampled on peers' shards
        self.local_edges = 0
        lo, hi = local.node_range
        if (int(self.bounds[local_rank]) != lo
                or int(self.bounds[local_rank + 1]) != hi):
            raise ValueError(f"local shard range {local.node_range} != "
                             f"bounds slot {local_rank}")

    @property
    def n_nodes(self) -> int:
        return self.local.n_nodes

    def owner_of(self, nodes: np.ndarray) -> np.ndarray:
        return (np.searchsorted(self.bounds, np.asarray(nodes),
                                side="right") - 1).astype(np.int64)

    def in_degrees(self, nodes: np.ndarray) -> np.ndarray:
        return self.local.in_degrees(nodes)

    def sample_in_neighbors(self, nodes: np.ndarray,
                            fanout: Optional[int] = None, *,
                            seed: int = 0, hop: int = 0,
                            replace: bool = False
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        nodes = np.asarray(nodes, dtype=np.int64)
        owners = self.owner_of(nodes)
        src_parts, dst_parts, val_parts = [], [], []
        # nodes arrive ascending (frontier layers are sorted-unique), so
        # owner runs are contiguous and rank order == dst order
        for rank in np.unique(owners):
            sub = nodes[owners == rank]
            if int(rank) == self.local_rank:
                s, d, v = self.local.sample_in_neighbors(
                    sub, fanout, seed=seed, hop=hop, replace=replace)
                self.local_edges += len(s)
            else:
                fn = self.remote.get(int(rank))
                if fn is None:
                    raise KeyError(f"no channel to shard owner {int(rank)}")
                s, d, v = fn(sub, fanout, seed=seed, hop=hop,
                             replace=replace)
                self.remote_edges += len(s)
            src_parts.append(np.asarray(s, dtype=np.int64))
            dst_parts.append(np.asarray(d, dtype=np.int64))
            val_parts.append(np.asarray(v, dtype=np.float32))
        if not src_parts:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros(0, dtype=np.float32)
        return (np.concatenate(src_parts), np.concatenate(dst_parts),
                np.concatenate(val_parts))
