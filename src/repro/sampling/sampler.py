"""Seeded k-hop neighbor sampling + frontier -> induced-subgraph compaction.

A sampled frontier is the layered receptive field of a seed batch:

    layers[0]   sorted-unique seed nodes (global ids)
    layers[k+1] layers[k]  UNION  sampled in-neighbors of layers[k]
    blocks[k]   the bipartite aggregation graph for hop k:
                  rows    = layers[k]        (destinations)
                  columns = layers[k+1]      (sources)

Layers NEST (every destination is also a source of its own hop), so
self-loop edges from GCN normalization always translate, and feature
gathering needs only the outermost layer. A GCN layer ``l`` of an
``L``-layer model aggregates over ``blocks[L - l]`` — process the blocks
list in REVERSE, outermost first (see
:meth:`repro.sampling.service.SamplingService.infer`).

Compaction is a stable relabel: block-local ids are positions in the
sorted-unique ``dst_nodes`` / ``src_nodes`` arrays (the inverse maps), so
``searchsorted`` translates global -> local and plain indexing translates
back. Rows keep the parent graph's within-row edge order (the sampler
sorts chosen slots; the relabel is order-preserving), which is what makes
full-fanout block aggregation BIT-identical to the full-graph SpMM.

Everything here is a pure function of (sampler backend, seeds, fanouts,
seed, replace): the same call is bit-deterministic across processes,
which the partitioned store and its parity gates rely on.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import CSRGraph

__all__ = ["FrontierBlock", "Frontier", "sample_frontier"]


@dataclasses.dataclass
class FrontierBlock:
    """One hop's induced bipartite subgraph, compacted to local ids."""

    graph: CSRGraph          # [len(dst_nodes), len(src_nodes)] local CSR
    dst_nodes: np.ndarray    # sorted-unique global ids; row i <-> dst_nodes[i]
    src_nodes: np.ndarray    # sorted-unique global ids; col j <-> src_nodes[j]

    def to_local_dst(self, nodes: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.dst_nodes, nodes)

    def to_local_src(self, nodes: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.src_nodes, nodes)

    @property
    def n_edges(self) -> int:
        return self.graph.nnz


@dataclasses.dataclass
class Frontier:
    """A sampled k-hop receptive field; ``blocks[k]`` aggregates hop k."""

    seeds: np.ndarray              # caller's seed batch, original order
    layers: List[np.ndarray]       # nested sorted-unique global id sets
    blocks: List[FrontierBlock]

    @property
    def num_hops(self) -> int:
        return len(self.blocks)

    @property
    def input_nodes(self) -> np.ndarray:
        """Global ids whose features feed the outermost hop."""
        return self.layers[-1]

    def seed_rows(self) -> np.ndarray:
        """Rows of the final (hop-0) output holding the caller's seeds,
        in the caller's original seed order."""
        return np.searchsorted(self.layers[0], self.seeds)

    def content_key(self) -> str:
        """Content hash over every block's arrays + id maps — two
        frontiers with equal keys induce identical computations."""
        h = hashlib.blake2b(digest_size=16)
        for b in self.blocks:
            for a in (b.graph.rowptr, b.graph.colidx, b.graph.values,
                      b.dst_nodes, b.src_nodes):
                h.update(np.ascontiguousarray(a).tobytes())
            h.update(str(b.graph.n_cols).encode())
        return h.hexdigest()


def _compact_block(dst_layer: np.ndarray, src_layer: np.ndarray,
                   src: np.ndarray, dst: np.ndarray,
                   val: np.ndarray) -> FrontierBlock:
    """Relabel a sampled COO triple into a local bipartite CSR.

    ``dst`` arrives grouped by destination in ``dst_layer`` order (the
    sampler contract) with within-row edges in parent-CSR order; counting
    rows per destination keeps both, so no sort happens here at all.
    """
    n_dst, n_src = len(dst_layer), len(src_layer)
    dst_local = np.searchsorted(dst_layer, dst)
    src_local = np.searchsorted(src_layer, src)
    counts = np.bincount(dst_local, minlength=n_dst)
    rowptr = np.zeros(n_dst + 1, dtype=np.int64)
    np.cumsum(counts, out=rowptr[1:])
    graph = CSRGraph(rowptr, src_local.astype(np.int64),
                     np.asarray(val, dtype=np.float32), n_cols=n_src)
    return FrontierBlock(graph=graph, dst_nodes=dst_layer,
                         src_nodes=src_layer)


def sample_frontier(sample_fn, seeds: np.ndarray,
                    fanouts: Sequence[Optional[int]], *, seed: int = 0,
                    replace: bool = False) -> Frontier:
    """Sample a ``len(fanouts)``-hop frontier for one seed batch.

    ``sample_fn`` is any :data:`~repro.sampling.store.SampleFn` — the
    local store method, a :class:`PartitionedStoreClient`, or a test
    double. ``fanouts[k]`` caps hop k's per-node in-degree (``None`` =
    take every in-edge: exact aggregation). Hop k's rng derives from
    ``(seed, k, node)`` only, so the frontier is bit-deterministic in
    (seeds-as-a-set, fanouts, seed, replace).
    """
    seeds = np.asarray(seeds, dtype=np.int64)
    if seeds.ndim != 1 or len(seeds) == 0:
        raise ValueError("seeds must be a non-empty 1-D node-id array")
    layers = [np.unique(seeds)]
    sampled: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for hop, fanout in enumerate(fanouts):
        src, dst, val = sample_fn(layers[hop], fanout, seed=seed,
                                  hop=hop, replace=replace)
        sampled.append((src, dst, val))
        layers.append(np.union1d(layers[hop], src))
    blocks = [_compact_block(layers[k], layers[k + 1], *sampled[k])
              for k in range(len(fanouts))]
    return Frontier(seeds=seeds, layers=layers, blocks=blocks)
