"""Neighbor-sampling service over a partitioned graph store.

The million-node-graph serving layer: ONE huge evolving graph
(:class:`GraphStore`, both adjacency orientations + the ``EdgeDelta``
feed), seeded k-hop frontier sampling with induced-subgraph compaction
(:func:`sample_frontier`), and :class:`SamplingService`, which feeds the
compacted frontiers through the plan-cache/batched-SpMM serving path —
sampled frontiers are exactly the recurring small-graph workload the
engine is already fast at. ``GraphStore.partition`` +
:class:`PartitionedStoreClient` +
:class:`~repro.distributed.multihost.FrontierExchange` spread the store
over the fleet's hosts with cross-partition hops on the peer data plane.
"""
from .sampler import Frontier, FrontierBlock, sample_frontier
from .service import SamplingService
from .store import GraphStore, PartitionedStoreClient

__all__ = [
    "Frontier",
    "FrontierBlock",
    "GraphStore",
    "PartitionedStoreClient",
    "SamplingService",
    "sample_frontier",
]
