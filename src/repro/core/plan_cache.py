"""Partition-plan cache: amortize Accel-GCN preprocessing across requests.

The paper's block-level partition (§III-C) exists to cut per-inference
metadata overhead — but rebuilding the degree sort + pattern table + slab
packing on *every* call throws that win away in a serving setting where the
same graphs recur. This module factors the whole preprocessing pipeline into
a content-addressed :class:`PartitionPlan` and caches finished plans in an
LRU :class:`PlanCache` keyed by (graph content hash, partition config):

* ``graph_content_hash`` — blake2b over the CSR arrays (structure AND edge
  values), so A' and A'^T of the same graph, or the same topology with
  different normalization, get distinct plans;
* ``build_partition_plan`` — the one place the pipeline runs: degree sort ->
  Algorithm 1 pattern table -> Algorithm 2 block emission -> slab packing ->
  device staging. Everything downstream (AccelSpMM, the batched multi-graph
  path, GraphServeEngine) consumes plans;
* ``PlanCache`` — LRU with hit/miss/eviction counters and a ``builds``
  counter tests and the serving engine use to assert "partitioned exactly
  once per distinct (graph, config)".
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import CSRGraph, degree_sort_csr
from .partition import (
    BlockPartition,
    block_level_partition,
    get_partition_patterns,
    pack_slabs,
)

__all__ = [
    "PartitionConfig",
    "PartitionPlan",
    "PlanCache",
    "graph_content_hash",
    "build_partition_plan",
]


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """Static knobs that change the partition layout (part of the cache key).

    ``warp_nzs_table`` is the tuner's per-degree warp_nzs override (see
    ``partition.validate_warp_nzs_override``); ``None`` means the derived
    Algorithm-1 table. It is a tuple so configs stay hashable cache keys.
    """

    mode: str = "tpu"
    max_block_warps: int = 64
    max_warp_nzs: int = 4
    max_rows_per_block: Optional[int] = None
    warp_nzs_table: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.warp_nzs_table is not None and \
                not isinstance(self.warp_nzs_table, tuple):
            object.__setattr__(self, "warp_nzs_table",
                               tuple(int(v) for v in self.warp_nzs_table))

    @property
    def deg_bound(self) -> int:
        return self.max_block_warps * self.max_warp_nzs


def graph_content_hash(g: CSRGraph) -> str:
    """Content hash of a CSR matrix: shapes, structure and edge values.

    Two graphs with the same topology but different values (e.g. before and
    after GCN normalization) hash differently — the packed slabs differ.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64([g.n_rows, g.n_cols, g.nnz]).tobytes())
    h.update(np.ascontiguousarray(g.rowptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.colidx, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.values, dtype=np.float32).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class PartitionPlan:
    """A finished, device-staged partition of one graph under one config.

    Immutable once built; shared freely between operators and serve batches.
    ``slabs`` holds the kernel inputs (colidx/values/rowloc/out_row as device
    arrays plus python ints R, C); ``inv_perm`` undoes the degree sort so
    callers always see the ORIGINAL row order.
    """

    key: Tuple[str, PartitionConfig]
    n_rows: int
    n_cols: int
    nnz: int
    slabs: Dict
    inv_perm: jax.Array          # original row -> sorted position
    partition: BlockPartition
    coo_row: jax.Array
    coo_col: jax.Array
    coo_val: jax.Array
    # monotone stamp in a graph's plan chain (0 = first build; incremental
    # repair / mutation bumps it — see core/plan_repair.py). The content
    # hash in ``key`` still changes with every version: the version is the
    # lineage, the hash is the identity.
    version: int = 0
    # dispatch hints attached by the autotuner at promotion (JSON-able:
    # backend/grid_order/label). None until a tuned candidate wins; spills
    # and reloads with the plan so tuned configs survive eviction.
    tuned: Optional[Dict] = None

    @property
    def graph_hash(self) -> str:
        return self.key[0]

    @property
    def config(self) -> PartitionConfig:
        return self.key[1]

    @property
    def num_blocks(self) -> int:
        return int(self.slabs["colidx"].shape[0])

    def device_bytes(self) -> int:
        """Approximate device footprint of the staged plan (for cache stats)."""
        total = 0
        for v in list(self.slabs.values()) + [self.inv_perm, self.coo_row,
                                              self.coo_col, self.coo_val]:
            if hasattr(v, "nbytes"):
                total += int(v.nbytes)
        return total


def build_partition_plan(g: CSRGraph, cfg: PartitionConfig,
                         graph_hash: Optional[str] = None) -> PartitionPlan:
    """Run the full O(n) preprocessing pipeline once and stage device buffers."""
    g.validate()
    gs = degree_sort_csr(g)
    pats = get_partition_patterns(
        cfg.max_block_warps, cfg.max_warp_nzs, mode=cfg.mode,
        max_rows_per_block=cfg.max_rows_per_block,
        warp_nzs_override=cfg.warp_nzs_table)
    bp = block_level_partition(gs, pats)
    slabs_np = pack_slabs(gs, bp)
    slabs = {k: jnp.asarray(v) for k, v in slabs_np.items()
             if isinstance(v, np.ndarray)}
    slabs["R"], slabs["C"] = slabs_np["R"], slabs_np["C"]

    inv_perm = np.empty(gs.n_rows, dtype=np.int64)
    inv_perm[gs.perm] = np.arange(gs.n_rows)

    # COO is cheap to keep and doubles as the gradient/baseline path.
    row_of = np.repeat(np.arange(g.n_rows, dtype=np.int32), np.diff(g.rowptr))
    return PartitionPlan(
        key=(graph_hash or graph_content_hash(g), cfg),
        n_rows=g.n_rows, n_cols=g.n_cols, nnz=g.nnz,
        slabs=slabs, inv_perm=jnp.asarray(inv_perm), partition=bp,
        coo_row=jnp.asarray(row_of),
        coo_col=jnp.asarray(g.colidx),
        coo_val=jnp.asarray(np.asarray(g.values, dtype=np.float32)),
    )


def _config_tag(cfg: PartitionConfig) -> str:
    """Stable short fingerprint of a PartitionConfig (part of spill names)."""
    h = hashlib.blake2b(repr(cfg).encode(), digest_size=8)
    return h.hexdigest()


class PlanCache:
    """LRU cache of :class:`PartitionPlan` keyed by (content hash, config).

    ``capacity`` counts plans, not bytes: partition metadata scales with nnz
    and serving workloads typically hold a small working set of graphs. All
    counters are monotone; ``stats()`` snapshots them.

    Thread safety: every lookup/insert/evict runs under one lock, so
    concurrent flush threads (the serving schedulers) can share a cache.
    Builds are *single-flight*: parallel ``get_or_build`` of the same
    (graph, config) runs the O(n) partition pipeline exactly once — the
    first caller builds (one ``miss`` + one ``build``), the rest wait on
    the in-flight build and then count as ``hits``. The build itself runs
    outside the cache lock, so distinct graphs still partition in parallel.

    Disk persistence (``save_dir``): evicted plans spill to
    ``<graph_hash>-<config_tag>.npz`` (content-hash-named — safe to share
    between processes serving the same graphs); a later miss reloads the
    spilled plan instead of re-running the partition pipeline. ``spills`` /
    ``disk_hits`` counters track both sides; a disk reload still counts as
    a ``miss`` but not as a ``build``.
    """

    def __init__(self, capacity: int = 32, save_dir: Optional[str] = None):
        if capacity < 1:
            raise ValueError("PlanCache capacity must be >= 1")
        self.capacity = capacity
        self.save_dir = save_dir
        if save_dir is not None:
            os.makedirs(save_dir, exist_ok=True)
        self._plans: "OrderedDict[Tuple[str, PartitionConfig], PartitionPlan]" = \
            OrderedDict()
        self._lock = threading.RLock()
        self._inflight: Dict[Tuple[str, PartitionConfig], threading.Event] = {}
        # version lifecycle: reader refcounts per key (a dispatch pins the
        # plan version it resolved for its whole duration) and retired
        # versions parked until their last pin drains
        self._pins: Dict[Tuple[str, PartitionConfig], int] = {}
        self._retired: Dict[Tuple[str, PartitionConfig], PartitionPlan] = {}
        self.lookups = 0        # == hits + misses, bumped under the SAME
        #                         lock hold (the stats-atomicity witness)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.builds = 0
        self.spills = 0
        self.disk_hits = 0
        self.publishes = 0
        self.retired_versions = 0   # old versions parked behind live pins
        self.retired_reclaimed = 0  # parked versions whose pins drained

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._plans

    def get_or_build(self, g: CSRGraph, cfg: PartitionConfig) -> PartitionPlan:
        """Return the cached plan for (g, cfg), building it on first sight."""
        key = (graph_content_hash(g), cfg)
        return self.get_by_key(
            key, lambda: build_partition_plan(g, cfg, graph_hash=key[0]))

    def get_by_key(self, key: Tuple[str, PartitionConfig],
                   build_fn: Callable[[], PartitionPlan]) -> PartitionPlan:
        """Counter-tracked lookup for callers that already hold the key (the
        serving engine hashes each graph once at registration, not per
        request); ``build_fn`` runs only on a miss, and only in ONE thread
        when several miss the same key at once."""
        while True:
            with self._lock:
                plan = self._plans.get(key)
                if plan is not None:
                    self.hits += 1
                    self.lookups += 1
                    self._plans.move_to_end(key)
                    return plan
                pending = self._inflight.get(key)
                if pending is None:
                    event = threading.Event()
                    self._inflight[key] = event
                    self.misses += 1
                    self.lookups += 1
            if pending is not None:
                pending.wait()      # another thread is building this key;
                continue            # loop back — next pass is a hit
            try:
                plan = self._load_from_disk(key)
                built = plan is None
                if built:
                    plan = build_fn()
                with self._lock:
                    if built:
                        self.builds += 1
                    else:
                        self.disk_hits += 1
                    evicted = self._insert_locked(key, plan)
                self._spill_evicted(evicted)
            finally:
                with self._lock:
                    del self._inflight[key]
                event.set()
            return plan

    def lookup(self, key: Tuple[str, PartitionConfig]) -> Optional[PartitionPlan]:
        """Counter-free peek (used by stats tooling); refreshes LRU order."""
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
            return plan

    def put(self, plan: PartitionPlan) -> None:
        """Insert an externally-built plan (e.g. shipped from another host)."""
        with self._lock:
            evicted = self._insert_locked(plan.key, plan)
        self._spill_evicted(evicted)

    def _insert_locked(self, key, plan: PartitionPlan) -> list:
        """Insert under the lock; returns evicted plans for the caller to
        spill AFTER releasing it (an O(nnz) .npz write must not stall every
        concurrent lookup)."""
        if key in self._plans:
            self._plans.move_to_end(key)
        self._plans[key] = plan
        evicted = []
        while len(self._plans) > self.capacity:
            _, old = self._plans.popitem(last=False)
            self.evictions += 1
            evicted.append(old)
        return evicted

    def _spill_evicted(self, evicted: list) -> None:
        if self.save_dir is None:
            return
        for plan in evicted:
            if self._spill(plan):
                with self._lock:
                    self.spills += 1

    def remove(self, key) -> bool:
        """Drop one plan WITHOUT spilling it (replica demotion: another
        resident copy — and possibly a spilled .npz — still exists
        elsewhere). Returns True if the key was resident. Not counted as
        an eviction: the caller chose to drop it, capacity didn't."""
        with self._lock:
            return self._plans.pop(key, None) is not None

    # -------------------------------------------------------- version chain
    def pin(self, key) -> int:
        """A reader (one in-flight dispatch) holds this plan version: its
        key cannot be silently discarded by :meth:`retire` until the
        matching :meth:`unpin`. Returns the new refcount. Pin/unpin must
        balance — the concurrency tests assert refcounts drain to zero."""
        with self._lock:
            c = self._pins.get(key, 0) + 1
            self._pins[key] = c
            return c

    def unpin(self, key) -> int:
        """Release one reader pin; when the last pin of a RETIRED version
        drains, the parked plan is reclaimed. Returns the remaining count."""
        with self._lock:
            c = self._pins.get(key, 0) - 1
            if c > 0:
                self._pins[key] = c
                return c
            self._pins.pop(key, None)
            if self._retired.pop(key, None) is not None:
                self.retired_reclaimed += 1
            return 0

    def retire(self, key) -> bool:
        """Remove a superseded version from the serving set. Unpinned
        versions drop immediately (no spill — stale content must not be
        resurrected by a disk hit racing the publish); pinned versions PARK
        until their readers drain, so an in-flight dispatch keeps a
        reachable plan for its whole duration. Returns True if the key was
        resident or parked."""
        with self._lock:
            plan = self._plans.pop(key, None)
            if plan is None:
                return key in self._retired
            if self._pins.get(key, 0) > 0:
                self._retired[key] = plan
                self.retired_versions += 1
            return True

    # uniform names with FleetPlanCache (whose bare ``pin`` records
    # directory-dictated placements), so the engines stay cache-agnostic
    def pin_version(self, key) -> int:
        return self.pin(key)

    def unpin_version(self, key) -> int:
        return self.unpin(key)

    def publish(self, plan: PartitionPlan, retire_key=None) -> PartitionPlan:
        """Atomically make ``plan`` the current version and retire the one
        it supersedes: readers either resolve the old key (still parked if
        pinned) or the new one — never a torn in-between. Spilling of any
        capacity eviction happens outside the lock as usual."""
        with self._lock:
            evicted = self._insert_locked(plan.key, plan)
            if retire_key is not None and retire_key != plan.key:
                old = self._plans.pop(retire_key, None)
                if old is not None and self._pins.get(retire_key, 0) > 0:
                    self._retired[retire_key] = old
                    self.retired_versions += 1
            self.publishes += 1
        self._spill_evicted(evicted)
        return plan

    def apply_delta(self, key, g_old: CSRGraph, delta, *,
                    churn_threshold: float = 0.25):
        """Repair the plan under ``key`` for an edge delta and publish the
        next version in one step. ``g_old`` is the pre-delta graph the key
        was built from (rebuilt here if the plan was evicted meanwhile).
        Returns ``(g_new, PlanVersion)`` — the caller re-binds its
        graph_id to ``pv.plan.key`` and pushes the new graph content.
        """
        from .plan_repair import apply_and_repair   # circular at module load
        plan = self.get_by_key(
            key, lambda: build_partition_plan(g_old, key[1],
                                              graph_hash=key[0]))
        g_new, pv = apply_and_repair(plan, g_old, delta,
                                     churn_threshold=churn_threshold)
        self.publish(pv.plan, retire_key=key)
        return g_new, pv

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()

    def keys(self):
        with self._lock:
            return list(self._plans.keys())

    # ------------------------------------------------------------ disk spill
    def _spill_path(self, key: Tuple[str, PartitionConfig]) -> str:
        graph_hash, cfg = key
        return os.path.join(self.save_dir, f"{graph_hash}-{_config_tag(cfg)}.npz")

    def _spill(self, plan: PartitionPlan) -> bool:
        """Write an evicted plan as a content-hash-named .npz (atomic)."""
        path = self._spill_path(plan.key)
        if os.path.exists(path):
            return False        # same content already spilled (idempotent)
        bp = plan.partition
        payload = {
            "n_rows": np.int64(plan.n_rows),
            "n_cols": np.int64(plan.n_cols),
            "nnz": np.int64(plan.nnz),
            "version": np.int64(plan.version),
            "slab_R": np.int64(plan.slabs["R"]),
            "slab_C": np.int64(plan.slabs["C"]),
            "slab_colidx": np.asarray(plan.slabs["colidx"]),
            "slab_values": np.asarray(plan.slabs["values"]),
            "slab_rowloc": np.asarray(plan.slabs["rowloc"]),
            "slab_out_row": np.asarray(plan.slabs["out_row"]),
            "inv_perm": np.asarray(plan.inv_perm),
            "coo_row": np.asarray(plan.coo_row),
            "coo_col": np.asarray(plan.coo_col),
            "coo_val": np.asarray(plan.coo_val),
            "bp_meta": bp.meta,
            "bp_n_rows_blk": bp.n_rows_blk,
            "bp_nnz_blk": bp.nnz_blk,
            "bp_is_split": bp.is_split,
            "bp_n_rows": np.int64(bp.n_rows),
            "bp_nnz": np.int64(bp.nnz),
        }
        if plan.tuned is not None:
            payload["tuned_json"] = np.array(json.dumps(plan.tuned))
        tmp = path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, **payload)
            os.replace(tmp, path)
            return True
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            return False

    def _load_from_disk(self, key: Tuple[str, PartitionConfig]
                        ) -> Optional[PartitionPlan]:
        """Reload a spilled plan; None when absent/unreadable (then rebuild)."""
        if self.save_dir is None:
            return None
        path = self._spill_path(key)
        if not os.path.exists(path):
            return None
        _, cfg = key
        try:
            with np.load(path) as z:
                slabs = {
                    "colidx": jnp.asarray(z["slab_colidx"]),
                    "values": jnp.asarray(z["slab_values"]),
                    "rowloc": jnp.asarray(z["slab_rowloc"]),
                    "out_row": jnp.asarray(z["slab_out_row"]),
                    "R": int(z["slab_R"]),
                    "C": int(z["slab_C"]),
                }
                bp = BlockPartition(
                    meta=z["bp_meta"],
                    n_rows_blk=z["bp_n_rows_blk"],
                    nnz_blk=z["bp_nnz_blk"],
                    is_split=z["bp_is_split"],
                    patterns=get_partition_patterns(
                        cfg.max_block_warps, cfg.max_warp_nzs, mode=cfg.mode,
                        max_rows_per_block=cfg.max_rows_per_block,
                        warp_nzs_override=cfg.warp_nzs_table),
                    n_rows=int(z["bp_n_rows"]),
                    nnz=int(z["bp_nnz"]),
                )
                tuned = (json.loads(str(z["tuned_json"]))
                         if "tuned_json" in z else None)
                return PartitionPlan(
                    key=key,
                    n_rows=int(z["n_rows"]), n_cols=int(z["n_cols"]),
                    nnz=int(z["nnz"]), slabs=slabs,
                    inv_perm=jnp.asarray(z["inv_perm"]), partition=bp,
                    coo_row=jnp.asarray(z["coo_row"]),
                    coo_col=jnp.asarray(z["coo_col"]),
                    coo_val=jnp.asarray(z["coo_val"]),
                    # pre-versioning spills reload as version 0
                    version=int(z["version"]) if "version" in z else 0,
                    tuned=tuned,
                )
        except Exception:       # corrupt/partial/alien spill (BadZipFile,
            return None         # KeyError, OSError, ...): rebuild instead

    def stats(self) -> Dict[str, float]:
        """ATOMIC snapshot of every counter, taken under one lock hold.

        Guarantee: all values in one returned dict are from the same
        instant — a flush thread mutating counters mid-``stats()`` can
        never produce a torn read (e.g. ``hits + misses != lookups``, or a
        ``hit_rate`` computed from two different moments). The benchmark
        samplers and the fleet cache's per-shard aggregation rely on this.
        """
        with self._lock:
            total = self.hits + self.misses
            return {
                "size": len(self._plans),
                "capacity": self.capacity,
                "lookups": self.lookups,
                "hits": self.hits,
                "misses": self.misses,
                "builds": self.builds,
                "evictions": self.evictions,
                "spills": self.spills,
                "disk_hits": self.disk_hits,
                "publishes": self.publishes,
                "pins": sum(self._pins.values()),
                "retired_versions": self.retired_versions,
                "retired_reclaimed": self.retired_reclaimed,
                "retired_live": len(self._retired),
                "hit_rate": self.hits / total if total else 0.0,
                "device_bytes": sum(p.device_bytes()
                                    for p in self._plans.values()),
            }
