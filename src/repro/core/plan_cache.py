"""Partition-plan cache: amortize Accel-GCN preprocessing across requests.

The paper's block-level partition (§III-C) exists to cut per-inference
metadata overhead — but rebuilding the degree sort + pattern table + slab
packing on *every* call throws that win away in a serving setting where the
same graphs recur. This module factors the whole preprocessing pipeline into
a content-addressed :class:`PartitionPlan` and caches finished plans in an
LRU :class:`PlanCache` keyed by (graph content hash, partition config):

* ``graph_content_hash`` — blake2b over the CSR arrays (structure AND edge
  values), so A' and A'^T of the same graph, or the same topology with
  different normalization, get distinct plans;
* ``build_partition_plan`` — the one place the pipeline runs: degree sort ->
  Algorithm 1 pattern table -> Algorithm 2 block emission -> slab packing ->
  device staging. Everything downstream (AccelSpMM, the batched multi-graph
  path, GraphServeEngine) consumes plans;
* ``PlanCache`` — LRU with hit/miss/eviction counters and a ``builds``
  counter tests and the serving engine use to assert "partitioned exactly
  once per distinct (graph, config)".
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import CSRGraph, degree_sort_csr
from .partition import (
    BlockPartition,
    block_level_partition,
    get_partition_patterns,
    pack_slabs,
)

__all__ = [
    "PartitionConfig",
    "PartitionPlan",
    "PlanCache",
    "graph_content_hash",
    "build_partition_plan",
]


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """Static knobs that change the partition layout (part of the cache key)."""

    mode: str = "tpu"
    max_block_warps: int = 64
    max_warp_nzs: int = 4
    max_rows_per_block: Optional[int] = None

    @property
    def deg_bound(self) -> int:
        return self.max_block_warps * self.max_warp_nzs


def graph_content_hash(g: CSRGraph) -> str:
    """Content hash of a CSR matrix: shapes, structure and edge values.

    Two graphs with the same topology but different values (e.g. before and
    after GCN normalization) hash differently — the packed slabs differ.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64([g.n_rows, g.n_cols, g.nnz]).tobytes())
    h.update(np.ascontiguousarray(g.rowptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.colidx, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.values, dtype=np.float32).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class PartitionPlan:
    """A finished, device-staged partition of one graph under one config.

    Immutable once built; shared freely between operators and serve batches.
    ``slabs`` holds the kernel inputs (colidx/values/rowloc/out_row as device
    arrays plus python ints R, C); ``inv_perm`` undoes the degree sort so
    callers always see the ORIGINAL row order.
    """

    key: Tuple[str, PartitionConfig]
    n_rows: int
    n_cols: int
    nnz: int
    slabs: Dict
    inv_perm: jax.Array          # original row -> sorted position
    partition: BlockPartition
    coo_row: jax.Array
    coo_col: jax.Array
    coo_val: jax.Array

    @property
    def graph_hash(self) -> str:
        return self.key[0]

    @property
    def config(self) -> PartitionConfig:
        return self.key[1]

    @property
    def num_blocks(self) -> int:
        return int(self.slabs["colidx"].shape[0])

    def device_bytes(self) -> int:
        """Approximate device footprint of the staged plan (for cache stats)."""
        total = 0
        for v in list(self.slabs.values()) + [self.inv_perm, self.coo_row,
                                              self.coo_col, self.coo_val]:
            if hasattr(v, "nbytes"):
                total += int(v.nbytes)
        return total


def build_partition_plan(g: CSRGraph, cfg: PartitionConfig,
                         graph_hash: Optional[str] = None) -> PartitionPlan:
    """Run the full O(n) preprocessing pipeline once and stage device buffers."""
    g.validate()
    gs = degree_sort_csr(g)
    pats = get_partition_patterns(
        cfg.max_block_warps, cfg.max_warp_nzs, mode=cfg.mode,
        max_rows_per_block=cfg.max_rows_per_block)
    bp = block_level_partition(gs, pats)
    slabs_np = pack_slabs(gs, bp)
    slabs = {k: jnp.asarray(v) for k, v in slabs_np.items()
             if isinstance(v, np.ndarray)}
    slabs["R"], slabs["C"] = slabs_np["R"], slabs_np["C"]

    inv_perm = np.empty(gs.n_rows, dtype=np.int64)
    inv_perm[gs.perm] = np.arange(gs.n_rows)

    # COO is cheap to keep and doubles as the gradient/baseline path.
    row_of = np.repeat(np.arange(g.n_rows), np.diff(g.rowptr))
    return PartitionPlan(
        key=(graph_hash or graph_content_hash(g), cfg),
        n_rows=g.n_rows, n_cols=g.n_cols, nnz=g.nnz,
        slabs=slabs, inv_perm=jnp.asarray(inv_perm), partition=bp,
        coo_row=jnp.asarray(row_of),
        coo_col=jnp.asarray(g.colidx),
        coo_val=jnp.asarray(g.values.astype(np.float32)),
    )


class PlanCache:
    """LRU cache of :class:`PartitionPlan` keyed by (content hash, config).

    ``capacity`` counts plans, not bytes: partition metadata scales with nnz
    and serving workloads typically hold a small working set of graphs. All
    counters are monotone; ``stats()`` snapshots them.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError("PlanCache capacity must be >= 1")
        self.capacity = capacity
        self._plans: "OrderedDict[Tuple[str, PartitionConfig], PartitionPlan]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.builds = 0

    def __len__(self) -> int:
        return len(self._plans)

    def __contains__(self, key) -> bool:
        return key in self._plans

    def get_or_build(self, g: CSRGraph, cfg: PartitionConfig) -> PartitionPlan:
        """Return the cached plan for (g, cfg), building it on first sight."""
        key = (graph_content_hash(g), cfg)
        return self.get_by_key(
            key, lambda: build_partition_plan(g, cfg, graph_hash=key[0]))

    def get_by_key(self, key: Tuple[str, PartitionConfig],
                   build_fn) -> PartitionPlan:
        """Counter-tracked lookup for callers that already hold the key (the
        serving engine hashes each graph once at registration, not per
        request); ``build_fn`` runs only on a miss."""
        plan = self._plans.get(key)
        if plan is not None:
            self.hits += 1
            self._plans.move_to_end(key)
            return plan
        self.misses += 1
        plan = build_fn()
        self.builds += 1
        self._insert(key, plan)
        return plan

    def lookup(self, key: Tuple[str, PartitionConfig]) -> Optional[PartitionPlan]:
        """Counter-free peek (used by stats tooling); refreshes LRU order."""
        plan = self._plans.get(key)
        if plan is not None:
            self._plans.move_to_end(key)
        return plan

    def put(self, plan: PartitionPlan) -> None:
        """Insert an externally-built plan (e.g. shipped from another host)."""
        self._insert(plan.key, plan)

    def _insert(self, key, plan: PartitionPlan) -> None:
        if key in self._plans:
            self._plans.move_to_end(key)
        self._plans[key] = plan
        while len(self._plans) > self.capacity:
            self._plans.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._plans.clear()

    def keys(self):
        return list(self._plans.keys())

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "size": len(self._plans),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "builds": self.builds,
            "evictions": self.evictions,
            "hit_rate": self.hits / total if total else 0.0,
            "device_bytes": sum(p.device_bytes()
                                for p in self._plans.values()),
        }
