"""Public SpMM API: a preprocessed Accel-GCN operator for a fixed sparse matrix.

``AccelSpMM`` owns the paper's full preprocessing pipeline (degree sorting ->
block-level partition -> slab packing) and exposes ``__call__(x)`` computing
``A @ x`` in the ORIGINAL row order, with selectable backends:

  backend="auto"     VMEM-routed Pallas dispatch: resident / windowed / hbm
                     picked per call from the feature-operand shape
  backend="pallas"   resident-X Pallas kernel (raises VmemBudgetError when
                     the feature tile exceeds the VMEM budget)
  backend="windowed" row-window streaming Pallas kernel (middle regime)
  backend="hbm"      HBM-gather Pallas kernel (N-unbounded fallback)
  backend="blocked"  jnp twin of the kernel (portable production path)
  backend="segment"  COO + segment_sum (cuSPARSE-analogue baseline)
  backend="warp"     warp-level fixed-NZ-group emulation (GNNAdvisor analogue)
  backend="dense"    dense matmul oracle (tiny graphs only)
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import CSRGraph
from .partition import BlockPartition, warp_level_partition
from .plan_cache import (
    PartitionConfig,
    PartitionPlan,
    PlanCache,
    build_partition_plan,
)
from ..kernels import ops as kops

Backend = Literal["auto", "pallas", "windowed", "hbm",
                  "blocked", "segment", "warp", "dense"]


@dataclasses.dataclass
class AccelSpMM:
    """Preprocessed sparse operator. Build via :func:`make_accel_spmm`."""

    n_rows: int
    n_cols: int
    nnz: int
    backend: Backend
    # degree-sorted CSR + slabs (device arrays)
    slabs: dict
    inv_perm: jax.Array          # original row -> sorted position
    # baselines
    coo_row: Optional[jax.Array] = None
    coo_col: Optional[jax.Array] = None
    coo_val: Optional[jax.Array] = None
    warp_slabs: Optional[dict] = None
    dense: Optional[jax.Array] = None
    partition: Optional[BlockPartition] = None
    plan: Optional[PartitionPlan] = None  # staged preprocessing this op wraps

    def __call__(self, x: jax.Array, backend: Optional[Backend] = None) -> jax.Array:
        be = backend or self.backend
        if be == "auto":
            out_sorted = kops.spmm_auto(self.slabs, x, self.n_rows)
            return out_sorted[self.inv_perm]
        if be == "pallas":
            out_sorted = kops.spmm_pallas(self.slabs, x, self.n_rows)
            return out_sorted[self.inv_perm]
        if be == "windowed":
            out_sorted = kops.spmm_pallas_windowed(self.slabs, x, self.n_rows)
            return out_sorted[self.inv_perm]
        if be == "hbm":
            out_sorted = kops.spmm_pallas_hbm(self.slabs, x, self.n_rows)
            return out_sorted[self.inv_perm]
        if be == "blocked":
            out_sorted = kops.spmm_blocked(
                self.slabs["colidx"], self.slabs["values"], self.slabs["rowloc"],
                self.slabs["out_row"], x, self.n_rows)
            return out_sorted[self.inv_perm]
        if be == "segment":
            contrib = self.coo_val[:, None] * x[self.coo_col].astype(jnp.float32)
            return jax.ops.segment_sum(contrib, self.coo_row, num_segments=self.n_rows)
        if be == "warp":
            ws = self.warp_slabs
            out = kops.spmm_blocked(ws["colidx"], ws["values"], ws["rowloc"],
                                    ws["out_row"], x, self.n_rows)
            return out  # warp partition is built un-sorted: original order
        if be == "dense":
            return jnp.dot(self.dense, x.astype(jnp.float32))
        raise ValueError(f"unknown backend {be!r}")


def accel_spmm_from_plan(plan: PartitionPlan,
                         backend: Backend = "blocked") -> AccelSpMM:
    """Wrap a finished (possibly cached) partition plan as a callable operator."""
    return AccelSpMM(
        n_rows=plan.n_rows, n_cols=plan.n_cols, nnz=plan.nnz, backend=backend,
        slabs=plan.slabs, inv_perm=plan.inv_perm, partition=plan.partition,
        coo_row=plan.coo_row, coo_col=plan.coo_col, coo_val=plan.coo_val,
        plan=plan,
    )


def make_accel_spmm(
    g: CSRGraph,
    *,
    mode: str = "tpu",
    max_block_warps: int = 64,
    max_warp_nzs: int = 4,
    backend: Backend = "blocked",
    with_baselines: bool = False,
    warp_ng: int = 32,
    plan_cache: Optional[PlanCache] = None,
) -> AccelSpMM:
    """Build the operator; with ``plan_cache`` the O(n) preprocessing runs at
    most once per distinct (graph content, partition config)."""
    cfg = PartitionConfig(mode=mode, max_block_warps=max_block_warps,
                          max_warp_nzs=max_warp_nzs)
    if plan_cache is not None:
        plan = plan_cache.get_or_build(g, cfg)
    else:
        plan = build_partition_plan(g, cfg)
    op = accel_spmm_from_plan(plan, backend=backend)

    if with_baselines:
        wp = warp_level_partition(g, ng_size=warp_ng)
        W = wp.num_warps
        ws_col = np.zeros((W, warp_ng), dtype=np.int32)
        ws_val = np.zeros((W, warp_ng), dtype=np.float32)
        for i, (_r, lo, ln) in enumerate(wp.meta):
            ws_col[i, :ln] = g.colidx[lo:lo + ln]
            ws_val[i, :ln] = g.values[lo:lo + ln]
        op.warp_slabs = {
            "colidx": jnp.asarray(ws_col), "values": jnp.asarray(ws_val),
            "rowloc": jnp.zeros((W, warp_ng), dtype=jnp.int32),
            "out_row": jnp.asarray(wp.meta[:, :1].astype(np.int32)),
        }
        if g.n_rows * g.n_cols <= 4_000_000:
            op.dense = jnp.asarray(g.to_dense())
    return op
