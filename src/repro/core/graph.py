"""Graph containers and O(n) preprocessing from Accel-GCN §III-C.

Everything here is *host-side* preprocessing (numpy), mirroring the paper's
lightweight on-the-fly stages: degree computation, counting-sort degree
sorting, and GCN symmetric normalization. The outputs feed the partitioner
(`core/partition.py`) and the SpMM backends (`core/spmm.py`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "CSRGraph",
    "degrees_from_rowptr",
    "counting_sort_by_degree",
    "degree_sort_csr",
    "gcn_normalize",
    "csr_from_edges",
]


@dataclasses.dataclass
class CSRGraph:
    """A CSR sparse matrix (adjacency) with optional edge values.

    ``rowptr``: int32[n_rows+1], ``colidx``: int32[nnz], ``values``:
    float32[nnz] (defaults to ones). ``perm`` records the degree-sort row
    permutation applied (new_row -> old_row), identity if unsorted.
    """

    rowptr: np.ndarray
    colidx: np.ndarray
    values: np.ndarray
    n_cols: int
    perm: Optional[np.ndarray] = None  # new_row -> old_row

    @property
    def n_rows(self) -> int:
        return len(self.rowptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.rowptr[-1])

    @property
    def degrees(self) -> np.ndarray:
        return degrees_from_rowptr(self.rowptr)

    def validate(self) -> None:
        assert self.rowptr.ndim == 1 and self.colidx.ndim == 1
        assert self.rowptr[0] == 0 and self.rowptr[-1] == len(self.colidx)
        assert np.all(np.diff(self.rowptr) >= 0), "rowptr must be monotone"
        if self.nnz:
            assert self.colidx.min() >= 0 and self.colidx.max() < self.n_cols
        assert len(self.values) == len(self.colidx)

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=self.values.dtype)
        for r in range(self.n_rows):
            lo, hi = self.rowptr[r], self.rowptr[r + 1]
            np.add.at(out[r], self.colidx[lo:hi], self.values[lo:hi])
        return out


def degrees_from_rowptr(rowptr: np.ndarray) -> np.ndarray:
    """Row degrees from the CSR row pointer — step (1) of degree sorting."""
    return np.diff(rowptr).astype(np.int64)


def counting_sort_by_degree(degrees: np.ndarray) -> np.ndarray:
    """Stable counting sort of row ids by ASCENDING degree. O(n + max_deg).

    The paper sorts rows so identical degrees are adjacent; stability keeps
    original order within a degree class (paper §III-C step 2). Returns the
    permutation ``perm`` with ``perm[k]`` = original row id of the k-th sorted
    row. Ascending order groups the small-degree rows (many rows per block)
    first; descending works equally — the partitioner only needs grouping.
    """
    n = len(degrees)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    max_deg = int(degrees.max())
    counts = np.zeros(max_deg + 2, dtype=np.int64)
    np.add.at(counts, degrees + 1, 1)
    starts = np.cumsum(counts)[:-1]  # first slot of each degree class
    perm = np.empty(n, dtype=np.int64)
    # Vectorized stable placement: rows are scanned in original order; the slot
    # for row i is starts[deg[i]] + (#rows with same degree before i).
    order_within = _rank_within_class(degrees)
    perm[starts[degrees] + order_within] = np.arange(n)
    return perm


def _rank_within_class(keys: np.ndarray) -> np.ndarray:
    """rank_within_class[i] = number of j<i with keys[j]==keys[i]. O(n)."""
    # argsort(kind="stable") on small ints is counting-based in numpy; we keep
    # a pure O(n) fallback for clarity and determinism.
    n = len(keys)
    seen = {}
    out = np.empty(n, dtype=np.int64)
    # This python loop is O(n) with tiny constants; used only at preprocessing
    # time. For large graphs we switch to the vectorized variant below.
    if n > 200_000:
        order = np.argsort(keys, kind="stable")
        ranks = np.empty(n, dtype=np.int64)
        sorted_keys = keys[order]
        grp_start = np.concatenate(([0], np.flatnonzero(np.diff(sorted_keys)) + 1))
        idx_in_grp = np.arange(n) - np.repeat(grp_start, np.diff(np.concatenate((grp_start, [n]))))
        ranks[order] = idx_in_grp
        return ranks
    for i, k in enumerate(keys):
        c = seen.get(int(k), 0)
        out[i] = c
        seen[int(k)] = c + 1
    return out


def degree_sort_csr(g: CSRGraph) -> CSRGraph:
    """Degree-sort a CSR matrix: permute rows so equal degrees are adjacent.

    Steps mirror the paper: (1) degrees from rowptr, (2) stable counting sort,
    (3) rebuild rowptr/colidx in the new order. Total O(n + nnz).
    """
    deg = degrees_from_rowptr(g.rowptr)
    perm = counting_sort_by_degree(deg)
    new_deg = deg[perm]
    new_rowptr = np.zeros(g.n_rows + 1, dtype=np.int64)
    np.cumsum(new_deg, out=new_rowptr[1:])
    # Gather each row's slice. Vectorized via fancy indexing on ranges.
    nnz = g.nnz
    src_starts = g.rowptr[perm]
    gather = _concat_ranges(src_starts, new_deg, nnz)
    out = CSRGraph(
        rowptr=new_rowptr.astype(np.int64),
        colidx=g.colidx[gather],
        values=g.values[gather],
        n_cols=g.n_cols,
        perm=perm,
    )
    return out


def _concat_ranges(starts: np.ndarray, lengths: np.ndarray, total: int) -> np.ndarray:
    """Indices equivalent to concatenate([arange(s, s+l) for s, l in zip(...)])."""
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(lengths)
    idx = np.arange(total, dtype=np.int64)
    row_of = np.searchsorted(ends, idx, side="right")
    offset_in_row = idx - (ends - lengths)[row_of]
    return starts[row_of] + offset_in_row


def gcn_normalize(g: CSRGraph, add_self_loops: bool = True) -> CSRGraph:
    """Symmetric GCN normalization A' = D^-1/2 (A + I) D^-1/2 (Kipf-Welling)."""
    if add_self_loops:
        g = _add_self_loops(g)
    deg = degrees_from_rowptr(g.rowptr).astype(np.float64)
    # Weighted degree for normalization uses the value sums; for unweighted
    # graphs this equals the structural degree.
    dinv = np.zeros(g.n_rows)
    nz = deg > 0
    dinv[nz] = 1.0 / np.sqrt(deg[nz])
    row_of = np.repeat(np.arange(g.n_rows), np.diff(g.rowptr))
    vals = g.values.astype(np.float64) * dinv[row_of] * dinv[g.colidx]
    return CSRGraph(g.rowptr, g.colidx, vals.astype(np.float32), g.n_cols, g.perm)


def _add_self_loops(g: CSRGraph) -> CSRGraph:
    assert g.n_rows == g.n_cols, "self loops need a square matrix"
    deg = np.diff(g.rowptr)
    new_rowptr = np.zeros(g.n_rows + 1, dtype=np.int64)
    np.cumsum(deg + 1, out=new_rowptr[1:])
    nnz = g.nnz + g.n_rows
    colidx = np.empty(nnz, dtype=g.colidx.dtype)
    values = np.empty(nnz, dtype=g.values.dtype)
    dst = _concat_ranges(new_rowptr[:-1], deg, g.nnz)
    colidx[dst] = g.colidx
    values[dst] = g.values
    loop_pos = new_rowptr[1:] - 1
    colidx[loop_pos] = np.arange(g.n_rows)
    values[loop_pos] = 1.0
    return CSRGraph(new_rowptr, colidx, values, g.n_cols, g.perm)


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n: int,
                   values: Optional[np.ndarray] = None) -> CSRGraph:
    """Build CSR from a COO edge list (dedup not performed). O(E)."""
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    if values is None:
        values = np.ones(len(src), dtype=np.float32)
    else:
        values = values[order]
    counts = np.bincount(src, minlength=n)
    rowptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=rowptr[1:])
    return CSRGraph(rowptr, dst.astype(np.int64), values.astype(np.float32), n)
