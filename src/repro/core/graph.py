"""Graph containers and O(n) preprocessing from Accel-GCN §III-C.

Everything here is *host-side* preprocessing (numpy), mirroring the paper's
lightweight on-the-fly stages: degree computation, counting-sort degree
sorting, and GCN symmetric normalization. The outputs feed the partitioner
(`core/partition.py`) and the SpMM backends (`core/spmm.py`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "CSRGraph",
    "degrees_from_rowptr",
    "counting_sort_by_degree",
    "degree_sort_csr",
    "gcn_normalize",
    "csr_from_edges",
    "csr_apply_edge_delta",
    "csr_transpose",
]


@dataclasses.dataclass
class CSRGraph:
    """A CSR sparse matrix (adjacency) with optional edge values.

    ``rowptr``: int32[n_rows+1], ``colidx``: int32[nnz], ``values``:
    float32[nnz] (defaults to ones). ``perm`` records the degree-sort row
    permutation applied (new_row -> old_row), identity if unsorted.
    """

    rowptr: np.ndarray
    colidx: np.ndarray
    values: np.ndarray
    n_cols: int
    perm: Optional[np.ndarray] = None  # new_row -> old_row

    @property
    def n_rows(self) -> int:
        return len(self.rowptr) - 1

    @property
    def nnz(self) -> int:
        return int(self.rowptr[-1])

    @property
    def degrees(self) -> np.ndarray:
        return degrees_from_rowptr(self.rowptr)

    def validate(self) -> None:
        assert self.rowptr.ndim == 1 and self.colidx.ndim == 1
        assert self.rowptr[0] == 0 and self.rowptr[-1] == len(self.colidx)
        assert np.all(np.diff(self.rowptr) >= 0), "rowptr must be monotone"
        if self.nnz:
            assert self.colidx.min() >= 0 and self.colidx.max() < self.n_cols
        assert len(self.values) == len(self.colidx)

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=self.values.dtype)
        for r in range(self.n_rows):
            lo, hi = self.rowptr[r], self.rowptr[r + 1]
            np.add.at(out[r], self.colidx[lo:hi], self.values[lo:hi])
        return out


def degrees_from_rowptr(rowptr: np.ndarray) -> np.ndarray:
    """Row degrees from the CSR row pointer — step (1) of degree sorting."""
    return np.diff(rowptr).astype(np.int64)


def counting_sort_by_degree(degrees: np.ndarray) -> np.ndarray:
    """Stable counting sort of row ids by ASCENDING degree. O(n + max_deg).

    The paper sorts rows so identical degrees are adjacent; stability keeps
    original order within a degree class (paper §III-C step 2). Returns the
    permutation ``perm`` with ``perm[k]`` = original row id of the k-th sorted
    row. Ascending order groups the small-degree rows (many rows per block)
    first; descending works equally — the partitioner only needs grouping.
    """
    n = len(degrees)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    max_deg = int(degrees.max())
    counts = np.zeros(max_deg + 2, dtype=np.int64)
    np.add.at(counts, degrees + 1, 1)
    starts = np.cumsum(counts)[:-1]  # first slot of each degree class
    perm = np.empty(n, dtype=np.int64)
    # Vectorized stable placement: rows are scanned in original order; the slot
    # for row i is starts[deg[i]] + (#rows with same degree before i).
    order_within = _rank_within_class(degrees)
    perm[starts[degrees] + order_within] = np.arange(n)
    return perm


def _rank_within_class(keys: np.ndarray) -> np.ndarray:
    """rank_within_class[i] = number of j<i with keys[j]==keys[i]. O(n)."""
    # argsort(kind="stable") on small ints is counting-based in numpy; we keep
    # a pure O(n) fallback for clarity and determinism.
    n = len(keys)
    seen = {}
    out = np.empty(n, dtype=np.int64)
    # This python loop is O(n) with tiny constants; used only at preprocessing
    # time. For large graphs we switch to the vectorized variant below.
    if n > 200_000:
        order = np.argsort(keys, kind="stable")
        ranks = np.empty(n, dtype=np.int64)
        sorted_keys = keys[order]
        grp_start = np.concatenate(([0], np.flatnonzero(np.diff(sorted_keys)) + 1))
        idx_in_grp = np.arange(n) - np.repeat(grp_start, np.diff(np.concatenate((grp_start, [n]))))
        ranks[order] = idx_in_grp
        return ranks
    for i, k in enumerate(keys):
        c = seen.get(int(k), 0)
        out[i] = c
        seen[int(k)] = c + 1
    return out


def degree_sort_csr(g: CSRGraph) -> CSRGraph:
    """Degree-sort a CSR matrix: permute rows so equal degrees are adjacent.

    Steps mirror the paper: (1) degrees from rowptr, (2) stable counting sort,
    (3) rebuild rowptr/colidx in the new order. Total O(n + nnz).
    """
    deg = degrees_from_rowptr(g.rowptr)
    perm = counting_sort_by_degree(deg)
    new_deg = deg[perm]
    new_rowptr = np.zeros(g.n_rows + 1, dtype=np.int64)
    np.cumsum(new_deg, out=new_rowptr[1:])
    # Gather each row's slice. Vectorized via fancy indexing on ranges.
    nnz = g.nnz
    src_starts = g.rowptr[perm]
    gather = _concat_ranges(src_starts, new_deg, nnz)
    out = CSRGraph(
        rowptr=new_rowptr.astype(np.int64),
        colidx=g.colidx[gather],
        values=g.values[gather],
        n_cols=g.n_cols,
        perm=perm,
    )
    return out


def _concat_ranges(starts: np.ndarray, lengths: np.ndarray, total: int) -> np.ndarray:
    """Indices equivalent to concatenate([arange(s, s+l) for s, l in zip(...)])."""
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    lengths = np.asarray(lengths, dtype=np.int64)
    ends = np.cumsum(lengths)
    # O(total) via repeat (searchsorted would add a log factor)
    base = np.repeat(np.asarray(starts, dtype=np.int64) - (ends - lengths),
                     lengths)
    return base + np.arange(total, dtype=np.int64)


def gcn_normalize(g: CSRGraph, add_self_loops: bool = True) -> CSRGraph:
    """Symmetric GCN normalization A' = D^-1/2 (A + I) D^-1/2 (Kipf-Welling)."""
    if add_self_loops:
        g = _add_self_loops(g)
    deg = degrees_from_rowptr(g.rowptr).astype(np.float64)
    # Weighted degree for normalization uses the value sums; for unweighted
    # graphs this equals the structural degree.
    dinv = np.zeros(g.n_rows)
    nz = deg > 0
    dinv[nz] = 1.0 / np.sqrt(deg[nz])
    row_of = np.repeat(np.arange(g.n_rows), np.diff(g.rowptr))
    vals = g.values.astype(np.float64) * dinv[row_of] * dinv[g.colidx]
    return CSRGraph(g.rowptr, g.colidx, vals.astype(np.float32), g.n_cols, g.perm)


def _add_self_loops(g: CSRGraph) -> CSRGraph:
    assert g.n_rows == g.n_cols, "self loops need a square matrix"
    deg = np.diff(g.rowptr)
    new_rowptr = np.zeros(g.n_rows + 1, dtype=np.int64)
    np.cumsum(deg + 1, out=new_rowptr[1:])
    nnz = g.nnz + g.n_rows
    colidx = np.empty(nnz, dtype=g.colidx.dtype)
    values = np.empty(nnz, dtype=g.values.dtype)
    dst = _concat_ranges(new_rowptr[:-1], deg, g.nnz)
    colidx[dst] = g.colidx
    values[dst] = g.values
    loop_pos = new_rowptr[1:] - 1
    colidx[loop_pos] = np.arange(g.n_rows)
    values[loop_pos] = 1.0
    return CSRGraph(new_rowptr, colidx, values, g.n_cols, g.perm)


def csr_apply_edge_delta(
    g: CSRGraph,
    insert_src: Optional[np.ndarray] = None,
    insert_dst: Optional[np.ndarray] = None,
    insert_val: Optional[np.ndarray] = None,
    delete_src: Optional[np.ndarray] = None,
    delete_dst: Optional[np.ndarray] = None,
    *,
    on_duplicate: str = "error",
    on_missing: str = "error",
) -> CSRGraph:
    """Apply a batched edge delta to a CSR matrix — ONE delta semantics for
    every engine and test instead of hand-rolled CSR surgery.

    Deletes apply first, then inserts (so replace-an-edge-value is
    ``delete + insert`` in a single delta). The result is deterministic:
    within each row, surviving old edges keep their relative order and
    inserted edges append after them in the order given — which is what
    makes an incremental plan repair bit-identical to a full rebuild of the
    post-delta graph.

    Defined edge cases:

    * **duplicate insert** — the edge (after deletes) already exists, or the
      insert list names the same ``(src, dst)`` twice. ``on_duplicate=
      "error"`` (default) raises ``ValueError``; ``"replace"`` overwrites
      the existing value in place (degree unchanged; the LAST occurrence in
      the insert list wins).
    * **missing delete** — ``(src, dst)`` is not present. ``on_missing=
      "error"`` (default) raises ``ValueError``; ``"ignore"`` skips it.
      A delete of an edge the graph holds multiple copies of (builders do
      not dedup) removes EVERY copy.

    Inserts/deletes must name existing node ids (``0 <= src < n_rows``,
    ``0 <= dst < n_cols``) — a delta never grows the matrix shape, so
    feature shapes and in-flight requests stay valid across versions.
    ``insert_val`` defaults to ones. Returns a NEW graph (``perm=None``,
    original row order); ``g`` is never mutated. O(nnz + delta).
    """
    if on_duplicate not in ("error", "replace"):
        raise ValueError(f"on_duplicate must be error|replace, "
                         f"got {on_duplicate!r}")
    if on_missing not in ("error", "ignore"):
        raise ValueError(f"on_missing must be error|ignore, "
                         f"got {on_missing!r}")

    def _pair(name, src, dst):
        src = (np.zeros(0, dtype=np.int64) if src is None
               else np.asarray(src, dtype=np.int64).ravel())
        dst = (np.zeros(0, dtype=np.int64) if dst is None
               else np.asarray(dst, dtype=np.int64).ravel())
        if len(src) != len(dst):
            raise ValueError(f"{name}: {len(src)} src for {len(dst)} dst")
        if len(src):
            if src.min() < 0 or src.max() >= g.n_rows:
                raise ValueError(f"{name}: src out of range [0, {g.n_rows})")
            if dst.min() < 0 or dst.max() >= g.n_cols:
                raise ValueError(f"{name}: dst out of range [0, {g.n_cols})")
        return src, dst

    ins_src, ins_dst = _pair("insert", insert_src, insert_dst)
    del_src, del_dst = _pair("delete", delete_src, delete_dst)
    if insert_val is None:
        ins_val = np.ones(len(ins_src), dtype=np.float32)
    else:
        ins_val = np.asarray(insert_val, dtype=np.float32).ravel()
        if len(ins_val) != len(ins_src):
            raise ValueError(
                f"insert: {len(ins_val)} values for {len(ins_src)} edges")

    # (src, dst) pairs as scalar keys for vectorized membership tests
    n_cols = max(int(g.n_cols), 1)
    old_row = np.repeat(np.arange(g.n_rows, dtype=np.int64),
                        np.diff(g.rowptr))
    old_key = old_row * n_cols + g.colidx.astype(np.int64)

    keep = np.ones(g.nnz, dtype=bool)
    if len(del_src):
        del_key = del_src * n_cols + del_dst
        hit = np.isin(old_key, del_key)
        if on_missing == "error":
            missing = ~np.isin(del_key, old_key)
            if missing.any():
                i = int(np.flatnonzero(missing)[0])
                raise ValueError(
                    f"delete of missing edge ({int(del_src[i])}, "
                    f"{int(del_dst[i])}) (on_missing='error')")
        keep &= ~hit

    new_val = g.values.astype(np.float32, copy=True)
    if len(ins_src):
        ins_key = ins_src * n_cols + ins_dst
        uniq, first = np.unique(ins_key, return_index=True)
        surviving_key = old_key[keep]
        dup_old = np.isin(ins_key, surviving_key)
        if on_duplicate == "error":
            if len(uniq) != len(ins_key):
                dup = np.ones(len(ins_key), dtype=bool)
                dup[first] = False
                i = int(np.flatnonzero(dup)[0])
                raise ValueError(
                    f"duplicate insert of edge ({int(ins_src[i])}, "
                    f"{int(ins_dst[i])}) within the delta "
                    f"(on_duplicate='error')")
            if dup_old.any():
                i = int(np.flatnonzero(dup_old)[0])
                raise ValueError(
                    f"insert of existing edge ({int(ins_src[i])}, "
                    f"{int(ins_dst[i])}) (on_duplicate='error')")
        else:
            # replace: existing edges get the new value in place (LAST
            # occurrence wins, matching sequential single-edge application)
            if dup_old.any():
                surv_pos = np.flatnonzero(keep)
                order = np.argsort(surviving_key, kind="stable")
                for i in np.flatnonzero(dup_old):
                    j = np.searchsorted(surviving_key[order], ins_key[i])
                    # every surviving copy of the edge takes the new value
                    while (j < len(order)
                           and surviving_key[order[j]] == ins_key[i]):
                        new_val[surv_pos[order[j]]] = ins_val[i]
                        j += 1
            fresh = ~dup_old
            # dedup the delta itself: LAST occurrence of a repeated pair wins
            last = np.zeros(len(ins_key), dtype=bool)
            seen: dict = {}
            for i in range(len(ins_key) - 1, -1, -1):
                k = int(ins_key[i])
                if k not in seen:
                    seen[k] = True
                    last[i] = True
            fresh &= last
            ins_src, ins_dst = ins_src[fresh], ins_dst[fresh]
            ins_val = ins_val[fresh]

    # assemble: per row, surviving old edges first, then appended inserts
    surv_counts = np.bincount(old_row[keep], minlength=g.n_rows)
    ins_counts = np.bincount(ins_src, minlength=g.n_rows)
    new_deg = surv_counts + ins_counts
    new_rowptr = np.zeros(g.n_rows + 1, dtype=np.int64)
    np.cumsum(new_deg, out=new_rowptr[1:])
    nnz = int(new_rowptr[-1])
    colidx = np.empty(nnz, dtype=np.int64)
    values = np.empty(nnz, dtype=np.float32)

    surv_dst = _concat_ranges(new_rowptr[:-1], surv_counts, int(keep.sum()))
    colidx[surv_dst] = g.colidx[keep]
    values[surv_dst] = new_val[keep]
    if len(ins_src):
        order = np.argsort(ins_src, kind="stable")
        ins_starts = new_rowptr[:-1] + surv_counts
        ins_dst_pos = _concat_ranges(ins_starts, ins_counts, len(ins_src))
        colidx[ins_dst_pos] = ins_dst[order]
        values[ins_dst_pos] = ins_val[order]

    return CSRGraph(new_rowptr, colidx, values, g.n_cols)


def csr_transpose(g: CSRGraph) -> CSRGraph:
    """CSC view of ``g`` as a CSRGraph: row ``v`` of the result lists the
    rows of ``g`` that have an edge INTO ``v`` (the in-adjacency view the
    neighbor sampler walks). O(E) counting build, no sort.

    Within each transposed row the entries appear in ascending source-row
    order (the row-major CSR scan is stable), so transposing twice
    round-trips a canonically ordered CSR exactly. Edge values ride along
    unchanged; ``perm`` does not survive (the result is a different matrix).
    """
    n_rows_t = g.n_cols
    row_of = np.repeat(np.arange(g.n_rows, dtype=np.int64),
                       np.diff(g.rowptr))
    counts = np.bincount(g.colidx, minlength=n_rows_t)
    rowptr_t = np.zeros(n_rows_t + 1, dtype=np.int64)
    np.cumsum(counts, out=rowptr_t[1:])
    # slot of edge e = start of its destination's class + #earlier edges
    # with the same destination (stable placement, same trick as the
    # counting degree sort above)
    rank = _rank_within_class(np.asarray(g.colidx, dtype=np.int64))
    pos = rowptr_t[np.asarray(g.colidx, dtype=np.int64)] + rank
    colidx_t = np.empty(g.nnz, dtype=np.int64)
    values_t = np.empty(g.nnz, dtype=np.float32)
    colidx_t[pos] = row_of
    values_t[pos] = g.values
    return CSRGraph(rowptr_t, colidx_t, values_t, g.n_rows)


def csr_from_edges(src: np.ndarray, dst: np.ndarray, n: int,
                   values: Optional[np.ndarray] = None) -> CSRGraph:
    """Build CSR from a COO edge list (dedup not performed). O(E)."""
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    if values is None:
        values = np.ones(len(src), dtype=np.float32)
    else:
        values = values[order]
    counts = np.bincount(src, minlength=n)
    rowptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=rowptr[1:])
    return CSRGraph(rowptr, dst.astype(np.int64), values.astype(np.float32), n)
