# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from .plan_cache import (  # noqa: F401
    PartitionConfig,
    PartitionPlan,
    PlanCache,
    build_partition_plan,
    graph_content_hash,
)
