"""Incremental partition-plan repair for streaming edge updates.

A :class:`~repro.core.plan_cache.PartitionPlan` is expensive because of the
python-loop stages (Algorithm 2 block emission, slab packing) that walk every
row and block. An edge delta touches few rows — but a naive "rebuild the
dirty degree classes" repair degenerates on power-law graphs: one touched
degree-5 row dirties the entire degree-5 class (often a third of the graph),
so class-granular repair falls back to a full rebuild for even 0.1% deltas.

:func:`repair_plan` instead repairs at **stable output positions**. Every
kernel backend scatters block outputs with ``segment_sum`` over the plan's
``out_row`` slab, so neither block ORDER nor the monotonicity of output
positions matters to the SpMM result — only that each row's non-zeros land
in some block whose ``out_row`` names that row's position. That licenses:

1. keep the old permutation verbatim: every row keeps its output position,
   ``inv_perm`` is reused by reference (touched rows' positions become
   output *slots*, not sort ranks);
2. MASK instead of rewrite: rewriting every touched row's slots to the
   drop sentinel ``n`` (an O(B x R) vectorized lookup over ``out_row``
   alone) deletes the row's old edges from the SpMM output without
   touching ``colidx``/``values``/``rowloc`` — untouched rows sharing the
   same blocks keep their lanes, so there is no re-emission amplification,
   and the lookup works unchanged on already-repaired plans (chained
   repairs need no extra bookkeeping);
3. re-emit ONLY the touched rows (not their blocks' cohabitants): build a
   degree-sorted sub-CSR of just those rows, run
   ``block_level_partition`` + ``pack_slabs`` over it with ``block_rows``
   clamped to the old plan's R (so the slabs stay rectangular with
   matching sentinels), then remap the sub plan's local ``out_row``
   indices to the rows' stable global positions;
4. splice = append: the big [B, C] slabs are concatenated on device (the
   old blocks survive byte-for-byte, dead lanes silenced purely through
   the patched host-side ``out_row``), the re-emitted blocks ride behind.

The repaired plan is **SpMM-output-identical** to a fresh
``build_partition_plan`` on the post-delta graph (the property tests
dispatch both through both batched kernel backends and compare outputs).
It is NOT bit-identical: untouched rows keep their old positions, so the
degree-sort order degrades gradually under churn — a performance property,
restored by the periodic full-rebuild fallbacks below. After a repair,
``partition.meta[:, 1]`` (nnz offset) and ``meta[:, 2]`` (start row) are no
longer globally meaningful; nothing consumes them after packing (kernels
read only the slabs; ``balance_stats`` reads ``meta[:, 0]``/``[:, 3]``,
which stay valid).

Fallbacks to a full rebuild (``PlanVersion.repaired == False``):

* the re-emitted row set exceeds ``churn_threshold`` of the rows (repair
  would cost about as much as the rebuild it replaces);
* block fragmentation: chained repairs accumulate partial blocks (each
  repair emits its own short tail blocks); when the block count drifts past
  2x the fresh-build estimate the slab footprint justifies re-compacting.

Every repair/rebuild stamps ``plan.version = old.version + 1`` — the
monotone version chain the cache publish / directory invalidation /
``mutate()`` path is built on.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .graph import CSRGraph, csr_apply_edge_delta, _concat_ranges
from .partition import BlockPartition, block_level_partition, pack_slabs
from .plan_cache import PartitionPlan, build_partition_plan, graph_content_hash

__all__ = ["EdgeDelta", "PlanVersion", "repair_plan", "apply_and_repair",
           "delta_chain_hash"]


def _arr(x, dtype) -> np.ndarray:
    return (np.zeros(0, dtype=dtype) if x is None
            else np.asarray(x, dtype=dtype).ravel())


@dataclasses.dataclass
class EdgeDelta:
    """A batched edge mutation: deletes apply first, then inserts.

    ``on_duplicate`` / ``on_missing`` carry the
    :func:`~repro.core.graph.csr_apply_edge_delta` policies with the delta,
    so the serving ``mutate()`` path and the tests share one semantics
    end to end. ``"replace"``/``"ignore"`` are the forgiving streaming
    policies; the strict defaults surface caller bugs.
    """

    insert_src: np.ndarray = None
    insert_dst: np.ndarray = None
    insert_val: Optional[np.ndarray] = None
    delete_src: np.ndarray = None
    delete_dst: np.ndarray = None
    on_duplicate: str = "error"
    on_missing: str = "error"

    def __post_init__(self):
        self.insert_src = _arr(self.insert_src, np.int64)
        self.insert_dst = _arr(self.insert_dst, np.int64)
        self.delete_src = _arr(self.delete_src, np.int64)
        self.delete_dst = _arr(self.delete_dst, np.int64)
        if self.insert_val is not None:
            self.insert_val = _arr(self.insert_val, np.float32)
            if len(self.insert_val) != len(self.insert_src):
                raise ValueError(
                    f"{len(self.insert_val)} insert values for "
                    f"{len(self.insert_src)} insert edges")
        if len(self.insert_src) != len(self.insert_dst):
            raise ValueError(f"{len(self.insert_src)} insert src for "
                             f"{len(self.insert_dst)} dst")
        if len(self.delete_src) != len(self.delete_dst):
            raise ValueError(f"{len(self.delete_src)} delete src for "
                             f"{len(self.delete_dst)} dst")

    @property
    def n_inserts(self) -> int:
        return len(self.insert_src)

    @property
    def n_deletes(self) -> int:
        return len(self.delete_src)

    @property
    def size(self) -> int:
        return self.n_inserts + self.n_deletes

    def touched_rows(self) -> np.ndarray:
        """Sorted unique row ids whose degree or content the delta touches."""
        return np.unique(np.concatenate([self.insert_src, self.delete_src]))

    def apply(self, g: CSRGraph) -> CSRGraph:
        """The post-delta graph (``g`` is never mutated)."""
        return csr_apply_edge_delta(
            g,
            insert_src=self.insert_src, insert_dst=self.insert_dst,
            insert_val=self.insert_val,
            delete_src=self.delete_src, delete_dst=self.delete_dst,
            on_duplicate=self.on_duplicate, on_missing=self.on_missing)


@dataclasses.dataclass
class PlanVersion:
    """One link of a graph's plan chain: the plan plus how it was produced."""

    plan: PartitionPlan
    version: int
    repaired: bool        # False = fell back to a full rebuild
    reason: str           # why (repair scope, or the fallback trigger)
    dirty_rows: int = 0   # rows re-partitioned (repair path only)
    reused_blocks: int = 0
    rebuilt_blocks: int = 0


def delta_chain_hash(parent_hash: str, delta: "EdgeDelta") -> str:
    """Content key of the graph ``delta`` produces from the graph keyed by
    ``parent_hash`` — in O(delta) instead of O(nnz).

    ``graph_content_hash`` walks every edge; on the streaming mutation path
    that re-hash would rival the repair itself. Chaining
    ``H(parent || delta)`` keeps the plan key collision-resistant and — the
    property multihost convergence rests on — DETERMINISTIC: every host
    applies the same delta sequence to the same base, so every host derives
    the same key without exchanging anything beyond the deltas. A chained
    key no longer equals ``graph_content_hash(g_new)``, which only means a
    from-scratch registration of identical content starts a fresh lineage.
    """
    h = hashlib.blake2b(parent_hash.encode(), digest_size=16)
    for a in (delta.insert_src, delta.insert_dst, delta.delete_src,
              delta.delete_dst):
        h.update(a.tobytes())
    h.update(b"" if delta.insert_val is None else delta.insert_val.tobytes())
    h.update(f"{delta.on_duplicate}|{delta.on_missing}".encode())
    return h.hexdigest()


def _rebuild(plan: PartitionPlan, g_new: CSRGraph, reason: str,
             graph_hash: Optional[str] = None) -> PlanVersion:
    new = build_partition_plan(g_new, plan.config, graph_hash=graph_hash)
    new.version = plan.version + 1
    return PlanVersion(plan=new, version=new.version, repaired=False,
                       reason=reason, dirty_rows=g_new.n_rows,
                       rebuilt_blocks=new.num_blocks)


def _min_blocks(deg: np.ndarray, patterns, R: int) -> int:
    """Lower bound on the block count a fresh build (with block_rows clamped
    to ``R``) would emit for row degrees ``deg`` — the fragmentation
    yardstick. Per pattern class d: ceil(count_d / block_rows_d); per split
    row (d > bound): ceil(d / bound) chunks."""
    bound = patterns.deg_bound
    low = deg[(deg > 0) & (deg <= bound)]
    total = 0
    if len(low):
        cnt = np.bincount(low, minlength=bound + 1)
        br = np.maximum(np.minimum(
            patterns.block_rows.astype(np.int64), R), 1)
        total += int(np.sum(-(-cnt[1:] // br[1:])))
    high = deg[deg > bound]
    if len(high):
        total += int(np.sum(-(-high // bound)))
    return total


def repair_plan(plan: PartitionPlan, g_old: CSRGraph, g_new: CSRGraph,
                touched_rows, *,
                churn_threshold: float = 0.25,
                graph_hash: Optional[str] = None) -> PlanVersion:
    """Repair ``plan`` (built for ``g_old``) into a plan for ``g_new``.

    ``touched_rows`` names every row whose degree OR edge content differs
    between the two graphs (``EdgeDelta.touched_rows()``); rows outside it
    must be identical in both. Both graphs are in ORIGINAL row order.
    Returns a :class:`PlanVersion` whose plan produces the same SpMM output
    as ``build_partition_plan(g_new, plan.config)`` — via stable-position
    block splicing when the dirty block set is small, via an actual full
    rebuild otherwise.

    ``graph_hash`` supplies the new plan's content key (usually a
    :func:`delta_chain_hash`) so the O(nnz) re-hash stays off the repair
    path; omitted, ``graph_content_hash(g_new)`` is computed here.
    """
    n = plan.n_rows
    if g_old.n_rows != n or g_new.n_rows != n:
        raise ValueError(
            f"row count changed: plan={n} old={g_old.n_rows} "
            f"new={g_new.n_rows} (deltas never resize the matrix)")
    if g_old.n_cols != g_new.n_cols:
        raise ValueError(f"n_cols changed: {g_old.n_cols} -> {g_new.n_cols}")
    if g_old.nnz != plan.nnz:
        raise ValueError(
            f"plan was built for nnz={plan.nnz}, g_old has {g_old.nnz}")

    touched = np.unique(_arr(touched_rows, np.int64))
    if len(touched) and (touched[0] < 0 or touched[-1] >= n):
        raise ValueError(f"touched rows outside [0, {n})")

    if graph_hash is None:
        graph_hash = graph_content_hash(g_new)

    if not len(touched):
        # empty delta: same graph, same arrays — just advance the version
        new = dataclasses.replace(
            plan, key=(graph_hash, plan.config),
            version=plan.version + 1)
        return PlanVersion(plan=new, version=new.version, repaired=True,
                           reason="empty delta",
                           reused_blocks=plan.num_blocks)

    if len(touched) > churn_threshold * max(n, 1):
        return _rebuild(
            plan, g_new,
            f"churn {len(touched)}/{n} rows > threshold {churn_threshold}",
            graph_hash=graph_hash)

    bp = plan.partition
    pats = bp.patterns
    R_old = int(plan.slabs["R"])
    deg_new = np.diff(g_new.rowptr).astype(np.int64)

    # row -> stable output position (kept verbatim; see module docstring)
    inv_old = np.asarray(plan.inv_perm, dtype=np.int64)

    # MASK the touched rows out of every block they occupy: a lane whose
    # out_row slot is the drop sentinel contributes nothing to segment_sum,
    # so pointing a row's slots at ``n`` deletes its old edges from the
    # output without touching colidx/values/rowloc. Untouched rows of the
    # same block keep their slots — no re-emission amplification.
    old_out_row = np.asarray(plan.slabs["out_row"])
    touched_pos = np.zeros(n + 1, dtype=bool)  # slot n = drop sentinel
    touched_pos[inv_old[touched]] = True
    dead = touched_pos[old_out_row]
    patched_out = np.where(dead, np.int32(n), old_out_row).astype(
        np.int32, copy=False)
    masked_blocks = int(dead.any(axis=1).sum())

    # re-emit ONLY the touched rows (empty rows emit nothing), appended as
    # fresh blocks from a degree-sorted sub-CSR
    sub_rows = touched[deg_new[touched] > 0]
    sub_rows = sub_rows[np.lexsort((sub_rows, deg_new[sub_rows]))]
    degs = deg_new[sub_rows]
    total = int(degs.sum())
    sub_rowptr = np.zeros(len(sub_rows) + 1, dtype=np.int64)
    np.cumsum(degs, out=sub_rowptr[1:])
    gather = _concat_ranges(g_new.rowptr[sub_rows], degs, total)
    # columns stay GLOBAL: SpMM's dense operand is the full feature matrix,
    # so sub-slabs index it directly — no column remap on splice
    sub_g = CSRGraph(sub_rowptr, g_new.colidx[gather],
                     g_new.values[gather], g_new.n_cols)
    clamped = dataclasses.replace(
        pats, block_rows=np.minimum(
            pats.block_rows, np.int32(max(R_old, 1))))
    sub_bp = block_level_partition(sub_g, clamped)
    sub_slabs = pack_slabs(sub_g, sub_bp, R=R_old)

    reused = bp.num_blocks
    rebuilt = sub_bp.num_blocks
    if reused + rebuilt > 2 * _min_blocks(deg_new, pats, R_old) + 16:
        # chained repairs accumulate appended blocks and dead lanes; once
        # the count drifts past 2x a fresh build's, re-compact
        return _rebuild(
            plan, g_new,
            f"fragmentation {reused + rebuilt} blocks after repair",
            graph_hash=graph_hash)

    # remap the sub plan's local row indices to stable global positions
    pos_map = inv_old[sub_rows].astype(np.int32)
    n_sub = len(sub_rows)
    if n_sub:
        sub_out_row = np.where(
            sub_slabs["out_row"] == n_sub, np.int32(n),
            pos_map[np.minimum(sub_slabs["out_row"], n_sub - 1)]
        ).astype(np.int32)
    else:
        sub_out_row = sub_slabs["out_row"]  # (0, R) — nothing to remap
    sub_meta = sub_bp.meta.copy()
    if len(sub_meta):
        sub_meta[:, 1] = -1  # sub-CSR nnz offsets are meaningless globally
        sub_meta[:, 2] = pos_map[sub_meta[:, 2]]

    # splice: every old block survives verbatim (dead lanes masked via
    # patched_out), re-emitted blocks appended — block order is irrelevant,
    # every kernel scatters through out_row. The big [B, C] slabs are
    # concatenated ON DEVICE; only out_row ([B, R], an order of magnitude
    # smaller) ever visits the host, for the mask.
    C = int(plan.slabs["C"])
    slab_colidx = jnp.concatenate(
        [plan.slabs["colidx"], jnp.asarray(sub_slabs["colidx"])])
    slab_values = jnp.concatenate(
        [plan.slabs["values"], jnp.asarray(sub_slabs["values"])])
    slab_rowloc = jnp.concatenate(
        [plan.slabs["rowloc"], jnp.asarray(sub_slabs["rowloc"])])
    slab_out_row = np.concatenate([patched_out, sub_out_row])

    new_bp = BlockPartition(
        meta=np.concatenate([bp.meta, sub_meta]),
        n_rows_blk=np.concatenate([bp.n_rows_blk, sub_bp.n_rows_blk]),
        nnz_blk=np.concatenate([bp.nnz_blk, sub_bp.nnz_blk]),
        is_split=np.concatenate([bp.is_split, sub_bp.is_split]),
        patterns=pats, n_rows=n, nnz=g_new.nnz)

    row_of = np.repeat(np.arange(n, dtype=np.int32), deg_new)
    new_plan = PartitionPlan(
        key=(graph_hash, plan.config),
        n_rows=n, n_cols=g_new.n_cols, nnz=g_new.nnz,
        slabs={"colidx": slab_colidx,
               "values": slab_values,
               "rowloc": slab_rowloc,
               "out_row": jnp.asarray(slab_out_row),
               "R": R_old, "C": C},
        inv_perm=plan.inv_perm,  # positions are stable: shared by reference
        partition=new_bp,
        coo_row=jnp.asarray(row_of),
        coo_col=jnp.asarray(g_new.colidx),
        coo_val=jnp.asarray(np.asarray(g_new.values, dtype=np.float32)),
        version=plan.version + 1,
    )
    return PlanVersion(
        plan=new_plan, version=new_plan.version, repaired=True,
        reason=f"masked {len(touched)} row(s) across {masked_blocks} "
               f"block(s), re-emitted {rebuilt} block(s)",
        dirty_rows=len(touched), reused_blocks=reused,
        rebuilt_blocks=rebuilt)


def apply_and_repair(plan: PartitionPlan, g_old: CSRGraph, delta: EdgeDelta,
                     *, churn_threshold: float = 0.25,
                     chain_hash: bool = True
                     ) -> Tuple[CSRGraph, PlanVersion]:
    """Apply ``delta`` to ``g_old`` and repair ``plan`` to match, in one
    step (the serving mutation path's workhorse). ``chain_hash`` keys the
    new plan with :func:`delta_chain_hash` (O(delta)); pass False to pay
    the O(nnz) ``graph_content_hash`` re-hash instead."""
    g_new = delta.apply(g_old)
    gh = delta_chain_hash(plan.graph_hash, delta) if chain_hash else None
    pv = repair_plan(plan, g_old, g_new, delta.touched_rows(),
                     churn_threshold=churn_threshold, graph_hash=gh)
    return g_new, pv
