"""Accel-GCN workload partitioning (paper §III-C, Algorithms 1 and 2).

Two partitioners are provided:

* ``warp_level_partition`` — the GNNAdvisor-style fixed non-zero-group
  baseline the paper compares against (one metadata record per warp).
* ``block_level_partition`` — the paper's contribution: a pattern table
  (Algorithm 1) decides, per row degree, how many rows share one block and
  how many non-zeros each workload unit ("warp") takes; a single O(n) pass
  (Algorithm 2) then emits one 128-bit metadata record *per block*.

Pattern modes:

* ``mode="paper"`` — Algorithm 1 verbatim: enumerate the factors of
  ``max_block_warps``; degree ``d`` is handled by the smallest factor ``f``
  with ``f * max_warp_nzs >= d`` using ``block_rows = max_block_warps / f``
  and ``warp_nzs = ceil(d / f)``.
* ``mode="tpu"`` — the TPU re-parameterization (DESIGN.md §2): the block is a
  fixed-capacity VMEM slab of ``C = deg_bound`` non-zeros and the pattern
  packs ``block_rows = clamp(C // d, 1, max_rows)`` rows densely.  There is
  no warp-granularity constraint on TPU, so slab utilization improves from
  ``d / next_factor_quantum(d)`` to ``>= 1 - (d-1)/C``.

Both modes share the same metadata format and the same Algorithm-2 emission
loop, so every downstream consumer (jnp backend, Pallas kernel, benchmarks)
is mode-agnostic.

Either mode accepts an explicit per-degree ``warp_nzs_override`` vector (the
upstream kernel's "v1..v5 workload" knob): entry ``d`` caps how many
non-zeros one workload unit takes for rows of degree ``d``.  Overrides are
validated against Algorithm 1's admissibility guard — some factor ``f`` of
``max_block_warps`` must satisfy ``f * warp_nzs[d] >= d``, which reduces to
``max_block_warps * warp_nzs[d] >= d`` — so every admissible override still
covers each row with one block and the kernels stay oblivious.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import CSRGraph, _concat_ranges

__all__ = [
    "PartitionPatterns",
    "BlockPartition",
    "WarpPartition",
    "get_partition_patterns",
    "validate_warp_nzs_override",
    "block_level_partition",
    "warp_level_partition",
    "pack_slabs",
    "balance_stats",
    "metadata_bytes",
]


# ---------------------------------------------------------------------------
# Algorithm 1 — pattern table
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PartitionPatterns:
    """Per-degree partition patterns for degrees 1 .. deg_bound INCLUSIVE.

    ``block_rows[d]`` rows of degree ``d`` share one block; each of the
    ``factor[d]`` workload units covers ``warp_nzs[d]`` non-zeros of a row.

    The boundary degree ``d == deg_bound`` is pattern-eligible: Algorithm 1
    admits any ``d`` with ``f * max_warp_nzs >= d`` for some factor ``f`` of
    ``max_block_warps``, and ``f = max_block_warps`` satisfies exactly
    ``max_block_warps * max_warp_nzs = deg_bound >= d``. Only ``d >
    deg_bound`` overflows a block's slab capacity and must split.
    """

    max_block_warps: int
    max_warp_nzs: int
    deg_bound: int
    block_rows: np.ndarray  # int32[deg_bound + 1]
    warp_nzs: np.ndarray    # int32[deg_bound + 1]
    factor: np.ndarray      # int32[deg_bound + 1]
    mode: str


def _factors(n: int) -> List[int]:
    return [f for f in range(1, n + 1) if n % f == 0]


def validate_warp_nzs_override(
    max_block_warps: int,
    max_warp_nzs: int,
    warp_nzs_override: Sequence[int],
) -> np.ndarray:
    """Validate a per-degree warp_nzs vector against Algorithm 1's guard.

    Accepts a vector of length ``deg_bound`` (entries for degrees 1 ..
    deg_bound) or ``deg_bound + 1`` (index 0 ignored). Every entry must be
    an integer with ``1 <= warp_nzs[d] <= max_warp_nzs`` and satisfy the
    admissibility guard ``max_block_warps * warp_nzs[d] >= d`` (i.e. SOME
    factor ``f`` of ``max_block_warps`` has ``f * warp_nzs[d] >= d``, so
    degree ``d`` still fits one block).  Returns the normalized int64 table
    indexed 0 .. deg_bound; raises ``ValueError`` otherwise.
    """
    deg_bound = max_block_warps * max_warp_nzs
    arr = np.asarray(warp_nzs_override)
    if arr.ndim != 1 or len(arr) not in (deg_bound, deg_bound + 1):
        raise ValueError(
            f"warp_nzs override must be a 1-D vector of length {deg_bound} "
            f"(degrees 1..deg_bound) or {deg_bound + 1} (index 0 ignored); "
            f"got shape {arr.shape}")
    if not np.issubdtype(arr.dtype, np.integer):
        if not np.all(np.isfinite(arr)) or np.any(arr != np.floor(arr)):
            raise ValueError("warp_nzs override entries must be integers")
    arr = arr.astype(np.int64)
    if len(arr) == deg_bound:
        arr = np.concatenate(([0], arr))
    d = np.arange(1, deg_bound + 1, dtype=np.int64)
    wnz = arr[1:]
    bad = (wnz < 1) | (wnz > max_warp_nzs) | (max_block_warps * wnz < d)
    if bad.any():
        offenders = d[bad][:8].tolist()
        raise ValueError(
            f"inadmissible warp_nzs override at degrees {offenders}"
            f"{'...' if int(bad.sum()) > 8 else ''}: need 1 <= warp_nzs[d] "
            f"<= max_warp_nzs={max_warp_nzs} and max_block_warps * "
            f"warp_nzs[d] >= d (max_block_warps={max_block_warps})")
    return arr


def get_partition_patterns(
    max_block_warps: int,
    max_warp_nzs: int,
    mode: str = "paper",
    max_rows_per_block: int | None = None,
    warp_nzs_override: Optional[Sequence[int]] = None,
) -> PartitionPatterns:
    """Algorithm 1: build the degree -> (block_rows, warp_nzs) table.

    The table covers degrees 1 .. deg_bound inclusive: ``f *
    max_warp_nzs >= d`` holds at ``d == deg_bound`` with ``f =
    max_block_warps``, so the boundary degree is one ordinary pattern block
    (block_rows=1, warp_nzs=max_warp_nzs), not a split row.

    ``warp_nzs_override`` (validated by :func:`validate_warp_nzs_override`)
    replaces the derived per-degree warp_nzs cap: in paper mode, degree ``d``
    takes the smallest factor ``f`` with ``f * warp_nzs_override[d] >= d``
    (the default table is exactly ``warp_nzs_override[d] == max_warp_nzs``
    everywhere); in tpu mode the per-block non-zero budget becomes
    ``warp_nzs_override[d] * max_block_warps`` instead of the full slab.
    Lower entries trade slab density for more, smaller blocks.
    """
    deg_bound = max_block_warps * max_warp_nzs
    block_rows = np.zeros(deg_bound + 1, dtype=np.int32)
    warp_nzs = np.zeros(deg_bound + 1, dtype=np.int32)
    factor = np.zeros(deg_bound + 1, dtype=np.int32)
    override = None
    if warp_nzs_override is not None:
        override = validate_warp_nzs_override(
            max_block_warps, max_warp_nzs, warp_nzs_override)

    if mode == "paper":
        factors = _factors(max_block_warps)
        if override is None:
            i = 0
            deg = 1
            # Verbatim transcription of Algorithm 1 (inclusive upper bound:
            # the guard admits deg_bound itself via the largest factor).
            while deg <= deg_bound:
                if factors[i] * max_warp_nzs >= deg:
                    block_rows[deg] = max_block_warps // factors[i]
                    warp_nzs[deg] = math.ceil(deg / factors[i])
                    factor[deg] = factors[i]
                    deg += 1
                else:
                    i += 1
        else:
            # Same guard with the per-degree cap; admissibility guarantees
            # the largest factor always qualifies, so the scan terminates.
            for deg in range(1, deg_bound + 1):
                f = next(fc for fc in factors
                         if fc * int(override[deg]) >= deg)
                block_rows[deg] = max_block_warps // f
                warp_nzs[deg] = math.ceil(deg / f)
                factor[deg] = f
    elif mode == "tpu":
        # Dense VMEM-slab packing: as many rows as fit the slab, capped so
        # the one-hot segment matmul operand stays MXU-sized.  An override
        # shrinks the per-block non-zero budget below the full slab.
        cap = max_rows_per_block or max_block_warps
        for deg in range(1, deg_bound + 1):
            budget = (deg_bound if override is None
                      else int(override[deg]) * max_block_warps)
            br = max(1, min(cap, budget // deg))
            block_rows[deg] = br
            warp_nzs[deg] = deg  # one unit per row on TPU
            factor[deg] = 1
    else:
        raise ValueError(f"unknown pattern mode {mode!r}")

    return PartitionPatterns(
        max_block_warps=max_block_warps,
        max_warp_nzs=max_warp_nzs,
        deg_bound=deg_bound,
        block_rows=block_rows,
        warp_nzs=warp_nzs,
        factor=factor,
        mode=mode,
    )


# ---------------------------------------------------------------------------
# Algorithm 2 — block emission
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class BlockPartition:
    """Block-level partition of a degree-sorted CSR matrix.

    ``meta`` mirrors the paper's int4 (4 x int32 = 128-bit) record per block:
      meta[:, 0] = deg   (row degree; for split blocks the full row degree)
      meta[:, 1] = loc   (starting non-zero offset)
      meta[:, 2] = row   (starting row id, in degree-sorted order)
      meta[:, 3] = info  (deg <= bound: warp_nzs << 16 | n_rows;
                          deg >  bound: non-zeros assigned to this block)
    Unpacked convenience arrays are kept alongside.
    """

    meta: np.ndarray        # int32[B, 4]
    n_rows_blk: np.ndarray  # int32[B] rows this block produces output for
    nnz_blk: np.ndarray     # int32[B] non-zeros this block consumes
    is_split: np.ndarray    # bool[B]  part of a row with deg > deg_bound
    patterns: PartitionPatterns
    n_rows: int
    nnz: int

    @property
    def num_blocks(self) -> int:
        return len(self.meta)


def block_level_partition(g: CSRGraph, patterns: PartitionPatterns) -> BlockPartition:
    """Algorithm 2: one pass over degree-sorted rows, emit per-block metadata.

    ``g`` must already be degree-sorted (rows with equal degree adjacent);
    this is asserted cheaply. Complexity O(n + B).
    """
    deg = np.diff(g.rowptr).astype(np.int64)
    n = g.n_rows
    bound = patterns.deg_bound

    recs: List[Tuple[int, int, int, int, int, int, bool]] = []
    r = 0
    while r < n:
        d = int(deg[r])
        if d == 0:  # empty rows produce no work; outputs stay zero
            r += 1
            continue
        if d <= bound:
            # pattern-eligible (Algorithm 1 admits d == bound via the
            # largest factor: one row per block, slab filled exactly);
            # run length of this degree class (degree-sorted => contiguous)
            r_end = r
            while r_end < n and deg[r_end] == d:
                r_end += 1
            br = int(patterns.block_rows[d])
            wnz = int(patterns.warp_nzs[d])
            rows_remaining = r_end - r
            row = r
            while rows_remaining > 0:
                take = min(br, rows_remaining)
                loc = int(g.rowptr[row])
                info = (wnz << 16) | take
                recs.append((d, loc, row, info, take, take * d, False))
                row += take
                rows_remaining -= take
            r = r_end
        else:
            # Row degree EXCEEDS a block's capacity (d > bound): split
            # across blocks with revisit-accumulation in the kernels.
            loc = int(g.rowptr[r])
            remaining = d
            while remaining > 0:
                take_nz = min(bound, remaining)
                recs.append((d, loc, r, take_nz, 1, take_nz, True))
                loc += take_nz
                remaining -= take_nz
            r += 1

    if recs:
        arr = np.array([rec[:4] for rec in recs], dtype=np.int64)
        meta = np.empty((len(recs), 4), dtype=np.int32)
        meta[:, 0] = np.minimum(arr[:, 0], np.iinfo(np.int32).max)
        meta[:, 1:] = arr[:, 1:].astype(np.int32)
        n_rows_blk = np.array([rec[4] for rec in recs], dtype=np.int32)
        nnz_blk = np.array([rec[5] for rec in recs], dtype=np.int32)
        is_split = np.array([rec[6] for rec in recs], dtype=bool)
    else:
        meta = np.zeros((0, 4), dtype=np.int32)
        n_rows_blk = np.zeros(0, dtype=np.int32)
        nnz_blk = np.zeros(0, dtype=np.int32)
        is_split = np.zeros(0, dtype=bool)

    return BlockPartition(
        meta=meta,
        n_rows_blk=n_rows_blk,
        nnz_blk=nnz_blk,
        is_split=is_split,
        patterns=patterns,
        n_rows=n,
        nnz=g.nnz,
    )


# ---------------------------------------------------------------------------
# Baseline: warp-level partition (GNNAdvisor-style non-zero groups)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class WarpPartition:
    """Fixed NZ-group partition: one record {row, col_off, len} per warp.

    The paper notes each 96-bit record pads to 128 bits on a 128-bit bus,
    which is what ``metadata_bytes`` accounts for.
    """

    meta: np.ndarray  # int32[W, 3] (row, loc, len)
    ng_size: int
    n_rows: int
    nnz: int

    @property
    def num_warps(self) -> int:
        return len(self.meta)


def warp_level_partition(g: CSRGraph, ng_size: int = 32) -> WarpPartition:
    deg = np.diff(g.rowptr).astype(np.int64)
    groups_per_row = np.ceil(deg / ng_size).astype(np.int64)
    total = int(groups_per_row.sum())
    meta = np.empty((total, 3), dtype=np.int32)
    w = 0
    for r in range(g.n_rows):
        lo, hi = int(g.rowptr[r]), int(g.rowptr[r + 1])
        for s in range(lo, hi, ng_size):
            meta[w] = (r, s, min(ng_size, hi - s))
            w += 1
    return WarpPartition(meta=meta, ng_size=ng_size, n_rows=g.n_rows, nnz=g.nnz)


# ---------------------------------------------------------------------------
# Kernel-side packed slabs
# ---------------------------------------------------------------------------
def pack_slabs(
    g: CSRGraph, bp: BlockPartition, R: int | None = None
) -> Dict[str, np.ndarray]:
    """Materialize fixed-capacity per-block slabs for the Pallas/jnp kernels.

    Returns dict with, for B = num_blocks, C = deg_bound, R = max rows/block:
      colidx  int32[B, C]  column index per slab slot (0 for padding)
      values  f32[B, C]    non-zero value per slot (0 for padding)
      rowloc  int32[B, C]  local output row per slot (R-1 sentinel on padding
                           with value 0, so padded lanes contribute nothing)
      out_row int32[B, R]  global output row per local row (n sentinel = drop)
      R, C                 python ints
    Every non-zero lands in exactly one slab slot.

    ``R`` may be forced wider than this partition strictly needs — incremental
    plan repair packs just the dirty block range with the FULL plan's R so the
    spliced slabs stay rectangular (and the rowloc/out_row sentinels match the
    untouched blocks bit for bit).
    """
    B = bp.num_blocks
    C = bp.patterns.deg_bound
    need = int(bp.n_rows_blk.max()) if B else 1
    if R is None:
        R = need
    elif R < need:
        raise ValueError(f"forced R={R} < max rows/block {need}")
    if B == 0:
        return {"colidx": np.zeros((0, C), dtype=np.int32),
                "values": np.zeros((0, C), dtype=np.float32),
                "rowloc": np.full((0, C), R - 1 if R > 0 else 0,
                                  dtype=np.int32),
                "out_row": np.full((0, R), bp.n_rows, dtype=np.int32),
                "R": R, "C": C}

    # Fully vectorized: block b's non-zeros live at CSR offsets
    # [loc_b, loc_b + nnz_b). A padded (B, C) gather + validity mask
    # replaces the per-block python loop — slot j of block b reads CSR
    # offset loc_b + j when j < nnz_b, else keeps the pad value.
    loc = bp.meta[:, 1].astype(np.int64)
    slot = np.arange(C, dtype=np.int32)[None, :]
    valid = slot < bp.nnz_blk[:, None]
    idx = np.minimum(loc[:, None] + slot, max(g.nnz - 1, 0))
    colidx = np.where(valid, g.colidx[idx], 0).astype(np.int32, copy=False)
    values = np.where(valid, g.values[idx], np.float32(0)).astype(
        np.float32, copy=False)
    # local output row per slot: slot j of a pattern block of degree d
    # serves local row j // d; split blocks emit a single local row 0
    d = np.maximum(bp.meta[:, 0], 1)[:, None]
    local = np.where(bp.is_split[:, None], 0, slot // d)
    rowloc = np.where(valid, local, R - 1).astype(np.int32, copy=False)
    # global output row per local row: row_b + arange(n_rows_blk_b)
    slot_r = np.arange(R, dtype=np.int32)[None, :]
    valid_r = slot_r < bp.n_rows_blk[:, None]
    out_row = np.where(valid_r, bp.meta[:, 2][:, None] + slot_r,
                       bp.n_rows).astype(np.int32, copy=False)
    return {"colidx": colidx, "values": values, "rowloc": rowloc,
            "out_row": out_row, "R": R, "C": C}


# ---------------------------------------------------------------------------
# Structural metrics (paper Eq. 1, Fig. 4(d)/(e) analogues)
# ---------------------------------------------------------------------------
def metadata_bytes(p) -> int:
    """Metadata footprint: 128 bits per record for both schemes (paper §III-C)."""
    if isinstance(p, BlockPartition):
        return 16 * p.num_blocks
    if isinstance(p, WarpPartition):
        return 16 * p.num_warps  # 96-bit record padded to the 128-bit bus
    raise TypeError(type(p))


def balance_stats(p) -> Dict[str, float]:
    """Workload balance: fraction of issue slots doing useful work.

    warp-level: each warp owns ``ng_size`` slots; block-level: each block owns
    ``deg_bound`` slab slots (the paper's max_block_warps x max_warp_nzs).
    """
    if isinstance(p, WarpPartition):
        slots = p.num_warps * p.ng_size
        return {
            "records": p.num_warps,
            "slots": float(slots),
            "utilization": p.nnz / slots if slots else 1.0,
            "metadata_bytes": float(metadata_bytes(p)),
        }
    if isinstance(p, BlockPartition):
        slots = p.num_blocks * p.patterns.deg_bound
        # Paper-mode blocks only *reserve* block_rows*warp_nzs*factor slots;
        # report both the reserved-slot and slab-capacity utilization.
        reserved = int(
            np.sum(np.where(p.is_split, p.nnz_blk,
                            p.n_rows_blk.astype(np.int64)
                            * (p.meta[:, 3] >> 16).astype(np.int64)
                            * p.patterns.factor[np.minimum(p.meta[:, 0],
                                                           p.patterns.deg_bound)]))
        )
        return {
            "records": p.num_blocks,
            "slots": float(slots),
            "utilization": p.nnz / slots if slots else 1.0,
            "reserved_slots": float(reserved),
            "reserved_utilization": p.nnz / reserved if reserved else 1.0,
            "metadata_bytes": float(metadata_bytes(p)),
        }
    raise TypeError(type(p))
