"""Serve GCN inference over a fleet of graphs through GraphServeEngine.

    PYTHONPATH=src python examples/serve_gcn.py

Simulates the serving north star at desk scale: several distinct graphs,
repeated inference traffic. Every layer's aggregation A'.(XW) for ALL graphs
in flight goes through ONE fused multi-graph SpMM dispatch; partition plans
are built once per graph and then always hit the cache. The engine's answer
is checked against the direct single-graph GraphOp path.

The second half demonstrates the continuous-batching core: N caller
threads submit single requests (``engine.submit -> Future``) and the
background scheduler coalesces them into fused cross-caller dispatches —
the thing the old blocking ``serve()`` fundamentally could not do.

The final section is the online partition autotuner quickstart: pass
``tuner=PlanTuner(...)`` at engine construction and hot graphs get their
partition config searched in the background — a fraction of live
dispatches is duplicated onto candidate plans OFF the critical path
(reads always answer from the incumbent and never pay for a candidate),
and a candidate that wins a streak of paired shadow measurements is
promoted through the plan version chain. ``tune_offline`` is the same
search as a one-shot CLI (``scripts/tune_partition.py``).
"""
import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import gcn_normalize
from repro.core.plan_cache import PartitionConfig
from repro.core.plan_repair import EdgeDelta
from repro.data.graphs import make_power_law_graph, node_features
from repro.models.gcn import GraphOp
from repro.models.layers import dense_init
from repro.serve.graph_engine import GraphRequest, GraphServeEngine
from repro.tuning import PlanTuner, tune_offline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=600)
    ap.add_argument("--edges", type=int, default=3600)
    ap.add_argument("--dims", type=int, nargs="+", default=[32, 64, 16])
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()

    # tuner quickstart, part 1: attach a PlanTuner and any graph whose
    # request rate crosses hot_rate gets shadow-tuned in the background
    tuner = PlanTuner(hot_rate=5.0, shadow_fraction=0.5, win_streak=2,
                      min_improvement=0.01, max_trials=4)
    engine = GraphServeEngine(config=PartitionConfig(), tuner=tuner,
                              backend="blocked", max_graphs_per_batch=4)
    graphs = {}
    for i in range(args.graphs):
        gid = f"g{i}"
        g = gcn_normalize(make_power_law_graph(
            args.nodes + 37 * i, args.edges + 101 * i, seed=i))
        engine.register_graph(gid, g)
        graphs[gid] = g
    print(f"[serve_gcn] registered {args.graphs} graphs; "
          f"cache builds={engine.cache.builds}")

    # One shared GCN weight stack (dims[0] -> ... -> dims[-1]).
    ks = jax.random.split(jax.random.PRNGKey(0), len(args.dims) - 1)
    weights = [dense_init(k, a, b, jnp.float32)
               for k, a, b in zip(ks, args.dims[:-1], args.dims[1:])]

    def engine_forward(feats):  # {gid: [N, F]} -> logits per graph
        h = dict(feats)
        for li, w in enumerate(weights):
            reqs = [GraphRequest(gid, jnp.dot(h[gid], w)) for gid in h]
            for r in engine.serve(reqs):
                h[r.graph_id] = (jax.nn.relu(r.out)
                                 if li < len(weights) - 1 else r.out)
        return h

    feats = {gid: jnp.asarray(node_features(g.n_rows, args.dims[0], seed=i))
             for i, (gid, g) in enumerate(graphs.items())}

    t0 = time.perf_counter()
    for rnd in range(args.rounds):
        logits = engine_forward(feats)
    dt = time.perf_counter() - t0

    # Cross-check one graph against the direct (unbatched) operator path.
    gid0 = next(iter(graphs))
    aggr = GraphOp.build(graphs[gid0], backend="blocked",
                         plan_cache=engine.cache)
    h = feats[gid0]
    for li, w in enumerate(weights):
        h = aggr(jnp.dot(h, w))
        if li < len(weights) - 1:
            h = jax.nn.relu(h)
    err = float(jnp.max(jnp.abs(h - logits[gid0])))
    assert err < 1e-3, f"engine vs direct mismatch: {err}"

    st = engine.stats()
    print(f"[serve_gcn] {args.rounds} rounds x {len(weights)} layers x "
          f"{args.graphs} graphs in {dt:.2f}s")
    print(f"[serve_gcn] batches={st['batches_dispatched']} "
          f"requests={st['requests_served']} "
          f"requests/batch={st['requests_per_batch']:.1f} "
          f"rows/s={st['rows_per_s']:.3g}")
    print(f"[serve_gcn] plan cache: builds={st['cache_builds']} "
          f"hits={st['cache_hits']} hit_rate={st['cache_hit_rate']:.3f} "
          f"(partitioned each graph exactly once)")
    print(f"[serve_gcn] engine vs direct GraphOp max|err| = {err:.2e}  OK")

    # ---- concurrent submitters: cross-caller continuous batching ---------
    base_batches = engine.batches_dispatched
    base_graphs = engine.graphs_dispatched
    n_threads, per_thread = 4, 6

    def caller(t):
        futs = []
        for k in range(per_thread):
            gid = f"g{(t + k) % args.graphs}"
            futs.append(engine.submit(gid, jnp.dot(feats[gid], weights[0])))
        for f in futs:
            f.result()

    threads = [threading.Thread(target=caller, args=(t,))
               for t in range(n_threads)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    dt = time.perf_counter() - t0
    d_batches = engine.batches_dispatched - base_batches
    d_graphs = engine.graphs_dispatched - base_graphs
    sst = engine.scheduler.stats()
    print(f"[serve_gcn] concurrent: {n_threads} threads x {per_thread} "
          f"submits in {dt:.2f}s -> {d_batches} fused dispatches "
          f"({d_graphs / max(d_batches, 1):.1f} graphs/dispatch, "
          f"flushes: size={sst['flush_size']:.0f} "
          f"deadline={sst['flush_deadline']:.0f}, "
          f"p99 latency {sst['p99_latency_s'] * 1e3:.1f}ms)")

    # ---- streaming edge updates: mutate() + incremental plan repair ------
    # A batched edge delta against a LIVE graph: deletes a few edges,
    # inserts a few (with weights), and publishes the repaired plan as the
    # next version of g0's chain — reads in flight keep the old version.
    g0 = graphs[gid0]
    rng = np.random.default_rng(0)
    eids = rng.choice(g0.nnz, 8, replace=False)
    rows = rng.integers(0, g0.n_rows, 8)
    delta = EdgeDelta(
        delete_src=np.searchsorted(g0.rowptr, eids, side="right") - 1,
        delete_dst=g0.colidx[eids],
        insert_src=rows, insert_dst=rng.integers(0, g0.n_cols, 8),
        insert_val=rng.random(8).astype(np.float32),
        on_duplicate="replace", on_missing="ignore")
    info = engine.mutate(gid0, delta).result()   # Future, like submit()
    y = engine.submit(gid0, feats[gid0]).result()  # serves the NEW version
    g1 = delta.apply(g0)
    ref = GraphOp.build(g1, backend="blocked")(feats[gid0])
    merr = float(jnp.max(jnp.abs(y - ref)))
    assert merr < 1e-3, f"post-mutation mismatch: {merr}"
    print(f"[serve_gcn] mutate: v{info['version']} published via "
          f"{'repair' if info['repaired'] else 'rebuild'} "
          f"({info['dirty_rows']} dirty rows), post-delta max|err| = "
          f"{merr:.2e}  OK")

    # ---- online partition autotuner quickstart ---------------------------
    # Part 2: a hot burst on one graph. The tuner duplicates every other
    # dispatch onto a candidate plan in a background worker (live answers
    # always come from the incumbent — shadows never touch the read path);
    # a candidate that wins 2 consecutive paired measurements by >= 1% is
    # published as the graph's next plan version.
    x_hot = feats[gid0] @ weights[0]
    for _ in range(60):
        engine.serve_one(gid0, x_hot)
        time.sleep(0.005)       # paced so shadows measure on an idle host
    ts = engine.stats()
    tuned = engine.plan_for(gid0).tuned
    print(f"[serve_gcn] tuner: {ts['shadow_dispatches']:.0f} shadow "
          f"measurements, {ts['shadow_skipped']:.0f} skipped (worker busy), "
          f"promotions={ts['tuned_promotions']:.0f}"
          + (f" -> '{tuned['label']}' now serving" if tuned else
             " (incumbent still best on this mix)"))
    # Part 3: the same search as a one-shot offline ranking (what
    # scripts/tune_partition.py prints for a saved graph)
    off = tune_offline(graphs[gid0], feat_dim=8, repeats=1)
    best = off["best"]
    if best is not None:
        print(f"[serve_gcn] tune_offline: best candidate "
              f"'{best['label']}' at {best['speedup_vs_base']:.2f}x vs "
              f"the default config")
    engine.close()


if __name__ == "__main__":
    main()
