"""Fleet serving demo on 8 simulated devices.

    PYTHONPATH=src python examples/serve_fleet.py

Forces ``--xla_force_host_platform_device_count=8`` (before jax import), so
a laptop CPU behaves like an 8-device host: the FleetGraphEngine places
each registered graph's partition plan on one device (consistent-hash +
load-aware override), groups every flush by owning device, and launches the
per-device fused dispatches concurrently. A narrow giant graph takes the
block-sharded whole-mesh path instead — its partition blocks round-robin
across all 8 devices and the per-device row slabs psum back together.
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core.graph import gcn_normalize                    # noqa: E402
from repro.data.graphs import make_power_law_graph            # noqa: E402
from repro.serve.fleet import FleetGraphEngine                # noqa: E402
from repro.serve.graph_engine import (                        # noqa: E402
    GraphRequest, GraphServeEngine,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=12)
    ap.add_argument("--nodes", type=int, default=300)
    ap.add_argument("--edges", type=int, default=2000)
    ap.add_argument("--feat", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()

    print(f"[serve_fleet] {len(jax.devices())} devices: {jax.devices()}")
    fleet = FleetGraphEngine(backend="blocked", max_graphs_per_batch=4)
    rng = np.random.default_rng(0)

    feats = {}
    for i in range(args.graphs):
        gid = f"g{i}"
        g = gcn_normalize(make_power_law_graph(
            args.nodes + 23 * i, args.edges + 77 * i, seed=i))
        fleet.register_graph(gid, g)
        feats[gid] = jnp.asarray(rng.normal(size=(g.n_cols, args.feat)),
                                 jnp.float32)
    cs = fleet.cache.stats()
    print(f"[serve_fleet] {args.graphs} plans placed over "
          f"{cs['devices']} devices; shard sizes={cs['shard_sizes']} "
          f"(overrides={cs['placement_overrides']})")

    # mixed recurring traffic: flushes group by owning device, devices fire
    # concurrently
    for rnd in range(args.rounds):
        reqs = [GraphRequest(gid, x) for gid, x in feats.items()]
        fleet.serve(reqs)
    st = fleet.stats()
    print(f"[serve_fleet] {st['requests_served']:.0f} requests in "
          f"{st['fleet_rounds']:.0f} fleet rounds "
          f"(graphs/round={st['fleet_graphs_per_round']:.1f}); "
          f"per-device dispatches={st['fleet_device_dispatches']} "
          f"occupancy={st['fleet_occupancy']:.2f}")

    # one giant narrow graph: block-sharded across the whole mesh
    # "giant" = past the 4096-row resident VMEM cap of one device
    big = gcn_normalize(make_power_law_graph(6000, 40000, seed=99))
    plan = fleet.register_graph("big", big)
    xb = jnp.asarray(rng.normal(size=(big.n_cols, args.feat)), jnp.float32)
    out = fleet.serve_one("big", xb)
    st = fleet.stats()
    print(f"[serve_fleet] giant graph: {plan.num_blocks} blocks "
          f"block-sharded -> per-device counts={st['fleet_block_counts']} "
          f"(balance={st['fleet_block_balance']:.3f}, 1.0 = perfect)")

    # cross-check against a single-device engine
    single = GraphServeEngine(backend="blocked")
    single.register_graph("big", big)
    ref = single.serve_one("big", xb)
    err = float(jnp.max(jnp.abs(out - ref)))
    print(f"[serve_fleet] fleet vs single-device max|diff| = {err:.2e}")
    assert err < 1e-4
    fleet.close()
    single.close()
    print("[serve_fleet] OK")


if __name__ == "__main__":
    main()
