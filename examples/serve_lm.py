"""Serve a small LM through the continuous-batching decode engine.

    PYTHONPATH=src python examples/serve_lm.py --arch phi3-mini-3.8b

Two phases: the classic synchronous ``generate()`` (kept as a thin wrapper
over the scheduler), then asynchronous ``submit() -> Future`` traffic where
more requests than decode slots are in flight — finished slots are refilled
mid-round (slot-reuse admission) instead of waiting for the whole batch.
"""
import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_reduced
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"[serve] arch={cfg.name} (reduced config, vocab={cfg.vocab})")
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch=args.batch, max_seq=128, eos_id=-1)

    # synchronous wrapper (backward-compatible API)
    reqs = [Request(prompt=[1 + i, 7, 42], max_new=args.max_new - i * 2)
            for i in range(args.batch - 1)]
    t0 = time.perf_counter()
    out = engine.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in out)
    for i, r in enumerate(out):
        print(f"  req{i}: prompt={r.prompt} -> {r.out}")
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batched greedy decode)")

    # async: 2x more requests than slots; early finishers free slots that
    # are refilled mid-round from the admission queue
    n_async = args.batch * 2
    t0 = time.perf_counter()
    futs = [engine.submit([3 + i, 11, 5], max_new=4 + 3 * (i % 3))
            for i in range(n_async)]
    outs = [f.result() for f in futs]
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    st = engine.stats()
    print(f"[serve] async: {n_async} requests through {args.batch} slots in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s)")
    print(f"[serve] rounds={st['rounds']} slots_reused={st['slots_reused']} "
          f"slot_utilization={st['slot_utilization']:.2f} "
          f"p99 latency={st['sched_p99_latency_s'] * 1e3:.0f}ms")
    engine.close()


if __name__ == "__main__":
    main()
