"""Serve a small LM with batched requests through the decode engine.

    PYTHONPATH=src python examples/serve_lm.py --arch phi3-mini-3.8b
"""
import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_reduced
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    print(f"[serve] arch={cfg.name} (reduced config, vocab={cfg.vocab})")
    params = lm.init_lm(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch=args.batch, max_seq=128, eos_id=-1)

    reqs = [Request(prompt=[1 + i, 7, 42], max_new=args.max_new - i * 2)
            for i in range(args.batch - 1)]
    t0 = time.perf_counter()
    out = engine.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in out)
    for i, r in enumerate(out):
        print(f"  req{i}: prompt={r.prompt} -> {r.out}")
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s batched greedy decode)")


if __name__ == "__main__":
    main()
