"""Serve seed-node batches of ONE giant evolving graph by sampled inference.

    PYTHONPATH=src python examples/serve_sampled.py

The million-node-graph story at desk scale. Instead of registering many
small graphs, a single big graph lives in a :class:`GraphStore` (both
adjacency orientations, fed by streaming ``EdgeDelta``\\ s) and
:class:`SamplingService` answers per-seed-batch queries:

1. sample a k-hop frontier for the seed batch (deterministic per
   ``(seed, hop, node)`` — the same seeds always draw the same frontier),
2. compact it into per-hop bipartite blocks and register them with the
   serving engine under CONTENT-derived ids (recurring frontiers
   partition exactly once),
3. run the GCN layers through the plan-cache/batched-SpMM path, gathering
   only the seed rows at the end.

Under FULL fanout the sampled result is bit-identical to running the
whole graph — demonstrated below — while capped fanouts bound per-batch
work no matter how big the graph gets. The final sections stream edge
deltas into the live store (cached frontiers repair through
``engine.mutate()`` or drop — never stale) and shard the store into two
partitions with sampling routed by ownership.
"""
import argparse
import time

import jax
import numpy as np

from repro.core.plan_repair import EdgeDelta
from repro.data.graphs import (
    make_power_law_graph, node_features, seed_batches, seed_splits,
)
from repro.models.gcn import init_gcn
from repro.sampling import GraphStore, PartitionedStoreClient, SamplingService
from repro.serve import GraphServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=3000)
    ap.add_argument("--edges", type=int, default=18000)
    ap.add_argument("--dims", type=int, nargs="+", default=[32, 64, 16])
    ap.add_argument("--fanout", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=64)
    args = ap.parse_args()
    n = args.nodes

    store = GraphStore.build(make_power_law_graph(n, args.edges, seed=0),
                             normalize=True)
    engine = GraphServeEngine(backend="blocked")
    x = node_features(n, args.dims[0], seed=1)
    params = init_gcn(jax.random.PRNGKey(0), args.dims)
    n_hops = len(args.dims) - 1
    print(f"[serve_sampled] store: {store.n_nodes} nodes "
          f"{store.n_edges} edges (normalized, both orientations)")

    # ---- full fanout == the full graph, bit for bit ----------------------
    svc_full = SamplingService(engine, store, fanouts=[None] * n_hops,
                               store=store)
    engine.register_graph("full", store.in_adj)
    h = jax.numpy.asarray(x)
    for i, p in enumerate(params):
        h = engine.submit("full", jax.numpy.dot(h, p["w"])).result() + p["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    ref = np.asarray(h)
    seeds = np.random.default_rng(2).choice(n, 32, replace=False)
    out = svc_full.infer(seeds, x, params)
    assert np.array_equal(out, ref[seeds])
    f = svc_full.frontier_for(seeds)
    print(f"[serve_sampled] full fanout: frontier layers "
          f"{[len(l) for l in f.layers]} -> output BIT-identical to the "
          f"full graph on {len(seeds)} seeds  OK")

    # ---- capped fanout: bounded frontiers, recurring batches amortize ----
    svc = SamplingService(engine, store, fanouts=[args.fanout] * n_hops,
                          store=store)
    train, _val = seed_splits(n, [0.5, 0.2], seed=3)
    batches = [b for _, b in zip(range(8), seed_batches(
        train, args.batch_size, seed=4))]
    t0 = time.perf_counter()
    for _epoch in range(3):                 # epochs revisit the same batches
        for b in batches:
            svc.infer(b, x, params)
    dt = time.perf_counter() - t0
    st, est = svc.stats(), engine.stats()
    print(f"[serve_sampled] fanout={args.fanout}: "
          f"{3 * len(batches)} batches in {dt:.2f}s — frontier hit rate "
          f"{st['frontier_hit_rate']:.2f} ({st['frontier_misses']} sampled, "
          f"{st['frontier_hits']} reused), plan cache hit rate "
          f"{est['cache_hit_rate']:.2f}")

    # ---- the graph is ALIVE: stream a delta into the store ---------------
    rng = np.random.default_rng(5)
    delta = EdgeDelta(insert_src=rng.integers(0, n, 4),
                      insert_dst=batches[0][:4],   # aimed at a cached
                      #                              frontier's seeds
                      insert_val=rng.random(4).astype(np.float32),
                      on_duplicate="replace")
    store.apply_delta(delta)                # both orientations + listeners
    st = svc.stats()
    print(f"[serve_sampled] delta applied (store v{store.version}): "
          f"{st['frontier_mutations']} cached frontiers repaired via "
          f"mutate(), {st['frontiers_invalidated']} dropped for resampling "
          f"— nothing stale survives")
    svc.infer(batches[0], x, params)        # serves the post-delta graph

    # ---- partition the store: sampling routed by node ownership ----------
    shards = store.partition(2)
    bounds = [s.node_range[0] for s in shards] + [n]
    # in-process stand-in for the remote side; across real hosts this is
    # FrontierExchange.sampler_for(rank) over PeerClient channels
    remote = {1: shards[1].sample_in_neighbors}
    client = PartitionedStoreClient(shards[0], bounds, remote, 0)
    from repro.sampling import sample_frontier
    fp = sample_frontier(store.sample_in_neighbors, seeds,
                         [None] * n_hops, seed=0)   # monolithic reference
    fq = sample_frontier(client.sample_in_neighbors, seeds,
                         [None] * n_hops, seed=0)
    assert fq.content_key() == fp.content_key()
    print(f"[serve_sampled] partitioned store: {client.local_edges} local "
          f"+ {client.remote_edges} cross-partition edges sampled — "
          f"frontier identical to the monolithic store  OK")
    engine.close()


if __name__ == "__main__":
    main()
