"""Quickstart: the Accel-GCN SpMM operator end to end.

Builds a power-law graph, runs the paper's O(n) preprocessing (degree sort +
block-level partition), executes SpMM through every backend (including the
Pallas TPU kernel in interpret mode) and prints the structural quantities the
paper reports: metadata ratio (Eq. 1) and workload balance.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.graph import degree_sort_csr, gcn_normalize
from repro.core.partition import (balance_stats, block_level_partition,
                                  get_partition_patterns, metadata_bytes,
                                  warp_level_partition)
from repro.core.spmm import make_accel_spmm
from repro.data.graphs import make_power_law_graph
from repro.kernels.ref import csr_spmm_ref


def main():
    n, e, F = 2000, 16000, 96
    print(f"== building power-law graph: {n} nodes, {e} edges ==")
    g = gcn_normalize(make_power_law_graph(n, e, seed=0))
    deg = np.diff(g.rowptr)
    print(f"degrees: mean={deg.mean():.1f} max={deg.max()} "
          f"(max/mean={deg.max()/deg.mean():.0f}x — the paper's Fig. 2 skew)")

    print("\n== O(n) preprocessing: degree sort + block-level partition ==")
    gs = degree_sort_csr(g)
    for mode, mbw, mwn in [("paper", 12, 32), ("tpu", 64, 4)]:
        bp = block_level_partition(gs, get_partition_patterns(mbw, mwn, mode))
        wp = warp_level_partition(g, 32)
        st = balance_stats(bp)
        print(f"[{mode:5s}] blocks={bp.num_blocks} "
              f"metadata={metadata_bytes(bp)}B "
              f"(ratio vs warp-level={metadata_bytes(bp)/metadata_bytes(wp):.3f}, "
              f"paper Eq.1) slab_util={st['utilization']:.2f}")

    print("\n== SpMM through every backend ==")
    X = jnp.asarray(np.random.default_rng(0).normal(size=(n, F)),
                    dtype=jnp.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, X))
    op = make_accel_spmm(g, with_baselines=True)
    for be in ["pallas", "blocked", "segment", "warp"]:
        out = np.asarray(op(X, backend=be))
        print(f"  {be:8s} max|err| vs oracle = {np.abs(out-ref).max():.2e}")
    print("\nDone — see benchmarks/run.py for the paper's tables.")


if __name__ == "__main__":
    main()
