"""End-to-end driver: train a GCN with the Accel-GCN aggregation operator.

    PYTHONPATH=src python examples/train_gcn.py --preset tiny   # seconds
    PYTHONPATH=src python examples/train_gcn.py --preset 100m   # ~100M params

The 100m preset is the deliverable-(b) driver: a ~100M-parameter GCN trained
for a few hundred steps on a synthetic power-law graph, with checkpointing
and the fault-tolerant loop.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.graph import gcn_normalize
from repro.data.graphs import make_power_law_graph, node_features, node_labels
from repro.models.gcn import GraphOp, gcn_loss, init_gcn

PRESETS = {
    # name: (nodes, edges, dims, classes, steps)
    "tiny": (2_000, 12_000, [64, 128, 16], 16, 60),
    "25m": (8_000, 64_000, [1024, 2048, 2048, 2048, 2048, 256], 256, 200),
    "100m": (5_000, 40_000, [1024] + [4096] * 7 + [256], 256, 300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--variant", default="gcn", choices=["gcn", "sage", "gin"])
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    n, e, dims, classes, steps = PRESETS[args.preset]
    steps = args.steps or steps
    print(f"[train_gcn] graph: {n} nodes / {e} edges; dims={dims}+[{classes}]")
    g = gcn_normalize(make_power_law_graph(n, e, seed=0))
    aggr = GraphOp.build(g, backend="blocked")
    X = jnp.asarray(node_features(n, dims[0], 0))
    y = jnp.asarray(node_labels(n, classes, 0))

    params = init_gcn(jax.random.PRNGKey(0), dims + [classes], args.variant)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    print(f"[train_gcn] {n_params/1e6:.1f}M parameters, {steps} steps")

    loss_fn = jax.jit(lambda p: gcn_loss(p, aggr, X, y, args.variant))
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p: gcn_loss(p, aggr, X, y, args.variant)))
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.perf_counter()
    for s in range(steps):
        l, grads = grad_fn(params)
        params = jax.tree.map(lambda p, gr: p - args.lr * gr, params, grads)
        if s % 20 == 0 or s == steps - 1:
            dt = time.perf_counter() - t0
            print(f"  step {s:4d} loss={float(l):.4f} ({dt:.1f}s)")
        if ckpt and (s + 1) % 100 == 0:
            ckpt.save(s + 1, params)
    print(f"[train_gcn] final loss {float(loss_fn(params)):.4f} "
          f"in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
