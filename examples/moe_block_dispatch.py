"""The paper's technique applied beyond GCNs: MoE expert dispatch.

Token->expert routing is a sparse aggregation with power-law "degrees"
(expert loads). This demo shows the Accel-GCN recipe working on it:
degree sorting (sort tokens by expert), block-level partition (fixed
128-row blocks, one metadata word each), combined warp (128-lane tiles in
the Pallas grouped GEMM) — and that the result is dropless and balanced
even under pathological routing skew.

    PYTHONPATH=src python examples/moe_block_dispatch.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import init_moe, moe_block, moe_capacity


def main():
    B, T, D, FF, E, k = 2, 128, 64, 128, 8, 2
    p = init_moe(jax.random.PRNGKey(0), D, FF, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))

    for name, bias in [("balanced routing", 0.0), ("skewed routing", 8.0)]:
        p2 = dict(p)
        p2["router"] = p["router"] + jnp.zeros((E,)).at[0].set(bias)
        # expert loads = the "degree distribution" of this sparse problem
        logits = (x.reshape(-1, D) @ p2["router"])
        ids = jax.lax.top_k(jax.nn.softmax(logits, -1), k)[1].reshape(-1)
        loads = np.bincount(np.asarray(ids), minlength=E)
        print(f"\n== {name}: expert loads {loads.tolist()} "
              f"(max/mean={loads.max()/loads.mean():.1f}x) ==")

        y_blk, _ = moe_block(p2, x, top_k=k, n_experts=E, m_tile=16,
                             use_pallas=True)
        y_ref, _ = moe_capacity(p2, x, top_k=k, n_experts=E,
                                capacity_factor=16.0)  # effectively dropless
        y_cap, _ = moe_capacity(p2, x, top_k=k, n_experts=E,
                                capacity_factor=1.25)
        print(f"block dispatch (paper technique) vs dropless oracle: "
              f"max|err|={float(jnp.abs(y_blk - y_ref).max()):.2e}  <- dropless")
        print(f"capacity-1.25 dispatch vs dropless oracle:           "
              f"max|err|={float(jnp.abs(y_cap - y_ref).max()):.2e}  "
              f"<- drops under skew")
        nb = (T * B * k + E * 16) // 16
        print(f"metadata: one int32 per block (~{nb} blocks) — "
              f"the analogue of the paper's 128-bit block records")


if __name__ == "__main__":
    main()
