"""Deliverable (f): per-architecture smoke tests — reduced same-family config,
one forward + one train step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import lm
from repro.train.step import init_train_state, make_train_step


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_and_train_step(name):
    cfg = get_reduced(name)
    key = jax.random.PRNGKey(0)
    B, T = 2, 32
    if cfg.frontend == "token":
        inputs = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    else:
        inputs = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                                   jnp.float32).astype(jnp.bfloat16)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)

    logits = lm.lm_forward(cfg, lm.init_lm(cfg, key), inputs,
                           q_chunk=16, kv_chunk=16, ssd_chunk=8)
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"

    state = init_train_state(cfg, key)
    step = make_train_step(cfg, loss_chunk=16, q_chunk=16, kv_chunk=16,
                           ssd_chunk=8)
    state2, metrics = jax.jit(step)(state, {"inputs": inputs, "labels": labels})
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # every fp32 master weight moved (bf16 casts may round tiny deltas away)
    m0 = jax.tree_util.tree_leaves(state.opt.master)
    m1 = jax.tree_util.tree_leaves(state2.opt.master)
    changed = sum(not np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(m0, m1))
    assert changed == len(m0), f"{name}: only {changed}/{len(m0)} master leaves moved"


@pytest.mark.parametrize("name,expected_b", [
    ("qwen1.5-32b", 32.5e9), ("phi3-mini-3.8b", 3.8e9), ("gemma2-27b", 27.2e9),
    ("internlm2-20b", 19.9e9), ("dbrx-132b", 132e9), ("deepseek-moe-16b", 16.4e9),
    ("chameleon-34b", 34e9), ("mamba2-780m", 0.78e9), ("hubert-xlarge", 0.96e9),
    ("zamba2-7b", 7.2e9),
])
def test_full_config_param_counts(name, expected_b):
    """Full configs match published parameter counts within 20% (counted via
    eval_shape; no allocation)."""
    import functools
    cfg = get_config(name)
    sds = jax.eval_shape(functools.partial(lm.init_lm, cfg), jax.random.PRNGKey(0))
    n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(sds))
    assert 0.8 * expected_b < n < 1.25 * expected_b, f"{name}: {n/1e9:.2f}B"


def test_decode_state_shapes():
    cfg = get_reduced("qwen1.5-32b")
    st = lm.init_decode_state(cfg, batch=2, max_seq=64)
    assert st.caches["kv"].k.shape == (cfg.n_layers, 2, 64, cfg.n_kv_heads,
                                       cfg.d_head)
    with pytest.raises(ValueError):
        lm.init_decode_state(get_reduced("hubert-xlarge"), 2, 64)
