"""Property tests for the paper's Algorithms 1 & 2 (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import csr_from_edges, degree_sort_csr
from repro.core.partition import (
    balance_stats, block_level_partition, get_partition_patterns,
    metadata_bytes, pack_slabs, validate_warp_nzs_override,
    warp_level_partition,
)

from conftest import make_powerlaw_csr


def _graph(n, seed, zipf=1.7):
    return degree_sort_csr(make_powerlaw_csr(n=n, seed=seed, zipf=zipf))


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mbw,mwn", [(12, 32), (8, 16), (64, 4), (4, 64)])
def test_patterns_paper_invariants(mbw, mwn):
    p = get_partition_patterns(mbw, mwn, mode="paper")
    assert p.deg_bound == mbw * mwn
    # table covers 1 .. deg_bound INCLUSIVE: f*mwn >= d admits the boundary
    for d in range(1, p.deg_bound + 1):
        f, br, wn = int(p.factor[d]), int(p.block_rows[d]), int(p.warp_nzs[d])
        assert mbw % f == 0 and br == mbw // f          # factor divides warps
        assert f * mwn >= d                              # Algorithm 1 guard
        assert wn == -(-d // f)                          # ceil(d / factor)
        assert br * d <= p.deg_bound                     # block capacity bound
    # boundary degree: handled by the largest factor as ONE ordinary block
    assert int(p.factor[p.deg_bound]) == mbw
    assert int(p.block_rows[p.deg_bound]) == 1
    assert int(p.warp_nzs[p.deg_bound]) == mwn


@pytest.mark.parametrize("mode", ["paper", "tpu"])
def test_patterns_monotone_block_rows(mode):
    p = get_partition_patterns(16, 16, mode=mode)
    br = p.block_rows[1:]
    assert np.all(np.diff(br.astype(int)) <= 0)  # higher degree -> fewer rows


# ---------------------------------------------------------------------------
# Algorithm 2 invariants: every non-zero covered exactly once, in order
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 400), seed=st.integers(0, 10_000),
       mode=st.sampled_from(["paper", "tpu"]),
       mbw=st.sampled_from([4, 12, 32]), mwn=st.sampled_from([4, 16, 32]))
def test_partition_covers_all_nnz(n, seed, mode, mbw, mwn):
    g = _graph(n, seed)
    pats = get_partition_patterns(mbw, mwn, mode=mode)
    bp = block_level_partition(g, pats)
    # blocks tile the nnz range contiguously and exactly
    assert int(bp.nnz_blk.sum()) == g.nnz
    pos = 0
    for b in range(bp.num_blocks):
        assert int(bp.meta[b, 1]) == pos, "blocks must tile nnz contiguously"
        pos += int(bp.nnz_blk[b])
    # rows covered exactly once (non-split) / split rows only via one row id
    covered = np.zeros(g.n_rows, dtype=int)
    for b in range(bp.num_blocks):
        if bp.is_split[b]:
            continue
        r0, nr = int(bp.meta[b, 2]), int(bp.n_rows_blk[b])
        covered[r0:r0 + nr] += 1
    deg = np.diff(g.rowptr)
    bound = pats.deg_bound
    assert np.all(covered[(deg > 0) & (deg <= bound)] == 1)
    assert np.all(covered[deg == 0] == 0)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 300), seed=st.integers(0, 1000))
def test_split_rows_capacity(n, seed):
    g = _graph(n, seed, zipf=1.3)  # heavier tail -> split rows likely
    pats = get_partition_patterns(4, 8, mode="paper")   # tiny bound = 32
    bp = block_level_partition(g, pats)
    assert np.all(bp.nnz_blk <= pats.deg_bound)
    # only degrees STRICTLY past the bound split (deg == bound is one
    # ordinary pattern block); split blocks of one row are consecutive and
    # sum to the row degree
    deg = np.diff(g.rowptr)
    for r in np.flatnonzero(deg > pats.deg_bound):
        blocks = np.flatnonzero((bp.meta[:, 2] == r) & bp.is_split)
        assert int(bp.nnz_blk[blocks].sum()) == deg[r]
        assert np.all(np.diff(blocks) == 1)
    for r in np.flatnonzero(deg == pats.deg_bound):
        assert not np.any((bp.meta[:, 2] == r) & bp.is_split)


# ---------------------------------------------------------------------------
# metadata economics (paper Eq. 1) + balance
# ---------------------------------------------------------------------------
def test_metadata_ratio_matches_eq1():
    g = _graph(2000, 3)
    pats = get_partition_patterns(12, 32, mode="paper")
    bp = block_level_partition(g, pats)
    wp = warp_level_partition(g, 32)
    ratio = metadata_bytes(bp) / metadata_bytes(wp)
    # Eq. 1: S_B/S_W ~= 1/avg_warps_per_block
    warps_per_block = wp.num_warps / bp.num_blocks
    assert ratio == pytest.approx(1.0 / warps_per_block, rel=1e-6)
    assert ratio < 0.5  # block-level metadata is much smaller


def test_balance_tpu_mode_beats_warp_level():
    g = _graph(3000, 4)
    pats = get_partition_patterns(256, 1, mode="tpu", max_rows_per_block=64)
    bp = block_level_partition(g, pats)
    wp = warp_level_partition(g, 32)
    bs, ws = balance_stats(bp), balance_stats(wp)
    assert bs["metadata_bytes"] < ws["metadata_bytes"]


@pytest.mark.parametrize("mode", ["paper", "tpu"])
def test_boundary_degree_pattern_path_and_kernel_parity(mode):
    """Rows with deg in {bound-1, bound, bound+1}: exactly-bound rows take
    the pattern path (single block, slab filled to capacity), only
    bound+1 splits — and both kernel backends agree with the dense oracle
    across the boundary."""
    import jax.numpy as jnp
    from repro.kernels.ops import spmm_blocked, spmm_pallas

    mbw, mwn = 4, 8
    bound = mbw * mwn                      # 32
    degs = [bound - 1, bound, bound + 1, bound, 3]   # mixed boundary classes
    n = max(degs) + 2                      # enough distinct columns per row
    src = np.concatenate([np.full(d, r) for r, d in enumerate(degs)])
    dst = np.concatenate([np.arange(d) for d in degs])
    rng = np.random.default_rng(0)
    g = degree_sort_csr(csr_from_edges(
        src, dst, n, values=rng.normal(size=len(src)).astype(np.float32)))

    pats = get_partition_patterns(mbw, mwn, mode=mode)
    bp = block_level_partition(g, pats)
    deg = np.diff(g.rowptr)
    for r in np.flatnonzero(deg == bound):
        mine = np.flatnonzero(bp.meta[:, 2] == r)
        # ONE ordinary block, not split, slab filled exactly to capacity
        own = [b for b in mine if not bp.is_split[b]
               and r < bp.meta[b, 2] + bp.n_rows_blk[b]]
        assert len(own) == 1 and not bp.is_split[own[0]]
        assert int(bp.nnz_blk[own[0]]) == bound
    for r in np.flatnonzero(deg == bound + 1):
        blocks = np.flatnonzero((bp.meta[:, 2] == r) & bp.is_split)
        assert len(blocks) == 2            # bound + 1 nzs -> two split blocks
    assert np.all(bp.is_split[bp.meta[:, 0] <= bound] == False)  # noqa: E712

    # parity through pack_slabs and BOTH kernel backends vs dense oracle
    slabs = pack_slabs(g, bp)
    x = jnp.asarray(rng.normal(size=(g.n_cols, 8)), jnp.float32)
    ref = g.to_dense() @ np.asarray(x)
    out_blocked = spmm_blocked(
        jnp.asarray(slabs["colidx"]), jnp.asarray(slabs["values"]),
        jnp.asarray(slabs["rowloc"]), jnp.asarray(slabs["out_row"]),
        x, g.n_rows)
    np.testing.assert_allclose(np.asarray(out_blocked), ref,
                               atol=1e-4, rtol=1e-4)
    jslabs = {k: (jnp.asarray(v) if isinstance(v, np.ndarray) else v)
              for k, v in slabs.items()}
    out_pallas = spmm_pallas(jslabs, x, g.n_rows, interpret=True)
    np.testing.assert_allclose(np.asarray(out_pallas), ref,
                               atol=1e-4, rtol=1e-4)


def test_pack_slabs_every_nz_exactly_once():
    g = _graph(500, 7)
    pats = get_partition_patterns(32, 8, mode="tpu")
    bp = block_level_partition(g, pats)
    slabs = pack_slabs(g, bp)
    assert float(slabs["values"].sum()) == pytest.approx(float(g.values.sum()), rel=1e-5)
    # padded slots must carry zero values
    nnzs = bp.nnz_blk
    for b in range(min(bp.num_blocks, 50)):
        assert np.all(slabs["values"][b, nnzs[b]:] == 0)


# ---------------------------------------------------------------------------
# warp_nzs overrides (the autotuner's candidate axis): any ADMISSIBLE
# table yields bit-identical SpMM output on both kernel backends, and
# inadmissible tables are rejected up front
# ---------------------------------------------------------------------------
def _int_graph(n, seed):
    """Small-integer-valued graph: SpMM sums are exactly representable in
    float32, so different block partitions must agree BIT-identically."""
    rng = np.random.default_rng(seed)
    deg = np.minimum(rng.zipf(1.6, n), 200)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, len(src))
    vals = rng.integers(1, 4, len(src)).astype(np.float32)
    return degree_sort_csr(csr_from_edges(src, dst, n, values=vals))


def _random_admissible_override(mbw, mwn, seed):
    rng = np.random.default_rng(seed)
    lo = np.maximum(1, -(-np.arange(1, mbw * mwn + 1) // mbw))  # ceil(d/mbw)
    return rng.integers(lo, mwn + 1)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(30, 250), seed=st.integers(0, 10_000),
       mode=st.sampled_from(["paper", "tpu"]),
       dims=st.sampled_from([(4, 4), (8, 2), (4, 8)]))
def test_admissible_override_bit_identical_on_both_backends(n, seed, mode,
                                                            dims):
    import jax.numpy as jnp
    from repro.kernels.ops import spmm_blocked, spmm_pallas

    mbw, mwn = dims
    g = _int_graph(n, seed)
    override = _random_admissible_override(mbw, mwn, seed + 1)
    rng = np.random.default_rng(seed + 2)
    x = jnp.asarray(rng.integers(-2, 3, (g.n_cols, 6)), jnp.float32)
    ref = (g.to_dense().astype(np.float64)
           @ np.asarray(x, np.float64)).astype(np.float32)

    for ovr in (None, override):
        pats = get_partition_patterns(mbw, mwn, mode=mode,
                                      warp_nzs_override=ovr)
        bp = block_level_partition(g, pats)
        slabs = pack_slabs(g, bp)
        out_b = spmm_blocked(
            jnp.asarray(slabs["colidx"]), jnp.asarray(slabs["values"]),
            jnp.asarray(slabs["rowloc"]), jnp.asarray(slabs["out_row"]),
            x, g.n_rows)
        np.testing.assert_array_equal(np.asarray(out_b), ref)
        jslabs = {k: (jnp.asarray(v) if isinstance(v, np.ndarray) else v)
                  for k, v in slabs.items()}
        out_p = spmm_pallas(jslabs, x, g.n_rows, interpret=True)
        np.testing.assert_array_equal(np.asarray(out_p), ref)


@pytest.mark.parametrize("mode", ["paper", "tpu"])
def test_override_of_all_max_warp_nzs_is_the_default_table(mode):
    mbw, mwn = 8, 4
    default = get_partition_patterns(mbw, mwn, mode=mode)
    same = get_partition_patterns(
        mbw, mwn, mode=mode,
        warp_nzs_override=np.full(mbw * mwn, mwn))
    for field in ("factor", "block_rows", "warp_nzs"):
        np.testing.assert_array_equal(getattr(default, field),
                                      getattr(same, field))


def test_inadmissible_overrides_rejected():
    mbw, mwn = 4, 8
    bound = mbw * mwn
    ok = np.full(bound, mwn)
    validate_warp_nzs_override(mbw, mwn, ok)            # sanity: passes
    bad_low = ok.copy()
    bad_low[0] = 0                                       # below 1
    with pytest.raises(ValueError, match="degree"):
        validate_warp_nzs_override(mbw, mwn, bad_low)
    bad_high = ok.copy()
    bad_high[3] = mwn + 1                                # above max_warp_nzs
    with pytest.raises(ValueError, match="degree"):
        validate_warp_nzs_override(mbw, mwn, bad_high)
    bad_cover = ok.copy()
    bad_cover[bound - 1] = mwn - 1      # mbw * (mwn-1) < bound: row uncovered
    with pytest.raises(ValueError, match="degree"):
        validate_warp_nzs_override(mbw, mwn, bad_cover)
    with pytest.raises(ValueError, match="length"):
        validate_warp_nzs_override(mbw, mwn, ok[:-1])
    with pytest.raises(ValueError, match="integer"):
        validate_warp_nzs_override(mbw, mwn, ok.astype(np.float32) + 0.5)
    # the same guard fires through the pattern-builder entry point
    with pytest.raises(ValueError):
        get_partition_patterns(mbw, mwn, warp_nzs_override=bad_cover)
