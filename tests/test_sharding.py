"""Sharding rules: divisibility fallback, cache specs, param specs."""
import types

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import _leaf_spec, resolve_spec


def fake_mesh(data=16, model=16, pod=None):
    shape = ((pod,) if pod else ()) + (data, model)
    names = (("pod",) if pod else ()) + ("data", "model")
    return types.SimpleNamespace(axis_names=names, devices=np.zeros(shape))


def test_resolve_divisible():
    m = fake_mesh()
    assert resolve_spec((64, 4096), ("data", "model"), m) == P("data", "model")


def test_resolve_fallback_drops_nondividing_axis():
    m = fake_mesh()
    # 40 heads on a 16-way axis -> replicated, head_dim stays sharded
    assert resolve_spec((64, 4096, 40, 128), (("data",), None, "model", None), m) \
        == P(("data",), None, None, None)
    assert resolve_spec((64, 4096, 40, 128), (None, None, None, "model"), m) \
        == P(None, None, None, "model")


def test_batch_axes_multipod():
    m = fake_mesh(pod=2)
    assert resolve_spec((256, 10), (("pod", "data"), None), m) == \
        P(("pod", "data"), None)
    # batch=1 cannot shard: falls back to replicated
    assert resolve_spec((1, 10), (("pod", "data"), None), m) == P(None, None)


def test_leaf_spec_rules():
    m = fake_mesh()
    # col-parallel weight (leading layer-stack dim replicated)
    assert _leaf_spec("layers.attn.wq", (32, 4096, 4096), m) == \
        P(None, "data", "model")
    assert _leaf_spec("layers.attn.wo", (32, 4096, 4096), m) == \
        P(None, "model", "data")
    # expert-parallel MoE weights
    assert _leaf_spec("layers.moe.wi", (40, 16, 6144, 10752), m) == \
        P(None, "model", "data", None)
    # norms replicate
    assert _leaf_spec("layers.ln1.w", (32, 4096), m) == P()
    # embedding: vocab on model, d_model FSDP
    assert _leaf_spec("embed", (152064, 5120), m) == P("model", "data")


def test_leaf_spec_divisibility_guard():
    m = fake_mesh()
    # vocab 504 (hubert) does not divide 16 -> replicated on that dim
    spec = _leaf_spec("head", (1280, 504), m)
    assert spec == P("data", None)
