"""PlacementDirectory semantics: cross-process determinism, epoch
invalidation, stale-host eviction, load-aware override, spread."""
import pytest

from repro.core.plan_cache import PartitionConfig
from repro.distributed.directory import (
    HostInfo, Placement, PlacementDirectory,
)


def _hosts(n=2, devs=4, epochs=None):
    epochs = epochs or [0] * n
    return [HostInfo(p, devs, epochs[p]) for p in range(n)]


def _keys(n, cfg=None):
    cfg = cfg or PartitionConfig()
    return [(f"graph-{i:04d}", cfg) for i in range(n)]


def test_placement_deterministic_across_processes():
    """Two directories built from the same host table (two processes, no
    coordination) must agree on every pure-hash placement — this is what
    makes the directory distributable without a directory server."""
    a = PlacementDirectory(_hosts(), load_spread=10_000)  # overrides off
    b = PlacementDirectory(_hosts(), load_spread=10_000)
    keys = _keys(300)
    pa = [a.place(k) for k in keys]
    # process B sees the keys in a DIFFERENT order — placements must agree
    pb = {k: b.place(k) for k in reversed(keys)}
    for k, p in zip(keys, pa):
        assert pb[k] == p
    # and placements are sticky
    assert [a.place(k) for k in keys] == pa


def test_placements_spread_over_hosts_and_devices():
    d = PlacementDirectory(_hosts(n=2, devs=4))
    pls = [d.place(k) for k in _keys(200)]
    assert {p.host for p in pls} == {0, 1}
    assert {(p.host, p.device) for p in pls} == set(d.slots())
    st = d.stats()
    assert st["hosts"] == 2 and st["slots"] == 8
    assert all(c >= 1 for c in st["host_placements"])
    counts = d.host_placement_counts()
    assert counts[0] + counts[1] == 200


def test_epoch_invalidation_on_host_restart():
    """A host re-announcing with a newer epoch lost its plan cache: every
    entry stamped with its old epoch must be invalidated and re-place."""
    d = PlacementDirectory(_hosts(n=2, devs=2), load_spread=10_000)
    keys = _keys(80)
    before = {k: d.place(k) for k in keys}
    owned_by_1 = [k for k, p in before.items() if p.host == 1]
    assert owned_by_1, "need at least one key on host 1"
    n_inv = d.update_host(HostInfo(1, 2, epoch=7))
    assert n_inv == len(owned_by_1)
    assert d.epoch_invalidations == len(owned_by_1)
    # stale entries gone from lookup; place() re-places with the new epoch
    for k in owned_by_1:
        assert d.lookup(k) is None
        again = d.place(k)
        # same host table, same ring -> the hash sends it back to host 1,
        # now stamped with the CURRENT epoch
        assert again.host == before[k].host
        assert again.device == before[k].device
        assert again.epoch == 7
    # host 0's entries were untouched
    for k, p in before.items():
        if p.host == 0:
            assert d.lookup(k) == p
    # re-announcing the SAME epoch invalidates nothing
    assert d.update_host(HostInfo(1, 2, epoch=7)) == 0


def test_device_count_correction_invalidates_dangling_slots():
    """Same epoch but a corrected (smaller) device count — the default
    directory guessed a homogeneous fleet, the handshake learned the
    truth — must invalidate entries pointing past the real slot table
    (they would dangle outside the ring AND the load accounting)."""
    d = PlacementDirectory(_hosts(n=2, devs=4), load_spread=10_000)
    keys = _keys(120)
    before = {k: d.place(k) for k in keys}
    dangling = [k for k, p in before.items() if p.host == 1 and p.device >= 2]
    surviving = {k: p for k, p in before.items()
                 if not (p.host == 1 and p.device >= 2)}
    assert dangling, "need placements on host 1 devices 2..3"
    n_inv = d.update_host(HostInfo(1, 2, epoch=0))   # same epoch, fewer devs
    assert n_inv == len(dangling)
    for k in dangling:
        assert d.lookup(k) is None
        p = d.place(k)
        assert (p.host, p.device) in d.slots()
    for k, p in surviving.items():
        assert d.lookup(k) == p
    # every live entry now references a live slot (load accounting intact)
    counts = d._slot_counts_locked()
    assert sum(counts) == len(d._entries)


def test_stale_host_eviction_moves_only_its_keys():
    d = PlacementDirectory(_hosts(n=3, devs=2), load_spread=10_000)
    keys = _keys(120)
    before = {k: d.place(k) for k in keys}
    dead = [k for k, p in before.items() if p.host == 2]
    survivors = {k: p for k, p in before.items() if p.host != 2}
    assert dead and survivors
    dropped = d.evict_host(2)
    assert dropped == len(dead)
    assert d.evicted_placements == len(dead)
    for k in dead:
        p = d.place(k)
        assert p.host in (0, 1)
    # consistent hashing: surviving placements did NOT move
    for k, p in survivors.items():
        assert d.place(k) == p
    # evicting an unknown host is a no-op; evicting the last host raises
    assert d.evict_host(9) == 0
    d.evict_host(1)
    with pytest.raises(ValueError):
        d.evict_host(0)


def test_load_aware_override_mirrors_fleet_cache():
    """When the ring's slot is far fuller than the emptiest slot, the key
    goes to the least-loaded slot instead (and sticks there)."""
    d = PlacementDirectory(_hosts(n=2, devs=1), load_spread=2)
    # force-load slot (0, 0) far past the spread via direct entries
    cfg = PartitionConfig()
    for i in range(10):
        d._entries[(f"forced-{i}", cfg)] = Placement(0, 0, 0)
    # while the imbalance exceeds the spread, ring picks of (0, 0) divert
    # to the emptier slot; once the counts converge the ring choice
    # resumes — so overrides fire AND the final counts are balanced
    for i in range(60):
        key = (f"probe-{i:03d}", cfg)
        p = d.place(key)
        assert d.place(key) == p      # sticky
    assert d.placement_overrides > 0
    counts = d._slot_counts_locked()
    assert max(counts) - min(counts) <= d.load_spread + 1


def test_new_host_joins_ring_and_takes_share():
    """Recorded placements are sticky across a join (their plans stay
    where they are); only re-placed/fresh keys see the newcomer's arcs —
    and the ring moves roughly 1/hosts of them, never most."""
    d = PlacementDirectory(_hosts(n=2, devs=2), load_spread=10_000)
    keys = _keys(200)
    before = {k: d.place(k) for k in keys}
    d.update_host(HostInfo(2, 2, epoch=0))
    # stickiness: live entries did not move
    for k in keys:
        assert d.place(k) == before[k]
    # a directory built AFTER the join (what a re-placement would compute):
    # keys either stay put or move to the newcomer, about 1/3 of them
    d3 = PlacementDirectory(_hosts(n=3, devs=2), load_spread=10_000)
    moved = 0
    for k in keys:
        p = d3.place(k)
        if (p.host, p.device) != (before[k].host, before[k].device):
            moved += 1
            assert p.host == 2   # keys only move TO the new host's arcs
    assert 0 < moved < len(keys) // 2
    # fresh keys land on the newcomer too
    fresh = [(f"fresh-{i:03d}", PartitionConfig()) for i in range(100)]
    assert any(d.place(k).host == 2 for k in fresh)


def test_directory_validation():
    with pytest.raises(ValueError):
        PlacementDirectory([])
    with pytest.raises(ValueError):
        PlacementDirectory([HostInfo(0, 2), HostInfo(0, 2)])
    with pytest.raises(ValueError):
        HostInfo(0, 0)
    with pytest.raises(ValueError):
        HostInfo(-1, 2)


# ------------------------------------------------------------- replica sets
def test_replica_add_remove_listing():
    d = PlacementDirectory(_hosts(n=2, devs=2))
    key = _keys(1)[0]
    prim = d.place(key)
    # a slot different from the primary, on the other host
    other = (1 - prim.host, 0)
    ent = d.add_replica(key, *other)
    assert [(p.host, p.device) for p in d.replicas(key)] == \
        [(prim.host, prim.device), other]
    # idempotent: re-adding a live replica (or the primary slot) is a no-op
    assert d.add_replica(key, *other) is ent
    assert d.add_replica(key, prim.host, prim.device) == prim
    assert d.stats()["replicas_added"] == 1
    # dropping the extra leaves the primary untouched
    assert d.remove_replica(key, *other) is True
    assert d.remove_replica(key, *other) is False
    assert d.replicas(key) == [prim]
    with pytest.raises(KeyError):
        d.add_replica(key, 9, 0)
    with pytest.raises(ValueError):
        d.add_replica(key, 0, 5)


def test_removing_primary_slot_promotes_replica():
    d = PlacementDirectory(_hosts(n=2, devs=2))
    key = _keys(1)[0]
    prim = d.place(key)
    other = (1 - prim.host, 1)
    d.add_replica(key, *other)
    assert d.remove_replica(key, prim.host, prim.device) is True
    new = d.lookup(key)
    assert (new.host, new.device) == other, \
        "surviving replica must take over as primary"
    assert d.stats()["replica_promotions"] == 1
    # the promoted entry is now the whole replica set
    assert d.replicas(key) == [new]


def test_epoch_bump_promotes_replica_on_other_host():
    """A restarted primary host loses its plan cache; the directory must
    hand the key to the replica on the surviving host rather than
    re-placing from scratch."""
    d = PlacementDirectory(_hosts(n=2, devs=2))
    key = _keys(1)[0]
    prim = d.place(key)
    other_host = 1 - prim.host
    d.add_replica(key, other_host, 0)
    assert d.update_host(HostInfo(prim.host, 2, epoch=7)) == 1
    new = d.lookup(key)
    assert (new.host, new.device) == (other_host, 0)
    st = d.stats()
    assert st["replica_promotions"] == 1
    assert st["epoch_invalidations"] == 1


def test_evict_host_promotes_surviving_replicas():
    d = PlacementDirectory(_hosts(n=2, devs=2))
    keys = _keys(40)
    replicated = []
    for k in keys:
        p = d.place(k)
        if p.host == 0:
            d.add_replica(k, 1, 0)
            replicated.append(k)
    assert replicated, "hash spread should place some keys on host 0"
    dropped = d.evict_host(0)
    # every host-0 key had a replica on host 1 -> nothing actually dropped
    assert dropped == 0
    for k in replicated:
        ent = d.lookup(k)
        assert ent is not None and ent.host == 1
    st = d.stats()
    assert st["replica_promotions"] == len(replicated)
    # an eviction also scrubs replicas that lived on the dead host
    assert st["replica_entries"] == 0
