"""Cross-host fleet serving, end to end: REAL multi-process JAX.

The worker below runs once per rank under :func:`run_cpu_fleet` (two
subprocesses x 4 fake CPU devices, ``jax.distributed`` rendezvous, gloo
collectives), mirroring the 8-device subprocess check in
``tests/test_fleet.py`` one level up:

* both ranks register the same graphs; the placement directory assigns
  each plan to exactly one (host, device) and only the owner builds it;
* BOTH ranks submit requests for EVERY graph concurrently — each forwards
  the groups the other owns while answering the other's forwards over the
  peer data plane (the mutual pattern that deadlocks if forwarded work
  queues behind the single flush worker) — and each checks output parity
  against a single-host engine;
* both ranks then enter the COLLECTIVE ``serve_global`` dispatch of one
  giant graph: blocks round-robin over all 8 global devices, the psum
  crosses processes, every rank checks parity locally.
"""
import os
import textwrap

from repro.distributed.multihost import run_cpu_fleet

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

_WORKER = textwrap.dedent("""
    import json, os, sys, threading
    sys.path.insert(0, "src")
    import numpy as np
    from repro.distributed.multihost import initialize_multihost
    ctx = initialize_multihost()            # env-driven (REPRO_MH_*)
    import jax, jax.numpy as jnp
    from jax.experimental import multihost_utils
    from repro.core.graph import gcn_normalize
    from repro.core.plan_cache import build_partition_plan
    from repro.data.graphs import make_power_law_graph
    from repro.kernels.ops import spmm_blocked
    from repro.serve.fleet import MultihostGraphEngine
    from repro.serve.graph_engine import GraphRequest, GraphServeEngine

    assert ctx.process_count == 2 and len(jax.devices()) == 8
    engine = MultihostGraphEngine(context=ctx, backend="blocked",
                                  max_graphs_per_batch=4)
    # phase gate over the DATA PLANE: a host parked inside a collective
    # cannot serve forwarded dispatches (its device queue is occupied), so
    # "rank 0 finished serving" travels as a peer-server op, not a barrier
    served_evt = threading.Event()
    engine.server.register("phase-served", lambda _p: served_evt.set())
    engine.connect_peers()

    # identical registration on both ranks (deterministic content)
    rng = np.random.default_rng(0)
    graphs, feats, owned = {}, {}, 0
    for i in range(6):
        gid = f"g{i}"
        g = gcn_normalize(make_power_law_graph(140 + 35 * i, 900 + 70 * i,
                                               seed=i))
        graphs[gid] = g
        plan = engine.register_graph(gid, g)
        owned += int(plan is not None)
        feats[gid] = jnp.asarray(rng.normal(size=(g.n_cols, 8 + 4 * i)),
                                 jnp.float32)
    multihost_utils.sync_global_devices("registered")

    # BOTH ranks serve every graph CONCURRENTLY: each forwards the groups
    # the other owns, while answering the other's forwards — the mutual-
    # forwarding pattern that deadlocks if forwarded work queues behind
    # the single flush worker instead of executing on the handler thread
    single = GraphServeEngine(backend="blocked")
    for gid, g in graphs.items():
        single.register_graph(gid, g)
    mh = engine.serve([GraphRequest(g, feats[g]) for g in graphs])
    ref = single.serve([GraphRequest(g, feats[g]) for g in graphs])
    single.close()
    max_err = 0.0
    for a, b in zip(mh, ref):
        max_err = max(max_err, float(np.max(np.abs(
            np.asarray(a.out) - np.asarray(b.out)))))
    assert max_err < 1e-4, f"forwarding parity broke: {max_err}"
    peer = engine.peers[1 - ctx.process_index]
    peer.request("phase-served", None)
    assert served_evt.wait(300), "peer never finished serving"

    # COLLECTIVE phase: both ranks dispatch the giant over the global mesh
    big = gcn_normalize(make_power_law_graph(6000, 30000, seed=9))
    engine.register_graph("big", big)
    xb = jnp.asarray(np.random.default_rng(2).normal(
        size=(big.n_cols, 16)), jnp.float32)
    out = engine.serve_global("big", xb)
    plan = build_partition_plan(big, engine.config)
    ref = spmm_blocked(plan.slabs["colidx"], plan.slabs["values"],
                       plan.slabs["rowloc"], plan.slabs["out_row"],
                       xb, plan.n_rows)[plan.inv_perm]
    g_err = float(np.max(np.abs(np.asarray(out) - np.asarray(ref))))
    assert g_err < 1e-3, f"global block-shard parity broke: {g_err}"
    multihost_utils.sync_global_devices("global-done")

    st = engine.stats()
    engine.close()
    print(json.dumps({
        "rank": ctx.process_index,
        "hosts": st["fleet_hosts"],
        "owned_plans": owned,
        "cache_size": st["cache_size"],
        "forwarded": st["fleet_forwarded"],
        "remote_served": st["fleet_remote_served"],
        "host_placements": st["fleet_dir_host_placements"],
        "global_dispatches": st["fleet_global_dispatches"],
        "block_counts": st["fleet_block_counts"],
        "max_err": max_err,
        "global_err": g_err,
        "failovers": st["fleet_host_failovers"],
        "sched_invariant": (st["sched_completed"] + st["sched_failed"]
                            + st["sched_cancelled"]
                            == st["sched_submitted"]),
    }))
""")


def test_two_host_fleet_end_to_end():
    """Acceptance: a two-subprocess fleet serves registered graphs with
    output parity vs the single-host engine, the directory spreads plans
    across both hosts (each owns >= 1), and the collective global-mesh
    dispatch agrees with the single-host kernel."""
    records = run_cpu_fleet(_WORKER, num_processes=2, n_local_devices=4,
                            timeout_s=560, cwd=_REPO_ROOT)
    assert len(records) == 2
    r0, r1 = sorted(records, key=lambda r: r["rank"])
    for r in (r0, r1):
        assert r["hosts"] == 2
        # acceptance: each host owns at least one plan, and owns exactly
        # what its local cache shard actually holds
        assert r["owned_plans"] >= 1
        assert r["cache_size"] >= r["owned_plans"]
        assert r["failovers"] == 0
        assert r["sched_invariant"]
        # the directory's view: both hosts carry placements
        assert len(r["host_placements"]) == 2
        assert all(c >= 1 for c in r["host_placements"])
    # BOTH ranks forwarded the groups the other owns AND answered the
    # other's forwards (the mutual pattern), with parity on both sides
    for r in (r0, r1):
        assert r["forwarded"] >= 1
        assert r["remote_served"] >= 1
        assert r["max_err"] < 1e-4
    # the collective dispatch ran on BOTH ranks and spanned all 8 devices
    for r in (r0, r1):
        assert r["global_dispatches"] == 1
        assert len(r["block_counts"]) == 8
        assert max(r["block_counts"]) - min(r["block_counts"]) <= 1
        assert r["global_err"] < 1e-3
