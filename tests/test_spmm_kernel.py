"""Pallas SpMM kernel: shape/dtype sweep + hypothesis graphs vs ref oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import degree_sort_csr, gcn_normalize
from repro.core.partition import (block_level_partition, get_partition_patterns,
                                  pack_slabs)
from repro.kernels.ref import csr_spmm_ref
from repro.kernels.spmm_accel import spmm_block_slabs
from conftest import make_powerlaw_csr


def _run(g, X, mode="tpu", mbw=32, mwn=8, kernel=None):
    gs = degree_sort_csr(g)
    pats = get_partition_patterns(mbw, mwn, mode=mode)
    bp = block_level_partition(gs, pats)
    slabs = pack_slabs(gs, bp)
    kern = kernel or spmm_block_slabs
    out_sorted = kern(
        jnp.asarray(slabs["colidx"]), jnp.asarray(slabs["values"]),
        jnp.asarray(slabs["rowloc"]), jnp.asarray(slabs["out_row"]),
        jnp.asarray(X), gs.n_rows, interpret=True)
    out = np.empty_like(np.asarray(out_sorted))
    out[gs.perm] = np.asarray(out_sorted)
    return out


@pytest.mark.parametrize("F", [1, 16, 32, 96, 128, 200, 256])
def test_feature_dims_sweep(F):
    """Paper Fig. 6 regime: column dims 16..128 (+ ragged edges)."""
    g = gcn_normalize(make_powerlaw_csr(n=150, seed=0))
    X = np.random.default_rng(0).normal(size=(150, F)).astype(np.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, jnp.asarray(X)))
    out = _run(g, X)
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-4), (jnp.bfloat16, 5e-2)])
def test_dtypes(dtype, atol):
    g = gcn_normalize(make_powerlaw_csr(n=100, seed=2))
    X = (np.random.default_rng(1).normal(size=(100, 64)) * 0.5)
    Xj = jnp.asarray(X.astype(np.float32)).astype(dtype)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values,
                                  Xj.astype(jnp.float32)))
    out = _run(g, np.asarray(Xj.astype(jnp.float32)))
    np.testing.assert_allclose(out, ref, atol=atol, rtol=1e-2)


@pytest.mark.parametrize("mode,mbw,mwn", [("paper", 12, 32), ("paper", 4, 8),
                                          ("tpu", 64, 4), ("tpu", 16, 16)])
def test_partition_configs(mode, mbw, mwn):
    g = gcn_normalize(make_powerlaw_csr(n=220, seed=3, zipf=1.4))
    X = np.random.default_rng(2).normal(size=(220, 48)).astype(np.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, jnp.asarray(X)))
    out = _run(g, X, mode=mode, mbw=mbw, mwn=mwn)
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(5, 250), seed=st.integers(0, 10_000),
       zipf=st.sampled_from([1.3, 1.8, 2.5]), F=st.integers(1, 80))
def test_hypothesis_random_graphs(n, seed, zipf, F):
    g = gcn_normalize(make_powerlaw_csr(n=n, seed=seed, zipf=zipf))
    X = np.random.default_rng(seed).normal(size=(n, F)).astype(np.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, jnp.asarray(X)))
    out = _run(g, X)
    np.testing.assert_allclose(out, ref, atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("F", [32, 96, 128, 200])
def test_hbm_gather_variant(F):
    """HBM-resident X kernel (double-buffered DMA gather) vs oracle."""
    from repro.kernels.spmm_hbm import spmm_block_slabs_hbm
    g = gcn_normalize(make_powerlaw_csr(n=140, seed=4))
    X = np.random.default_rng(0).normal(size=(140, F)).astype(np.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, jnp.asarray(X)))
    out = _run(g, X, kernel=spmm_block_slabs_hbm)
    np.testing.assert_allclose(out, ref, atol=2e-4, rtol=1e-4)


def test_hbm_matches_resident_kernel():
    from repro.kernels.spmm_hbm import spmm_block_slabs_hbm
    g = gcn_normalize(make_powerlaw_csr(n=120, seed=5, zipf=1.4))
    X = np.random.default_rng(1).normal(size=(120, 64)).astype(np.float32)
    a = _run(g, X)
    b = _run(g, X, kernel=spmm_block_slabs_hbm)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_empty_rows_and_rectangular():
    # rows with zero degree + rectangular (n_rows != n_cols)
    from repro.core.graph import csr_from_edges
    src = np.array([0, 0, 3, 3, 3, 3])
    dst = np.array([1, 4, 0, 1, 2, 4])
    g = csr_from_edges(src, dst, 4)
    g = type(g)(g.rowptr, g.colidx, g.values, 5)  # 4 x 5, rows 1,2 empty
    X = np.random.default_rng(3).normal(size=(5, 40)).astype(np.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, jnp.asarray(X)))
    out = _run(g, X)
    np.testing.assert_allclose(out, ref, atol=2e-4)
    assert np.all(out[1] == 0) and np.all(out[2] == 0)
