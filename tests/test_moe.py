"""MoE dispatch paths: capacity vs block (paper technique) vs dropless loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import apply_mlp
from repro.models.moe import init_moe, moe_block, moe_capacity


def _ref_dropless(p, x, k, D):
    xt = np.asarray(x.reshape(-1, D), np.float32)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ids = np.argsort(-probs, -1)[:, :k]
    w = np.take_along_axis(probs, ids, -1)
    w = w / w.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(k):
            e = int(ids[t, j])
            h = xt[t] @ np.asarray(p["wi"][e], np.float32)
            g = xt[t] @ np.asarray(p["wg"][e], np.float32)
            h = (g / (1 + np.exp(-g))) * h
            out[t] += w[t, j] * (h @ np.asarray(p["wo"][e], np.float32))
    if "shared" in p:
        out += np.asarray(apply_mlp(p["shared"], jnp.asarray(xt)), np.float32)
    return out.reshape(x.shape)


@pytest.mark.parametrize("E,k,shared", [(8, 2, 0), (8, 2, 1), (4, 1, 0),
                                        (16, 4, 2)])
def test_dispatch_paths_agree(E, k, shared):
    B, T, D, FF = 2, 24, 16, 32
    p = init_moe(jax.random.PRNGKey(E + k), D, FF, E, n_shared=shared,
                 dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
    ref = _ref_dropless(p, x, k, D)
    y_cap, _ = moe_capacity(p, x, top_k=k, n_experts=E, capacity_factor=8.0)
    y_blk, _ = moe_block(p, x, top_k=k, n_experts=E, m_tile=8, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_cap), ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y_blk), ref, atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens_when_tight():
    B, T, D, FF, E, k = 2, 32, 8, 16, 4, 2
    p = init_moe(jax.random.PRNGKey(0), D, FF, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, D))
    y_tight, _ = moe_capacity(p, x, top_k=k, n_experts=E, capacity_factor=0.25)
    y_loose, _ = moe_capacity(p, x, top_k=k, n_experts=E, capacity_factor=8.0)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose))


def test_block_dispatch_is_dropless_and_balanced():
    """The paper-technique path: every block has identical FLOPs and no
    token is dropped regardless of routing skew."""
    B, T, D, FF, E, k = 1, 64, 8, 16, 4, 1
    p = init_moe(jax.random.PRNGKey(3), D, FF, E, dtype=jnp.float32)
    # force extreme skew: bias router to expert 0
    p["router"] = p["router"] + jnp.asarray([10.0, 0, 0, 0])
    x = jax.random.normal(jax.random.PRNGKey(4), (B, T, D))
    ref = _ref_dropless(p, x, k, D)
    y, _ = moe_block(p, x, top_k=k, n_experts=E, m_tile=8, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4, rtol=1e-4)


def test_aux_loss_sensitivity():
    B, T, D, FF, E, k = 2, 64, 8, 16, 4, 1
    p = init_moe(jax.random.PRNGKey(5), D, FF, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (B, T, D))
    _, aux_bal = moe_capacity(p, x, top_k=k, n_experts=E)
    p2 = dict(p)
    p2["router"] = p["router"] + jnp.asarray([50.0, 0, 0, 0])
    _, aux_skew = moe_capacity(p2, x, top_k=k, n_experts=E)
    assert float(aux_skew) > float(aux_bal)


def test_grouped_dispatch_equivalence():
    """DISPATCH_GROUPS (the §Perf lever) is numerically identical to the
    single-group path when capacity is ample (needs >=64 tokens/group)."""
    import repro.models.moe as MO
    B, T, D, FF, E, k = 2, 256, 16, 32, 4, 2
    p = init_moe(jax.random.PRNGKey(9), D, FF, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(10), (B, T, D))
    y1, a1 = moe_capacity(p, x, top_k=k, n_experts=E, capacity_factor=8.0)
    MO.DISPATCH_GROUPS = 4
    try:
        y2, a2 = moe_capacity(p, x, top_k=k, n_experts=E, capacity_factor=8.0)
    finally:
        MO.DISPATCH_GROUPS = 1
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert float(a1) == float(a2)


def test_grouped_dispatch_guard_small_batches():
    """Decode-sized token counts keep the single-group path (measured 1.7x
    collective regression otherwise; EXPERIMENTS.md addendum)."""
    import repro.models.moe as MO
    B, T, D, FF, E, k = 2, 16, 8, 16, 4, 1   # 32 tokens < 64*G
    p = init_moe(jax.random.PRNGKey(11), D, FF, E, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(12), (B, T, D))
    y1, _ = moe_capacity(p, x, top_k=k, n_experts=E, capacity_factor=8.0)
    MO.DISPATCH_GROUPS = 4
    try:
        y2, _ = moe_capacity(p, x, top_k=k, n_experts=E, capacity_factor=8.0)
    finally:
        MO.DISPATCH_GROUPS = 1
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
