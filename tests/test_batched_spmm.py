"""Batched multi-graph SpMM == per-graph SpMM, including nasty edge cases."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import csr_from_edges, gcn_normalize
from repro.core.plan_cache import PartitionConfig, build_partition_plan
from repro.kernels.ref import csr_spmm_ref
from repro.kernels.spmm_accel import spmm_block_slabs
from repro.kernels.spmm_batched import batch_graph_slabs, bucket_blocks, spmm_batched

from conftest import make_powerlaw_csr, make_wide_csr


def _plan_x(g, cfg, F, seed):
    plan = build_partition_plan(g, cfg)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(g.n_rows, F)),
                    dtype=jnp.float32)
    return plan, x


def _check_parity(plans, xs, backend, **kw):
    outs = spmm_batched([p.slabs for p in plans], xs,
                        [p.n_rows for p in plans], backend=backend, **kw)
    assert len(outs) == len(plans)
    for p, x, out in zip(plans, xs, outs):
        ref = spmm_block_slabs(p.slabs["colidx"], p.slabs["values"],
                               p.slabs["rowloc"], p.slabs["out_row"],
                               x, p.n_rows)
        assert out.shape == (p.n_rows, x.shape[1])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["pallas", "blocked"])
def test_batched_matches_individual(backend):
    cfg = PartitionConfig()
    plans, xs = [], []
    for i, (n, F) in enumerate([(150, 32), (90, 64), (220, 16)]):
        g = gcn_normalize(make_powerlaw_csr(n=n, seed=i, zipf=1.8))
        p, x = _plan_x(g, cfg, F, seed=i)
        plans.append(p)
        xs.append(x)
    _check_parity(plans, xs, backend)


def test_batched_single_graph_degenerate():
    cfg = PartitionConfig()
    g = gcn_normalize(make_powerlaw_csr(n=77, seed=4))
    p, x = _plan_x(g, cfg, 40, seed=4)
    _check_parity([p], [x], "blocked")


@pytest.mark.slow
def test_batched_mixed_partition_configs():
    """Graphs partitioned under different configs (different C, R) pad to a
    common capacity and still agree with their own single-graph runs."""
    cfgs = [PartitionConfig(),                                     # C=256
            PartitionConfig(max_block_warps=8, max_warp_nzs=4),    # C=32
            PartitionConfig(mode="paper", max_block_warps=12,
                            max_warp_nzs=8)]                       # C=96
    plans, xs = [], []
    for i, cfg in enumerate(cfgs):
        g = gcn_normalize(make_powerlaw_csr(n=100 + 30 * i, seed=i))
        p, x = _plan_x(g, cfg, 24, seed=10 + i)
        plans.append(p)
        xs.append(x)
    assert len({p.slabs["C"] for p in plans}) > 1, "test needs mixed C"
    _check_parity(plans, xs, "pallas")


def test_batched_zero_degree_rows():
    """Rows with no non-zeros must come back exactly zero, per graph."""
    # graph 0: rows 0,2,4.. empty; graph 1: dense-ish power law
    src = np.array([1, 1, 3, 5, 5, 5], dtype=np.int64)
    dst = np.array([0, 2, 1, 4, 5, 0], dtype=np.int64)
    g0 = csr_from_edges(src, dst, 7)
    g1 = gcn_normalize(make_powerlaw_csr(n=60, seed=3))
    cfg = PartitionConfig(max_block_warps=8, max_warp_nzs=4)
    p0, x0 = _plan_x(g0, cfg, 8, seed=0)
    p1, x1 = _plan_x(g1, cfg, 8, seed=1)
    _check_parity([p0, p1], [x0, x1], "blocked")
    outs = spmm_batched([p0.slabs, p1.slabs], [x0, x1],
                        [p0.n_rows, p1.n_rows], backend="blocked")
    # zero-degree rows of g0 are zero in DEGREE-SORTED order: empty rows sort
    # first, and g0 has 4 of them (0, 2, 4, 6)
    np.testing.assert_array_equal(np.asarray(outs[0][:4]), 0.0)


@pytest.mark.slow
def test_batched_split_rows_degree_exceeds_capacity():
    """Rows with degree > C split across blocks; cross-block accumulation in
    the fused epilogue must not leak between graphs."""
    cfg = PartitionConfig(max_block_warps=4, max_warp_nzs=4)  # C = 16
    plans, xs, graphs = [], [], []
    for i in range(2):
        g = gcn_normalize(make_powerlaw_csr(n=50, seed=20 + i, zipf=1.3))
        assert (np.diff(g.rowptr) >= 16).any(), "need at least one split row"
        p, x = _plan_x(g, cfg, 12, seed=20 + i)
        plans.append(p)
        xs.append(x)
        graphs.append(g)
    _check_parity(plans, xs, "pallas")
    # also against the layout-free oracle (un-permute to ORIGINAL row order)
    outs = spmm_batched([p.slabs for p in plans], xs,
                        [p.n_rows for p in plans], backend="pallas")
    for g, p, x, out in zip(graphs, plans, xs, outs):
        ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values,
                                      np.asarray(x)))
        np.testing.assert_allclose(np.asarray(out[p.inv_perm]), ref,
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("min_bucket", [64, 256])
def test_block_bucketing_parity(min_bucket):
    cfg = PartitionConfig()
    plans, xs = [], []
    for i in range(3):
        g = gcn_normalize(make_powerlaw_csr(n=80 + 40 * i, seed=30 + i))
        p, x = _plan_x(g, cfg, 16, seed=30 + i)
        plans.append(p)
        xs.append(x)
    b_total = sum(p.num_blocks for p in plans)
    bucket = bucket_blocks(b_total, min_bucket)
    assert bucket >= b_total and bucket >= min_bucket
    _check_parity(plans, xs, "blocked", pad_blocks_to=bucket)


def test_bucket_blocks_tiers_bound_padding_waste():
    """Power-of-two tiers from 8: a tiny batch no longer pads to 256
    blocks, and waste stays below 2x for any batch at least one tier big."""
    assert bucket_blocks(3) == 8
    assert bucket_blocks(8) == 8
    assert bucket_blocks(9) == 16
    assert bucket_blocks(100) == 128
    for b in range(8, 2000, 37):
        bucket = bucket_blocks(b)
        assert b <= bucket < 2 * b
    # explicit floors (jit-reuse tuning) still respected
    assert bucket_blocks(3, min_bucket=256) == 256
    assert bucket_blocks(300, min_bucket=64) == 512


@pytest.mark.slow
def test_batched_auto_routes_oversized_mix_to_hbm():
    """One n_cols=20k graph in an otherwise-small batch: auto must pick the
    HBM-gather kernel and still match the per-graph blocked oracle."""
    from repro.kernels.ops import spmm_blocked

    cfg = PartitionConfig()
    graphs = [make_wide_csr(500, 20_000, 1_500, seed=1),
              gcn_normalize(make_powerlaw_csr(n=90, seed=2)),
              gcn_normalize(make_powerlaw_csr(n=130, seed=3))]
    plans = [build_partition_plan(g, cfg) for g in graphs]
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.normal(size=(g.n_cols, 8)), jnp.float32)
          for g in graphs]

    outs, decision = spmm_batched(
        [p.slabs for p in plans], xs, [p.n_rows for p in plans],
        backend="auto", return_decision=True)
    assert decision.backend == "hbm"
    assert decision.n_rows == sum(g.n_cols for g in graphs)
    for p, x, out in zip(plans, xs, outs):
        ref = spmm_blocked(p.slabs["colidx"], p.slabs["values"],
                           p.slabs["rowloc"], p.slabs["out_row"],
                           x, p.n_rows)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_batched_forced_resident_raises_on_oversized_mix():
    from repro.kernels.router import VmemBudgetError

    cfg = PartitionConfig()
    graphs = [make_wide_csr(500, 20_000, 1_500, seed=1),
              gcn_normalize(make_powerlaw_csr(n=90, seed=2))]
    plans = [build_partition_plan(g, cfg) for g in graphs]
    xs = [jnp.zeros((g.n_cols, 8), jnp.float32) for g in graphs]
    with pytest.raises(VmemBudgetError, match="VMEM budget"):
        spmm_batched([p.slabs for p in plans], xs,
                     [p.n_rows for p in plans], backend="pallas")


@pytest.mark.slow
def test_batched_auto_windowed_middle_regime():
    """A batch of individually-resident graphs whose concatenation lands in
    the windowed regime (4096 < N_pad <= 16384)."""
    cfg = PartitionConfig()
    graphs = [make_wide_csr(400, 2_500, 1_200, seed=10 + i)
              for i in range(3)]
    plans = [build_partition_plan(g, cfg) for g in graphs]
    rng = np.random.default_rng(4)
    xs = [jnp.asarray(rng.normal(size=(g.n_cols, 16)), jnp.float32)
          for g in graphs]

    outs, decision = spmm_batched(
        [p.slabs for p in plans], xs, [p.n_rows for p in plans],
        backend="auto", return_decision=True)
    assert decision.backend == "windowed" and decision.num_windows == 2
    _check_parity(plans, xs, "blocked")   # blocked twin agrees per graph
    for p, x, out in zip(plans, xs, outs):
        ref = spmm_block_slabs(p.slabs["colidx"], p.slabs["values"],
                               p.slabs["rowloc"], p.slabs["out_row"],
                               x, p.n_rows)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_batch_graph_slabs_sentinel_remap():
    """Per-graph drop sentinels must map to the single batch sentinel, never
    to another graph's live rows."""
    cfg = PartitionConfig()
    gs = [gcn_normalize(make_powerlaw_csr(n=60 + i * 20, seed=40 + i))
          for i in range(3)]
    plans = [build_partition_plan(g, cfg) for g in gs]
    merged, out_off, col_off, n_out = batch_graph_slabs(
        [p.slabs for p in plans], [p.n_rows for p in plans],
        [p.n_cols for p in plans])
    assert n_out == sum(p.n_rows for p in plans)
    orw = merged["out_row"]
    assert orw.max() == n_out, "batch sentinel present"
    # every non-sentinel out_row of graph i lies inside graph i's row span
    b0 = 0
    for i, p in enumerate(plans):
        span = orw[b0:b0 + p.num_blocks]
        live = span[span != n_out]
        assert live.min() >= out_off[i] and live.max() < out_off[i + 1]
        b0 += p.num_blocks


def test_grid_order_ft_major_matches_block_major():
    """ROADMAP grid-order experiment: iterating (feature-tile, block)
    instead of (block, feature-tile) must be a pure schedule change —
    identical outputs, including with multiple feature tiles (F > 128)."""
    cfg = PartitionConfig()
    gs = [gcn_normalize(make_powerlaw_csr(n=80 + 30 * i, seed=50 + i))
          for i in range(3)]
    plans = [build_partition_plan(g, cfg) for g in gs]
    rng = np.random.default_rng(3)
    xs = [jnp.asarray(rng.normal(size=(p.n_cols, 130 + i)), jnp.float32)
          for i, p in enumerate(plans)]   # F > 128 -> 2 feature tiles
    a = spmm_batched([p.slabs for p in plans], xs,
                     [p.n_rows for p in plans], backend="pallas")
    b = spmm_batched([p.slabs for p in plans], xs,
                     [p.n_rows for p in plans], backend="pallas",
                     grid_order="ft_major")
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=1e-5, rtol=1e-5)


def test_grid_order_validated():
    from repro.kernels.spmm_accel import spmm_block_slabs as kern
    cfg = PartitionConfig()
    p = build_partition_plan(gcn_normalize(make_powerlaw_csr(n=50, seed=1)),
                             cfg)
    x = jnp.ones((p.n_cols, 8), jnp.float32)
    with pytest.raises(ValueError, match="grid_order"):
        kern(p.slabs["colidx"], p.slabs["values"], p.slabs["rowloc"],
             p.slabs["out_row"], x, p.n_rows, grid_order="diagonal")
