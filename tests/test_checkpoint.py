"""Checkpoint manager: atomic publish, keep-k GC, bf16 roundtrip, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": jax.random.normal(k, (3,)).astype(jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip_including_bf16(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t)
    assert mgr.latest_step() == 10
    r = mgr.restore(10, t)
    for a, b in zip(jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(r)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree())
    # simulate a crashed writer: directory without manifest
    os.makedirs(tmp_path / "step_00000009")
    assert mgr.latest_step() == 5


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree())
    with pytest.raises(AssertionError):
        mgr.restore(1, {"only": jnp.zeros(3)})
