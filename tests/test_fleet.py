"""Fleet serving: sharded SpMM dispatch, plan placement, FleetGraphEngine.

These tests adapt to the visible device count: under the plain suite (one
CPU device) every code path still executes through a degenerate 1-device
mesh; under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
matrix entry) the same tests exercise real multi-device semantics. The
subprocess test at the bottom guarantees 8-device coverage even in a plain
local run.
"""
import json
import os
import subprocess
import sys
import textwrap
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import gcn_normalize
from repro.core.plan_cache import PartitionConfig, build_partition_plan
from repro.data.graphs import make_power_law_graph
from repro.distributed import (
    ConsistentHashRing, FleetPlanCache, round_robin_block_order,
    spmm_block_sharded, spmm_feature_sharded,
)
from repro.kernels.ops import spmm_blocked
from repro.kernels.router import route_fleet
from repro.launch.mesh import graph_mesh
from repro.serve.fleet import FleetGraphEngine
from repro.serve.graph_engine import GraphRequest, GraphServeEngine

from conftest import make_powerlaw_csr


def _plan(n=400, e=2600, seed=0):
    g = gcn_normalize(make_power_law_graph(n, e, seed=seed))
    return g, build_partition_plan(g, PartitionConfig())


# --------------------------------------------------------------- placement
@settings(max_examples=60)
@given(num_blocks=st.integers(min_value=0, max_value=500),
       n_devices=st.integers(min_value=1, max_value=16))
def test_round_robin_balanced_within_one_block(num_blocks, n_devices):
    """Property (acceptance): ANY plan's blocks round-robin onto d devices
    land balanced within 1 block, and every block is placed exactly once."""
    order, live = round_robin_block_order(num_blocks, n_devices)
    assert live.sum() == num_blocks
    assert live.max() - live.min() <= 1
    per = len(order) // n_devices
    assert len(order) % n_devices == 0
    # device-major layout: device k's slice holds exactly the blocks
    # congruent to k mod d, in original (degree-sorted) order
    for k in range(n_devices):
        mine = order[k * per:(k + 1) * per]
        live_mine = mine[mine < num_blocks]
        assert np.all(live_mine % n_devices == k)
        assert np.all(np.diff(live_mine) > 0)


def test_consistent_hash_ring_deterministic_and_covering():
    ring = ConsistentHashRing(range(8), vnodes=64)
    keys = [f"graph-{i}" for i in range(400)]
    owners = [ring.lookup(k) for k in keys]
    assert owners == [ring.lookup(k) for k in keys], "lookup must be stable"
    assert set(owners) == set(range(8)), "400 keys should touch all 8 arcs"
    # a second ring with the same members agrees (cross-process placement)
    again = ConsistentHashRing(range(8), vnodes=64)
    assert owners == [again.lookup(k) for k in keys]


def test_fleet_cache_places_each_plan_on_exactly_one_device():
    cache = FleetPlanCache(jax.devices(), capacity_per_device=8)
    cfg = PartitionConfig()
    keys = []
    for i in range(6):
        g = gcn_normalize(make_powerlaw_csr(n=80 + 17 * i, seed=i))
        plan = cache.get_or_build(g, cfg)
        keys.append(plan.key)
        dev_idx = cache.device_index_of(plan.key)
        assert plan.slabs["colidx"].devices() == {cache.devices[dev_idx]}, \
            "plan must be staged on its owning device"
    # resident on exactly one shard, placement sticky across lookups
    for key in keys:
        assert sum(key in s for s in cache.shards) == 1
        assert cache.device_index_of(key) == cache.device_index_of(key)
    st_ = cache.stats()
    assert st_["builds"] == 6 and st_["size"] == 6
    assert sum(st_["shard_sizes"]) == 6


def test_fleet_cache_load_aware_override():
    """When the ring's pick is far fuller than the emptiest shard, the plan
    goes to the least-loaded shard instead (and the placement sticks)."""
    # a 2-shard fleet on one physical device: the placement policy is pure
    # bookkeeping and does not need 2 real devices
    dev = jax.devices()[0]
    cache = FleetPlanCache([dev, dev], capacity_per_device=64, load_spread=2)
    cfg = PartitionConfig()
    # stuff the ring's favorite shard well past the spread
    target = 0
    for i in range(cache.load_spread + 2):
        g = gcn_normalize(make_powerlaw_csr(n=60 + 13 * i, seed=100 + i))
        key = (f"forced-{i}", cfg)
        plan = build_partition_plan(g, cfg)
        plan.key = key
        cache._placements[key] = target
        cache.shards[target].put(plan)
    # now any new key whose ring pick is the overloaded shard gets overridden
    before = cache.placement_overrides
    seen_override = False
    for i in range(40):
        key = (f"probe-{i}", cfg)
        dev = cache.device_index_of(key)
        if cache.ring.lookup(key[0]) == target:
            assert dev != target
            seen_override = True
    assert seen_override and cache.placement_overrides > before


def test_fleet_cache_placements_bounded_under_churn():
    """One-off graph churn must not leak placement entries: past 2x fleet
    capacity, placements of shard-evicted plans are pruned (so a rebuilt
    plan re-places with current load data)."""
    dev = jax.devices()[0]
    cache = FleetPlanCache([dev, dev], capacity_per_device=2)
    cfg = PartitionConfig()
    cap = 2 * cache.capacity_per_device * len(cache.shards)
    for i in range(6 * cap):
        g = gcn_normalize(make_powerlaw_csr(n=40 + i, seed=300 + i))
        cache.get_or_build(g, cfg)
        assert len(cache._placements) <= cap + 1
    # resident plans keep their placements
    for key in cache.keys():
        assert key in cache._placements


def test_fleet_engine_rejects_plain_plan_cache():
    from repro.core.plan_cache import PlanCache
    with pytest.raises(TypeError):
        FleetGraphEngine(cache=PlanCache(4))


# ---------------------------------------------------------------- routing
def test_route_fleet_strategies():
    # small resident dispatch, narrow, few blocks -> single
    fd = route_fleet(500, 16, 64, 32, num_blocks=6, n_devices=8)
    assert fd.strategy == "single" and fd.n_devices == 1
    # resident + narrow stays single even with many blocks: fits one
    # device's VMEM budget, nothing to save by sharding
    fd = route_fleet(3000, 16, 64, 32, num_blocks=169, n_devices=8)
    assert fd.strategy == "single"
    # wide features -> feature sharding with per-device share routed
    fd = route_fleet(500, 8 * 128, 64, 32, num_blocks=6, n_devices=8)
    assert fd.strategy == "feature" and fd.n_devices == 8
    assert fd.per_device.f_pad == 128
    # narrow GIANT graph (single-device estimate demotes off resident),
    # many blocks -> block sharding
    fd = route_fleet(20_000, 16, 64, 32, num_blocks=169, n_devices=8)
    assert fd.strategy == "block"
    assert fd.single.backend != "resident"
    # giant but too few blocks to give each device a share: still single
    fd = route_fleet(20_000, 16, 64, 32, num_blocks=4, n_devices=8)
    assert fd.strategy == "single"
    # one device: always single
    fd = route_fleet(20_000, 8 * 128, 64, 32, num_blocks=169, n_devices=1)
    assert fd.strategy == "single"


def test_route_fleet_multihost():
    """n_hosts > 1 routes over the GLOBAL mesh: block sharding spans hosts
    (psum returns a replicated answer), feature sharding is disabled
    (column-sharded output would pay a cross-host gather per answer)."""
    # narrow giant over 2 hosts x 4 devices: block-shard the global mesh
    fd = route_fleet(20_000, 16, 64, 32, num_blocks=169, n_devices=8,
                     n_hosts=2)
    assert fd.strategy == "block" and fd.n_hosts == 2
    assert fd.n_devices == 8
    assert "host" in fd.describe()
    # wide features, multi-host: NOT feature-sharded — stays single
    fd = route_fleet(500, 8 * 128, 64, 32, num_blocks=6, n_devices=8,
                     n_hosts=2)
    assert fd.strategy == "single"
    # same shape on one host still feature-shards (unchanged behavior)
    fd = route_fleet(500, 8 * 128, 64, 32, num_blocks=6, n_devices=8,
                     n_hosts=1)
    assert fd.strategy == "feature" and fd.n_hosts == 1
    # too few blocks for the global device count: single
    fd = route_fleet(20_000, 16, 64, 32, num_blocks=16, n_devices=8,
                     n_hosts=2)
    assert fd.strategy == "single"
    with pytest.raises(ValueError):
        route_fleet(500, 16, 64, 32, num_blocks=6, n_devices=8, n_hosts=0)


# ------------------------------------------------------- sharded dispatch
def test_feature_sharded_matches_blocked():
    g, plan = _plan()
    rng = np.random.default_rng(0)
    mesh = graph_mesh()
    d = mesh.devices.size
    # a width that does NOT divide the mesh exercises the pad/slice path
    for F in (d * 8, d * 8 + 3, 5):
        x = jnp.asarray(rng.normal(size=(g.n_cols, F)), jnp.float32)
        ref = spmm_blocked(plan.slabs["colidx"], plan.slabs["values"],
                           plan.slabs["rowloc"], plan.slabs["out_row"],
                           x, plan.n_rows)
        out = spmm_feature_sharded(plan.slabs, x, plan.n_rows, mesh)
        assert out.shape == (plan.n_rows, F)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_block_sharded_matches_blocked_and_reports_balance():
    g, plan = _plan(n=900, e=6000, seed=2)
    rng = np.random.default_rng(1)
    mesh = graph_mesh()
    x = jnp.asarray(rng.normal(size=(g.n_cols, 24)), jnp.float32)
    ref = spmm_blocked(plan.slabs["colidx"], plan.slabs["values"],
                       plan.slabs["rowloc"], plan.slabs["out_row"],
                       x, plan.n_rows)
    out, live = spmm_block_sharded(plan.slabs, x, plan.n_rows, mesh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert live.sum() == plan.num_blocks
    assert live.max() - live.min() <= 1


# ------------------------------------------------------------- fleet engine
def _mixed_traffic_engines(n_graphs=5, feat=16):
    fleet = FleetGraphEngine(backend="blocked", max_graphs_per_batch=4)
    single = GraphServeEngine(backend="blocked", max_graphs_per_batch=4)
    feats = {}
    rng = np.random.default_rng(0)
    for i in range(n_graphs):
        gid = f"g{i}"
        g = gcn_normalize(make_power_law_graph(180 + 40 * i, 1200 + 90 * i,
                                               seed=i))
        fleet.register_graph(gid, g)
        single.register_graph(gid, g)
        feats[gid] = jnp.asarray(rng.normal(size=(g.n_cols, feat + 4 * i)),
                                 jnp.float32)
    return fleet, single, feats


def test_fleet_engine_matches_single_device_engine():
    """Acceptance: fleet-served outputs == single-device serving (fp tol)."""
    fleet, single, feats = _mixed_traffic_engines()
    try:
        freqs = fleet.serve([GraphRequest(gid, x) for gid, x in feats.items()])
        sreqs = single.serve([GraphRequest(gid, x) for gid, x in feats.items()])
        for fr, sr in zip(freqs, sreqs):
            np.testing.assert_allclose(np.asarray(fr.out), np.asarray(sr.out),
                                       atol=1e-4, rtol=1e-4)
        st_ = fleet.stats()
        assert st_["requests_served"] == len(feats)
        assert st_["fleet_rounds"] >= 1
        assert sum(st_["fleet_device_dispatches"]) >= 1
        # every request was answered by exactly one device's dispatch
        assert sum(st_["fleet_device_requests"]) == len(feats)
    finally:
        fleet.close()
        single.close()


def test_fleet_engine_concurrent_submitters_coalesce():
    fleet, single, feats = _mixed_traffic_engines(n_graphs=4)
    single.close()
    outs = {}
    try:
        def submitter(gid):
            outs[gid] = fleet.submit(gid, feats[gid])
        threads = [threading.Thread(target=submitter, args=(gid,))
                   for gid in feats]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for gid, fut in outs.items():
            direct = fleet.serve_one(gid, feats[gid])
            np.testing.assert_allclose(np.asarray(fut.result()),
                                       np.asarray(direct),
                                       atol=1e-4, rtol=1e-4)
        st_ = fleet.stats()
        assert st_["sched_completed"] == 2 * len(feats)
        assert st_["fleet_graphs_per_round"] >= 1.0
    finally:
        fleet.close()


def test_fleet_engine_giant_graph_block_shards():
    """A narrow giant graph (past the resident VMEM cap on one device)
    takes the block-sharded whole-mesh path and the engine exports its
    per-device balance evidence."""
    big = gcn_normalize(make_power_law_graph(6000, 40000, seed=5))
    fleet = FleetGraphEngine(backend="blocked")
    single = GraphServeEngine(backend="blocked")
    try:
        plan = fleet.register_graph("big", big)
        single.register_graph("big", big)
        assert plan.num_blocks >= fleet.n_devices
        x = jnp.asarray(np.random.default_rng(2).normal(
            size=(big.n_cols, 16)), jnp.float32)
        out = fleet.serve_one("big", x)
        ref = single.serve_one("big", x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)
        st_ = fleet.stats()
        # routed_* accounts for every dispatch, sharded ones included
        assert (st_["routed_resident"] + st_["routed_windowed"]
                + st_["routed_hbm"] + st_["routed_blocked"]
                == st_["batches_dispatched"])
        if fleet.n_devices > 1:
            assert st_["fleet_block_sharded"] == 1
            counts = st_["fleet_block_counts"]
            assert len(counts) == fleet.n_devices
            assert sum(counts) == plan.num_blocks
            # acceptance: balanced within 10% of the per-device mean
            assert st_["fleet_block_balance"] <= 1.10
        else:
            assert st_["fleet_block_sharded"] == 0  # degenerate 1-dev mesh
    finally:
        fleet.close()
        single.close()


def test_fleet_engine_validation_and_unknown_graph():
    fleet = FleetGraphEngine(backend="blocked")
    try:
        with pytest.raises(KeyError):
            fleet.submit("nope", jnp.zeros((4, 4)))
        g = gcn_normalize(make_powerlaw_csr(n=60, seed=0))
        fleet.register_graph("g", g)
        with pytest.raises(ValueError):
            fleet.submit("g", jnp.zeros((g.n_cols + 1, 4)))
    finally:
        fleet.close()


# -------------------------------------------------- real 8-device coverage
_EIGHT_DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys, json
    sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.graph import gcn_normalize
    from repro.data.graphs import make_power_law_graph
    from repro.serve.fleet import FleetGraphEngine
    from repro.serve.graph_engine import GraphRequest, GraphServeEngine

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)
    fleet = FleetGraphEngine(backend="blocked", max_graphs_per_batch=4)
    single = GraphServeEngine(backend="blocked", max_graphs_per_batch=4)
    feats = {}
    for i in range(4):
        gid = f"g{i}"
        g = gcn_normalize(make_power_law_graph(150 + 30 * i, 900 + 80 * i,
                                               seed=i))
        fleet.register_graph(gid, g)
        single.register_graph(gid, g)
        feats[gid] = jnp.asarray(rng.normal(size=(g.n_cols, 12)), jnp.float32)
    fr = fleet.serve([GraphRequest(g, x) for g, x in feats.items()])
    sr = single.serve([GraphRequest(g, x) for g, x in feats.items()])
    for a, b in zip(fr, sr):
        np.testing.assert_allclose(np.asarray(a.out), np.asarray(b.out),
                                   atol=1e-4, rtol=1e-4)

    big = gcn_normalize(make_power_law_graph(6000, 30000, seed=9))
    plan = fleet.register_graph("big", big)
    single.register_graph("big", big)
    xb = jnp.asarray(rng.normal(size=(big.n_cols, 16)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(fleet.serve_one("big", xb)),
        np.asarray(single.serve_one("big", xb)), atol=1e-4, rtol=1e-4)
    st = fleet.stats()
    fleet.close(); single.close()
    print(json.dumps({
        "devices": st["fleet_devices"],
        "block_sharded": st["fleet_block_sharded"],
        "block_counts": st["fleet_block_counts"],
        "block_balance": st["fleet_block_balance"],
        "device_requests": st["fleet_device_requests"],
        "num_blocks": plan.num_blocks,
    }))
""")


def test_fleet_on_eight_fake_devices_subprocess():
    """Real 8-device semantics regardless of how the suite itself was run
    (subprocess so the XLA flag cannot leak into other tests)."""
    proc = subprocess.run(
        [sys.executable, "-c", _EIGHT_DEV_SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-4000:]
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 8
    assert rec["block_sharded"] == 1
    assert sum(rec["block_counts"]) == rec["num_blocks"]
    assert rec["block_balance"] <= 1.10
    assert max(rec["block_counts"]) - min(rec["block_counts"]) <= 1


# ------------------------------------------------------------ replica sets
def test_fleet_cache_add_drop_replica_roundtrip():
    """Replica copies are independent per-shard clones: the primary stays
    authoritative, extras stage/drop without touching it."""
    dev = jax.devices()[0]
    cache = FleetPlanCache([dev, dev], capacity_per_device=8)
    cfg = PartitionConfig()
    g = gcn_normalize(make_powerlaw_csr(n=90, seed=5))
    plan = cache.get_or_build(g, cfg)
    primary = cache.device_index_of(plan.key)
    other = 1 - primary
    assert cache.replica_devices(plan.key) == [primary]

    assert cache.add_replica(plan.key, other) is True
    assert cache.add_replica(plan.key, other) is True   # idempotent
    assert cache.replica_devices(plan.key) == [primary, other]
    copy = cache.plan_on(plan.key, other)
    assert copy is not None and copy is not plan, \
        "replica must be its own staged clone, not the primary object"
    import numpy as np
    np.testing.assert_array_equal(np.asarray(copy.slabs["colidx"]),
                                  np.asarray(plan.slabs["colidx"]))

    # the primary slot can never be dropped through the replica API
    assert cache.drop_replica(plan.key, primary) is False
    assert cache.drop_replica(plan.key, other) is True
    assert cache.replica_devices(plan.key) == [primary]
    assert cache.plan_on(plan.key, other) is None
    st = cache.stats()
    assert st["replicas_added"] == 1 and st["replicas_removed"] == 1

    # replicating a key with no resident primary plan is refused
    assert cache.add_replica(("ghost", cfg), other) is False


def test_fleet_cache_prune_is_replica_aware():
    """Placement pruning must not forget a key whose plan is resident only
    on a replica shard (regression: pruning used to consult the primary
    shard alone, so a replicated-but-primary-evicted plan lost its
    placement and its replicas became unreachable)."""
    dev = jax.devices()[0]
    cache = FleetPlanCache([dev, dev], capacity_per_device=2)
    cfg = PartitionConfig()
    g = gcn_normalize(make_powerlaw_csr(n=90, seed=6))
    plan = cache.get_or_build(g, cfg)
    primary = cache.device_index_of(plan.key)
    other = 1 - primary
    assert cache.add_replica(plan.key, other)
    # evict the PRIMARY copy (LRU churn elsewhere would do the same)
    assert cache.shards[primary].remove(plan.key)
    # churn one-off plans far past the pruning threshold
    for i in range(8 * 2 * cache.capacity_per_device * len(cache.shards)):
        cache.device_index_of((f"churn-{i}", cfg))
    assert plan.key in cache._placements, \
        "replica-resident key lost its placement to pruning"
    assert other in cache.replica_devices(plan.key)
    assert cache.plan_on(plan.key, other) is not None


_ZIPF_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys, json, threading
    sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.graph import gcn_normalize
    from repro.data.graphs import make_power_law_graph
    from repro.serve.fleet import FleetGraphEngine

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(3)
    graphs = {f"z{i}": gcn_normalize(make_power_law_graph(
        220 + 40 * i, 1500 + 150 * i, seed=50 + i)) for i in range(5)}
    feats = {k: jnp.asarray(rng.normal(size=(g.n_cols, 16)), jnp.float32)
             for k, g in graphs.items()}
    names = list(graphs)
    p = np.arange(1, len(names) + 1, dtype=np.float64) ** -1.6
    p /= p.sum()
    schedule = [names[i] for i in
                rng.choice(len(names), size=96, p=p)]

    def run(**kw):
        e = FleetGraphEngine(max_batch_requests=32, max_wait_ms=3.0,
                             max_graphs_per_batch=1, backend="blocked", **kw)
        for k, g in graphs.items():
            e.register_graph(k, g)

        def pass_once():
            futs = [[] for _ in range(4)]
            def sub(t):
                futs[t] = [e.submit(gid, feats[gid])
                           for gid in schedule[t::4]]
            ths = [threading.Thread(target=sub, args=(t,)) for t in range(4)]
            for t in ths: t.start()
            for t in ths: t.join()
            return [np.asarray(f.result()) for fs in futs for f in fs]

        pass_once()              # warm: learn rates, stage replicas
        e.reset_stats()
        outs = pass_once()       # measured: replicated steady state
        st = e.stats()
        e.close()
        return outs, st

    outs_rep, st_rep = run(rate_per_replica=1.0, max_replicas=8,
                           replica_halflife_s=4.0,
                           replication_interval_s=0.005,
                           split_min_requests=1)
    outs_dis, st_dis = run(replicate_hot=False)
    for a, b in zip(outs_rep, outs_dis):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
    print(json.dumps({
        "promotions": st_rep["fleet_promotions"],
        "replicated_keys": st_rep["cache_replicated_keys"],
        "replica_copies": st_rep["cache_replica_copies"],
        "occ_rep": st_rep["fleet_occupancy"],
        "occ_dis": st_dis["fleet_occupancy"],
        "req_rep": st_rep["fleet_device_requests"],
        "req_dis": st_dis["fleet_device_requests"],
    }))
""")


def test_fleet_zipf_replication_subprocess():
    """Hot-plan replication under a zipf-skewed mix on 8 real fake devices:
    the hot plan promotes to >= 2 replicas, its traffic spreads across
    devices, fleet occupancy beats the single-owner run, and results match
    the replication-disabled engine exactly."""
    proc = subprocess.run(
        [sys.executable, "-c", _ZIPF_SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-4000:]
    rec = json.loads(proc.stdout.strip().splitlines()[-1])
    assert rec["promotions"] >= 1
    assert rec["replicated_keys"] >= 1
    assert rec["replica_copies"] >= 1, \
        "hot plan never reached a second replica"
    # replication spreads the zipf mix over strictly more devices than the
    # single-owner placement uses
    assert (len([r for r in rec["req_rep"] if r > 0])
            > len([r for r in rec["req_dis"] if r > 0]))
    # and the measured occupancy window must improve materially
    assert rec["occ_rep"] >= 1.5 * rec["occ_dis"], rec
