"""CSR container + O(n) preprocessing correctness."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.graph import (
    CSRGraph, counting_sort_by_degree, csr_from_edges, csr_transpose,
    degree_sort_csr, degrees_from_rowptr, gcn_normalize,
)
from conftest import make_powerlaw_csr


@settings(max_examples=30, deadline=None)
@given(degs=st.lists(st.integers(0, 50), min_size=1, max_size=200))
def test_counting_sort_stable_ascending(degs):
    d = np.array(degs)
    perm = counting_sort_by_degree(d)
    s = d[perm]
    assert np.all(np.diff(s) >= 0)
    # stability: equal degrees keep original relative order
    for val in np.unique(d):
        orig = np.flatnonzero(d == val)
        assert np.array_equal(perm[s == val], orig)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 300), seed=st.integers(0, 999))
def test_degree_sort_preserves_matrix(n, seed):
    g = make_powerlaw_csr(n=n, seed=seed)
    gs = degree_sort_csr(g)
    gs.validate()
    # row contents preserved under permutation
    dense = g.to_dense()
    dense_s = gs.to_dense()
    assert np.allclose(dense_s, dense[gs.perm])
    # degrees ascending
    assert np.all(np.diff(degrees_from_rowptr(gs.rowptr)) >= 0)


def test_gcn_normalize_symmetric():
    g = make_powerlaw_csr(n=50, seed=1)
    gn = gcn_normalize(g)
    a = gn.to_dense()
    deg = np.asarray((make_powerlaw_csr(n=50, seed=1).to_dense()
                      + np.eye(50) > 0))  # structure only
    # row sums of D^-1/2 (A+I) D^-1/2 bounded by sqrt(deg) ratios; spot check
    # the self-loop value: 1/deg for isolated-ish nodes
    gi = gcn_normalize(CSRGraph(np.arange(6), np.zeros(5, np.int64),
                                np.ones(5, np.float32), 5))
    d = gi.to_dense()
    # each row had 1 edge to node 0 + self loop
    assert d.shape == (5, 5)
    assert np.isfinite(d).all()


def test_csr_from_edges_roundtrip():
    src = np.array([2, 0, 1, 0, 2])
    dst = np.array([1, 2, 0, 1, 2])
    g = csr_from_edges(src, dst, 3)
    g.validate()
    d = g.to_dense()
    expect = np.zeros((3, 3))
    for s, t in zip(src, dst):
        expect[s, t] += 1
    assert np.allclose(d, expect)


# --------------------------------------------------------------- transpose
def test_csr_transpose_dense_parity():
    g = make_powerlaw_csr(n=60, seed=3)
    t = csr_transpose(g)
    t.validate()
    assert np.array_equal(t.to_dense(), g.to_dense().T)


def test_csr_transpose_rectangular_and_values():
    dst = np.array([1, 4, 0, 4])
    vals = np.array([1.5, -2.0, 0.25, 7.0], dtype=np.float32)
    g = CSRGraph(np.array([0, 2, 2, 3, 4]), dst.astype(np.int64),
                 vals, n_cols=5)
    t = csr_transpose(g)
    assert t.n_rows == 5 and t.n_cols == 4
    assert np.array_equal(t.to_dense(), g.to_dense().T)


def test_csr_transpose_within_row_source_order():
    # transposed rows list sources ASCENDING (row-major scan is stable)
    g = make_powerlaw_csr(n=80, seed=9)
    t = csr_transpose(g)
    for r in range(t.n_rows):
        lo, hi = t.rowptr[r], t.rowptr[r + 1]
        assert np.all(np.diff(t.colidx[lo:hi]) >= 0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 200), seed=st.integers(0, 999))
def test_csr_transpose_involution(n, seed):
    g = make_powerlaw_csr(n=n, seed=seed)
    tt = csr_transpose(csr_transpose(g))
    assert np.array_equal(tt.to_dense(), g.to_dense())
