"""SamplingService end to end: sampled inference through the serving path.

The ISSUE-9 acceptance surface: a 2-layer GCN over full-fanout sampled
frontiers matches the full-graph reference BIT-FOR-BIT on both kernel
backends; recurring frontiers amortize through the frontier LRU and the
engine's plan cache (content-derived subgraph ids); store deltas either
ride the PR-7 ``mutate()`` repair path into the cached frontier plans or
drop the affected frontiers — never serving stale ones; and the
cross-partition frontier exchange works over the REAL peer data plane
(two subprocesses at the bottom of the file).
"""
import os
import textwrap

import jax
import numpy as np
import pytest

from repro.core.graph import csr_from_edges
from repro.core.plan_repair import EdgeDelta
from repro.distributed.multihost import run_cpu_fleet
from repro.models.gcn import init_gcn
from repro.sampling import GraphStore, SamplingService
from repro.serve import GraphServeEngine

BACKENDS = ["blocked", "pallas"]


def _simple_graph(n=80, seed=0, m=500):
    """Deduplicated random digraph (no parallel edges, so delta policies
    and dense comparisons are unambiguous)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    eid = np.unique(src * n + dst)
    return csr_from_edges(eid // n, eid % n, n)


def _reference_gcn(engine, gid, x, params):
    """Full-graph forward pass with the exact layer arithmetic the
    service mirrors (h = aggr(h @ W) + b, relu between layers)."""
    h = jax.numpy.asarray(x)
    for i, p in enumerate(params):
        agg = engine.submit(gid, jax.numpy.dot(h, p["w"])).result()
        h = agg + p["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return np.asarray(h)


@pytest.mark.parametrize("backend", BACKENDS)
def test_two_layer_gcn_full_fanout_bit_exact(backend):
    n = 90
    store = GraphStore.build(_simple_graph(n, seed=0), normalize=True)
    engine = GraphServeEngine(backend=backend)
    try:
        engine.register_graph("full", store.in_adj)
        svc = SamplingService(engine, store, fanouts=[None, None],
                              store=store)
        x = np.random.default_rng(1).normal(size=(n, 12)).astype(np.float32)
        params = init_gcn(jax.random.PRNGKey(0), [12, 16, 5])
        ref = _reference_gcn(engine, "full", x, params)
        seeds = np.array([7, 3, 55, 20])   # deliberately unsorted
        out = svc.infer(seeds, x, params)
        assert out.shape == (4, 5)
        assert np.array_equal(out, ref[seeds])   # bit-for-bit
    finally:
        engine.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_k_hop_aggregate_full_fanout_bit_exact(backend):
    n = 70
    store = GraphStore.build(_simple_graph(n, seed=2), normalize=True)
    engine = GraphServeEngine(backend=backend)
    try:
        engine.register_graph("full", store.in_adj)
        svc = SamplingService(engine, store, fanouts=[None, None],
                              store=store)
        x = np.random.default_rng(3).normal(size=(n, 8)).astype(np.float32)
        a1 = np.asarray(engine.submit("full", x).result())
        a2 = np.asarray(engine.submit("full", a1).result())
        seeds = np.array([1, 66, 30])
        assert np.array_equal(svc.aggregate(seeds, x), a2[seeds])
    finally:
        engine.close()


def test_recurring_frontier_amortizes_plans():
    n = 60
    store = GraphStore.build(_simple_graph(n, seed=4), normalize=True)
    engine = GraphServeEngine(backend="blocked")
    try:
        svc = SamplingService(engine, store, fanouts=[2, 2], store=store)
        x = np.random.default_rng(0).normal(size=(n, 4)).astype(np.float32)
        seeds = np.array([5, 9, 33])
        svc.aggregate(seeds, x)
        size_after_first = engine.stats()["cache_size"]
        # same seed SET in a different order: frontier LRU hit, no
        # sampling, no registration, no new plans
        svc.aggregate(np.array([33, 5, 9]), x)
        st = svc.stats()
        assert st["frontier_hits"] == 1 and st["frontier_misses"] == 1
        assert engine.stats()["cache_size"] == size_after_first
        # a SECOND service (fresh LRU, same engine): content-derived ids
        # make its registrations plan-cache hits, not rebuilds
        builds_before = engine.stats()["cache_misses"]
        svc2 = SamplingService(engine, store, fanouts=[2, 2], store=store)
        svc2.aggregate(seeds, x)
        assert engine.stats()["cache_misses"] == builds_before
    finally:
        engine.close()


def test_submit_gather_epilogue():
    n = 40
    g = GraphStore.build(_simple_graph(n, seed=5), normalize=True).in_adj
    engine = GraphServeEngine(backend="blocked")
    try:
        gid = engine.register_subgraph(g, prefix="sub")
        assert gid.startswith("sub:")
        # idempotent: same content, same id, no duplicate binding
        assert engine.register_subgraph(g, prefix="sub") == gid
        x = np.random.default_rng(1).normal(size=(n, 6)).astype(np.float32)
        rows = np.array([3, 0, 17])
        full = np.asarray(engine.submit(gid, x).result())
        gathered = np.asarray(engine.submit_gather(gid, x, rows).result())
        assert np.array_equal(gathered, full[rows])
    finally:
        engine.close()


def test_unregister_graph_drops_binding():
    n = 30
    g = GraphStore.build(_simple_graph(n, seed=6), normalize=True).in_adj
    engine = GraphServeEngine(backend="blocked")
    try:
        gid = engine.register_subgraph(g)
        x = np.zeros((n, 2), np.float32)
        engine.submit(gid, x).result()
        assert engine.unregister_graph(gid)
        assert gid not in engine.graph_ids()
        assert not engine.unregister_graph(gid)   # second call: no-op
        with pytest.raises(KeyError):
            engine.submit(gid, x)
        # re-registration re-binds (plan may still be cached)
        assert engine.register_subgraph(g) == gid
        engine.submit(gid, x).result()
    finally:
        engine.close()


def test_frontier_lru_eviction_unregisters():
    n = 60
    store = GraphStore.build(_simple_graph(n, seed=7), normalize=True)
    engine = GraphServeEngine(backend="blocked")
    try:
        svc = SamplingService(engine, store, fanouts=[None],
                              max_cached_frontiers=1, store=store)
        x = np.zeros((n, 2), np.float32)
        svc.aggregate(np.array([1, 2]), x)
        gids_first = list(svc._cache.values())[0]["gids"]
        svc.aggregate(np.array([40, 41]), x)
        st = svc.stats()
        assert st["frontiers_evicted"] == 1 and st["frontiers_cached"] == 1
        for gid in gids_first:
            assert gid not in engine.graph_ids()
    finally:
        engine.close()


# ------------------------------------------------------------ invalidation
def _frontier_edge(store, svc, seeds):
    """(frontier, one in-edge (u -> v) with v a seed) for delta tests."""
    f = svc.frontier_for(seeds)
    v = int(f.layers[0][0])
    a = store.in_adj
    lo, hi = int(a.rowptr[v]), int(a.rowptr[v + 1])
    assert hi > lo, "test graph left the first seed with no in-edges"
    return f, int(a.colidx[lo]), v


def test_delta_rides_mutate_path_and_stays_exact():
    """Full-fanout frontier + expressible delta: the cached plans repair
    through engine.mutate() (no resample) and keep serving exactly."""
    n = 80
    store = GraphStore.build(_simple_graph(n, seed=8))   # unnormalized
    engine = GraphServeEngine(backend="blocked")
    try:
        svc = SamplingService(engine, store, fanouts=[None, None],
                              store=store)
        x = np.random.default_rng(2).normal(size=(n, 5)).astype(np.float32)
        seeds = np.array([4, 11, 62])
        svc.aggregate(seeds, x)
        f, u, v = _frontier_edge(store, svc, seeds)
        # delete an existing in-edge of a seed; insert a fresh edge whose
        # endpoints both already sit in the frontier's layers
        w = int(f.layers[1][-1])
        dense = store.out_adj.to_dense()
        ins = [(w, v)] if dense[w, v] == 0 else []
        mut_before = engine.stats()["mutations_applied"]
        store.apply_delta(EdgeDelta(
            insert_src=[e[0] for e in ins], insert_dst=[e[1] for e in ins],
            insert_val=[1.0] * len(ins),
            delete_src=[u], delete_dst=[v]))
        st = svc.stats()
        assert st["frontier_mutations"] >= 1
        assert st["frontiers_invalidated"] == 0
        assert engine.stats()["mutations_applied"] > mut_before
        # cached entry survives AND serves the post-delta graph exactly
        engine.register_graph("ref", store.in_adj)
        a1 = np.asarray(engine.submit("ref", x).result())
        a2 = np.asarray(engine.submit("ref", a1).result())
        out = svc.aggregate(seeds, x)
        assert svc.stats()["frontier_hits"] >= 1
        assert np.array_equal(out, a2[seeds])
    finally:
        engine.close()


def test_unexpressible_insert_invalidates_and_resamples():
    n = 80
    store = GraphStore.build(_simple_graph(n, seed=9))
    engine = GraphServeEngine(backend="blocked")
    try:
        svc = SamplingService(engine, store, fanouts=[None, None],
                              store=store)
        x = np.random.default_rng(3).normal(size=(n, 4)).astype(np.float32)
        seeds = np.array([2, 3])
        svc.aggregate(seeds, x)
        f = svc.frontier_for(seeds)
        v = int(f.layers[0][0])
        outside = np.setdiff1d(np.arange(n), f.layers[1])
        assert len(outside), "frontier swallowed the whole graph; shrink it"
        w = int(outside[0])   # insert from OUTSIDE the frontier: no local
        #                       coordinates for w -> must resample
        store.apply_delta(EdgeDelta(insert_src=[w], insert_dst=[v],
                                    insert_val=[1.0],
                                    on_duplicate="replace"))
        st = svc.stats()
        assert st["frontiers_invalidated"] == 1
        assert st["frontier_mutations"] == 0
        # next query resamples against the post-delta store and is exact
        engine.register_graph("ref", store.in_adj)
        a1 = np.asarray(engine.submit("ref", x).result())
        a2 = np.asarray(engine.submit("ref", a1).result())
        assert np.array_equal(svc.aggregate(seeds, x), a2[seeds])
        assert svc.stats()["frontier_misses"] == 2
    finally:
        engine.close()


def test_capped_fanout_delta_invalidates():
    n = 60
    store = GraphStore.build(_simple_graph(n, seed=10))
    engine = GraphServeEngine(backend="blocked")
    try:
        svc = SamplingService(engine, store, fanouts=[2, 2], store=store)
        x = np.zeros((n, 2), np.float32)
        seeds = np.array([1, 5])
        svc.aggregate(seeds, x)
        _, u, v = _frontier_edge(store, svc, seeds)
        store.apply_delta(EdgeDelta(delete_src=[u], delete_dst=[v]))
        st = svc.stats()
        assert st["frontiers_invalidated"] == 1
        assert st["frontier_mutations"] == 0
    finally:
        engine.close()


def test_unrelated_delta_leaves_frontiers_cached():
    n = 80
    store = GraphStore.build(_simple_graph(n, seed=11))
    engine = GraphServeEngine(backend="blocked")
    try:
        svc = SamplingService(engine, store, fanouts=[None], store=store)
        x = np.zeros((n, 2), np.float32)
        seeds = np.array([0, 1])
        svc.aggregate(seeds, x)
        f = svc.frontier_for(seeds)
        outside = np.setdiff1d(np.arange(n), f.layers[0])
        v = int(outside[-1])   # delta touches rows OUTSIDE the receptive
        u = int(outside[0])    # field: nothing to do
        store.apply_delta(EdgeDelta(insert_src=[u], insert_dst=[v],
                                    insert_val=[1.0],
                                    on_duplicate="replace"))
        st = svc.stats()
        assert st["frontiers_invalidated"] == 0
        assert st["frontier_mutations"] == 0
        assert st["frontiers_cached"] == 1
    finally:
        engine.close()


# --------------------------------------------------- cross-partition (real)
_EXCHANGE_WORKER = textwrap.dedent("""
    import json, os, threading
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    from repro.data.graphs import make_power_law_graph
    from repro.distributed.multihost import (
        FrontierExchange, PeerClient, PeerServer, peer_ports,
    )
    from repro.sampling import (
        GraphStore, PartitionedStoreClient, sample_frontier,
    )

    rank = int(os.environ["REPRO_MH_PID"])
    nprocs = int(os.environ["REPRO_MH_NPROCS"])
    ports = peer_ports()

    # every rank derives the SAME graph deterministically, keeps its own
    # shard for serving, and a monolithic copy as the parity reference
    full = GraphStore.build(make_power_law_graph(400, 2400, seed=0),
                            normalize=True)
    shards = full.partition(nprocs)
    bounds = [s.node_range[0] for s in shards] + [full.n_nodes]

    server = PeerServer(ports[rank], process_index=rank, epoch=0,
                        n_devices=1)
    FrontierExchange.serve(server, shards[rank])
    done = threading.Event()
    server.register("peer-done", lambda _p: done.set())

    peers = {r: PeerClient(("127.0.0.1", p), process_index=rank)
             for r, p in ports.items() if r != rank}
    exchange = FrontierExchange(peers)
    client = PartitionedStoreClient(shards[rank], bounds,
                                    exchange.remote_map(), rank)

    # seeds straddling every partition boundary force remote hops
    seeds = np.array([3, 197, 202, 396])
    checks = []
    for fanouts in ([None, None], [3, 3]):
        fp = sample_frontier(client.sample_in_neighbors, seeds, fanouts,
                             seed=7)
        fm = sample_frontier(full.sample_in_neighbors, seeds, fanouts,
                             seed=7)
        checks.append(fp.content_key() == fm.content_key())

    for peer in peers.values():
        peer.request("peer-done", None)
    assert done.wait(120), "peer never finished sampling"
    for peer in peers.values():
        peer.close()
    server.close()
    print(json.dumps({"rank": rank, "parity": all(checks),
                      "remote_edges": int(client.remote_edges),
                      "local_edges": int(client.local_edges),
                      "failovers": exchange.failovers,
                      "requests": exchange.requests}))
""")


def test_cross_partition_exchange_two_processes():
    """REAL data plane: two subprocesses each own half the store; both
    sample frontiers straddling the boundary via FrontierExchange and
    must match the monolithic store bit-for-bit with zero failovers."""
    repo_root = os.path.join(os.path.dirname(__file__), "..")
    records = run_cpu_fleet(_EXCHANGE_WORKER, num_processes=2,
                            n_local_devices=1, timeout_s=300.0,
                            cwd=repo_root)
    assert len(records) == 2
    for rec in sorted(records, key=lambda r: r["rank"]):
        assert rec["parity"], f"rank {rec['rank']} lost sampling parity"
        assert rec["remote_edges"] > 0     # boundary hops actually crossed
        assert rec["failovers"] == 0
        assert rec["requests"] > 0
