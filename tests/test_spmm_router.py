"""VMEM-budget routing: regime boundaries, the forced-resident guard, and
kernel parity across the three regimes (windowed exercised with a small
window so the middle regime stays CI-cheap)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import gcn_normalize
from repro.core.plan_cache import PartitionConfig, build_partition_plan
from repro.core.spmm import make_accel_spmm
from repro.kernels import ops as kops
from repro.kernels.router import (
    MAX_WINDOWS,
    VmemBudgetError,
    assert_resident_fits,
    estimate_vmem_bytes,
    pad_rows,
    resident_window_rows,
    route_spmm,
)
from repro.kernels.spmm_accel import (
    spmm_block_slabs,
    spmm_block_slabs_windowed,
)

from conftest import make_powerlaw_csr

C, R = 256, 64
WINDOW = resident_window_rows()          # 4096 at f32/128-lane defaults


def test_default_window_is_documented_4096():
    assert WINDOW == 4096


# --------------------------------------------------------------- boundaries
def test_route_exact_resident_boundary():
    assert route_spmm(WINDOW, 64, C, R).backend == "resident"
    assert route_spmm(WINDOW + 1, 64, C, R).backend == "windowed"


def test_route_exact_windowed_boundary():
    hi = MAX_WINDOWS * WINDOW
    d = route_spmm(hi, 64, C, R)
    assert d.backend == "windowed" and d.num_windows == MAX_WINDOWS
    d = route_spmm(hi + 1, 64, C, R)
    assert d.backend == "hbm" and d.num_windows == 0


def test_route_respects_row_padding():
    # 4090 unpadded rows pad to 4096 -> still resident; 4092 pads to 4096
    # too; 4097 pads to 4104 -> windowed.
    assert route_spmm(4090, 64, C, R).n_pad == 4096
    assert route_spmm(4090, 64, C, R).backend == "resident"
    assert route_spmm(4097, 64, C, R).backend == "windowed"


def test_route_itemsize_scales_boundary():
    # bf16 halves the per-row cost -> twice the resident rows.
    assert resident_window_rows(itemsize=2) == 2 * WINDOW
    assert route_spmm(2 * WINDOW, 64, C, R, itemsize=2).backend == "resident"
    assert route_spmm(2 * WINDOW + 8, 64, C, R, itemsize=2).backend == "windowed"


def test_route_custom_budget():
    # Shrinking the budget moves every boundary proportionally.
    small = 64 * 1024
    w = resident_window_rows(budget_bytes=small)
    assert w == small // (128 * 4) // 8 * 8
    assert route_spmm(w, 16, C, R, budget_bytes=small).backend == "resident"
    assert route_spmm(w + 1, 16, C, R, budget_bytes=small).backend == "windowed"
    assert route_spmm(MAX_WINDOWS * w + 1, 16, C, R,
                      budget_bytes=small).backend == "hbm"


def test_vmem_estimate_ordering():
    n_pad = pad_rows(20_000)
    resident = estimate_vmem_bytes("resident", n_pad, C, R)
    windowed = estimate_vmem_bytes("windowed", n_pad, C, R)
    hbm = estimate_vmem_bytes("hbm", n_pad, C, R)
    assert resident > windowed > hbm
    # hbm footprint is independent of N
    assert hbm == estimate_vmem_bytes("hbm", 8, C, R)
    with pytest.raises(ValueError, match="unknown backend"):
        estimate_vmem_bytes("nope", n_pad, C, R)


def test_decision_reports_estimates():
    d = route_spmm(20_000, 64, C, R)
    assert d.backend == "hbm"
    assert d.resident_bytes > d.budget_bytes
    assert d.vmem_bytes < d.budget_bytes
    assert "hbm" in d.describe()


def test_oversized_block_capacity_falls_back_then_raises():
    """The MXU operands scale with C*R in EVERY regime: a partition capacity
    that pushes the resident step over the total budget must route to hbm
    (leaner X cost) even for small N, and one that overflows hbm too must
    raise rather than hand hardware an uncompilable step."""
    d = route_spmm(4_000, 64, 2048, 768)   # one-hot alone is 6 MiB
    assert d.backend == "hbm" and "total VMEM budget" in d.reason
    assert d.vmem_bytes <= d.total_budget_bytes
    with pytest.raises(VmemBudgetError, match="no SpMM regime"):
        route_spmm(100, 64, 4096, 1024)    # one-hot alone is 16 MiB


def test_every_routed_regime_fits_total_budget():
    """budget_bytes caps the per-buffer X tile; the whole-step footprint of
    whatever regime routing picks must fit the total VMEM budget — the
    uniform invariant serving asserts per dispatch (windowed's two in-flight
    windows exceed the X-tile slice by design, never the total)."""
    for n in [64, WINDOW, WINDOW + 8, 3 * WINDOW, MAX_WINDOWS * WINDOW + 8,
              500_000]:
        d = route_spmm(n, 64, C, R)
        assert d.vmem_bytes <= d.total_budget_bytes, (n, d.backend)
        if d.backend == "resident":
            assert n <= d.window_rows


# -------------------------------------------------------------------- guard
def test_assert_resident_fits_message_names_dims_and_fallback():
    with pytest.raises(VmemBudgetError) as ei:
        assert_resident_fits(20_000, 64, C, R)
    msg = str(ei.value)
    assert "N_pad=20000" in msg and "C=256" in msg and "R=64" in msg
    assert "hbm" in msg          # the suggested backend for this shape
    # middle regime suggests the windowed kernel instead
    with pytest.raises(VmemBudgetError, match="windowed"):
        assert_resident_fits(5_000, 64, C, R)


def test_spmm_block_slabs_guard_raises_not_compiles():
    """The resident kernel itself refuses an oversized X at trace time."""
    slabs = {
        "colidx": jnp.zeros((1, 8), jnp.int32),
        "values": jnp.zeros((1, 8), jnp.float32),
        "rowloc": jnp.zeros((1, 8), jnp.int32),
        "out_row": jnp.zeros((1, 4), jnp.int32),
    }
    x = jnp.zeros((WINDOW + 8, 4), jnp.float32)
    with pytest.raises(VmemBudgetError, match="VMEM budget"):
        spmm_block_slabs(slabs["colidx"], slabs["values"], slabs["rowloc"],
                         slabs["out_row"], x, 4)
    # one row under the boundary still runs
    out = spmm_block_slabs(slabs["colidx"], slabs["values"], slabs["rowloc"],
                           slabs["out_row"], jnp.zeros((WINDOW, 4)), 4)
    assert out.shape == (4, 4)


# ------------------------------------------------------------ kernel parity
@pytest.mark.parametrize("window_rows,F", [(64, 32), (64, 130), (96, 17)])
def test_windowed_kernel_matches_resident(window_rows, F):
    """Small windows force multi-window accumulation on a CI-size graph."""
    g = gcn_normalize(make_powerlaw_csr(n=220, seed=7, zipf=1.5))
    plan = build_partition_plan(g, PartitionConfig())
    x = jnp.asarray(np.random.default_rng(7).normal(size=(g.n_cols, F)),
                    jnp.float32)
    ref = spmm_block_slabs(plan.slabs["colidx"], plan.slabs["values"],
                           plan.slabs["rowloc"], plan.slabs["out_row"],
                           x, plan.n_rows)
    out = spmm_block_slabs_windowed(
        plan.slabs["colidx"], plan.slabs["values"], plan.slabs["rowloc"],
        plan.slabs["out_row"], x, plan.n_rows, window_rows=window_rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_windowed_single_window_degenerate():
    g = gcn_normalize(make_powerlaw_csr(n=60, seed=8))
    plan = build_partition_plan(g, PartitionConfig())
    x = jnp.asarray(np.random.default_rng(8).normal(size=(g.n_cols, 12)),
                    jnp.float32)
    ref = spmm_block_slabs(plan.slabs["colidx"], plan.slabs["values"],
                           plan.slabs["rowloc"], plan.slabs["out_row"],
                           x, plan.n_rows)
    out = spmm_block_slabs_windowed(
        plan.slabs["colidx"], plan.slabs["values"], plan.slabs["rowloc"],
        plan.slabs["out_row"], x, plan.n_rows)   # default window >> N
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)


def test_spmm_auto_small_graph_picks_resident():
    g = gcn_normalize(make_powerlaw_csr(n=120, seed=9))
    plan = build_partition_plan(g, PartitionConfig())
    x = jnp.asarray(np.random.default_rng(9).normal(size=(g.n_cols, 8)),
                    jnp.float32)
    out, decision = kops.spmm_auto(plan.slabs, x, plan.n_rows,
                                   return_decision=True)
    assert decision.backend == "resident"
    ref = kops.spmm_pallas(plan.slabs, x, plan.n_rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0)


@pytest.mark.parametrize("backend", ["auto", "windowed", "hbm"])
def test_accel_spmm_new_backends_agree(backend):
    g = gcn_normalize(make_powerlaw_csr(n=150, seed=10))
    x = jnp.asarray(np.random.default_rng(10).normal(size=(g.n_cols, 24)),
                    jnp.float32)
    op = make_accel_spmm(g, backend="blocked")
    ref = np.asarray(op(x))
    out = np.asarray(op(x, backend=backend))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_hbm_kernel_wide_features_multi_tile():
    """F > 128 spans several feature tiles: each HBM grid step must DMA its
    OWN lane window (regression: the gather once copied full-width rows into
    a one-tile buffer, crashing for any F_pad > f_tile)."""
    g = gcn_normalize(make_powerlaw_csr(n=140, seed=12))
    x = jnp.asarray(np.random.default_rng(12).normal(size=(g.n_cols, 200)),
                    jnp.float32)
    op = make_accel_spmm(g, backend="blocked")
    np.testing.assert_allclose(np.asarray(op(x, backend="hbm")),
                               np.asarray(op(x)), atol=1e-5, rtol=1e-5)
