"""BatchScheduler semantics: flush triggers, backpressure, failure scoping."""
import threading
import time

import pytest

from repro.serve.scheduler import BatchScheduler, QueueFullError, percentile


class Recorder:
    """flush_fn that completes every item and records the batches."""

    def __init__(self, delay_s=0.0, gate=None):
        self.batches = []
        self.delay_s = delay_s
        self.gate = gate          # optional Event the flush waits on
        self.entered = threading.Event()  # set when a flush begins

    def __call__(self, items):
        self.entered.set()
        if self.gate is not None:
            assert self.gate.wait(10.0), "test gate never opened"
        if self.delay_s:
            time.sleep(self.delay_s)
        self.batches.append([it.payload for it in items])
        for it in items:
            it.complete(("done", it.payload))


def test_size_trigger_flushes_full_batches():
    rec = Recorder()
    sched = BatchScheduler(rec, max_batch=4, max_wait_ms=10_000, max_queue=64)
    with sched:
        items = sched.submit_many(list(range(8)))
        results = [it.future.result(timeout=10) for it in items]
    assert results == [("done", i) for i in range(8)]
    assert [len(b) for b in rec.batches] == [4, 4]
    st = sched.stats()
    assert st["flush_size"] == 2 and st["completed"] == 8
    assert st["items_per_flush"] == 4.0


def test_deadline_flush_fires_for_single_request():
    """A lone queued request must not wait for co-batchable traffic."""
    rec = Recorder()
    sched = BatchScheduler(rec, max_batch=64, max_wait_ms=30, max_queue=8)
    t0 = time.perf_counter()
    item = sched.submit("solo")
    assert item.future.result(timeout=10) == ("done", "solo")
    elapsed = time.perf_counter() - t0
    assert elapsed < 5.0
    st = sched.stats()
    assert st["flush_deadline"] == 1 and st["flush_size"] == 0
    assert item.latency_s is not None and item.latency_s >= 0.030 * 0.5
    sched.stop()


def test_backpressure_raises_nonblocking_and_times_out():
    gate = threading.Event()
    rec = Recorder(gate=gate)
    sched = BatchScheduler(rec, max_batch=1, max_wait_ms=0, max_queue=2)
    # first item enters the (gated) flush; once the worker is inside it,
    # nothing drains the queue, so filling to max_queue is deterministic
    first = sched.submit("a")
    assert rec.entered.wait(10.0)
    while sched.queue_depth() < 2:
        sched.submit("fill", block=False)
    with pytest.raises(QueueFullError):
        sched.submit("overflow", block=False)
    with pytest.raises(QueueFullError):
        sched.submit("overflow", timeout=0.05)
    assert sched.stats()["rejected"] >= 2
    gate.set()                              # drain; admission works again
    assert first.future.result(timeout=10) == ("done", "a")
    ok = sched.submit("after")
    assert ok.future.result(timeout=10) == ("done", "after")
    sched.stop()


def test_blocking_submit_waits_for_room():
    gate = threading.Event()
    rec = Recorder(gate=gate)
    sched = BatchScheduler(rec, max_batch=1, max_wait_ms=0, max_queue=1)
    sched.submit("a")
    assert rec.entered.wait(10.0)   # worker gated: queue can only grow now
    got = []

    def blocked_submit():
        got.append(sched.submit("b", block=True, timeout=10))

    while sched.queue_depth() < 1:
        sched.submit("fill", block=False)
    t = threading.Thread(target=blocked_submit)
    t.start()
    time.sleep(0.05)
    assert not got, "submit must block while the queue is full"
    gate.set()
    t.join(timeout=10)
    assert got and got[0].future.result(timeout=10)[0] == "done"
    sched.stop()


def test_flush_exception_fails_only_that_flush():
    calls = []

    def flaky(items):
        calls.append(len(items))
        if len(calls) == 1:
            raise ValueError("boom")
        for it in items:
            it.complete("ok")

    sched = BatchScheduler(flaky, max_batch=2, max_wait_ms=1, max_queue=8)
    bad = sched.submit_many(["x", "y"])
    for it in bad:
        with pytest.raises(ValueError, match="boom"):
            it.future.result(timeout=10)
    good = sched.submit("z")
    assert good.future.result(timeout=10) == "ok"
    st = sched.stats()
    assert st["failed"] == 2 and st["completed"] == 1
    sched.stop()


def test_unanswered_items_are_failed_not_hung():
    def forgetful(items):
        items[0].complete("answered")   # leaves the rest unanswered

    sched = BatchScheduler(forgetful, max_batch=3, max_wait_ms=1, max_queue=8)
    items = sched.submit_many(["a", "b", "c"])
    assert items[0].future.result(timeout=10) == "answered"
    for it in items[1:]:
        with pytest.raises(RuntimeError, match="without answering"):
            it.future.result(timeout=10)
    sched.stop()


def test_stop_drains_queue():
    rec = Recorder()
    sched = BatchScheduler(rec, max_batch=64, max_wait_ms=60_000, max_queue=64)
    items = sched.submit_many(list(range(5)))
    sched.stop(timeout=10)                # deadline far away: drain flushes
    assert [it.future.result(timeout=1) for it in items] == \
        [("done", i) for i in range(5)]
    assert sched.stats()["flush_drain"] >= 1


def test_take_ready_pulls_into_running_flush():
    sched_box = {}

    def reusing(items):
        for it in items:
            it.complete("first")
        time.sleep(0.05)  # let late submits queue up
        for extra in sched_box["s"].take_ready(8):
            extra.complete("pulled")

    sched = BatchScheduler(reusing, max_batch=1, max_wait_ms=0, max_queue=8)
    sched_box["s"] = sched
    a = sched.submit("a")
    time.sleep(0.01)
    late = [sched.submit(f"late{i}") for i in range(3)]
    assert a.future.result(timeout=10) == "first"
    results = {it.future.result(timeout=10) for it in late}
    assert "pulled" in results            # at least one mid-flush admission
    assert sched.stats()["mid_flush_admissions"] >= 1
    sched.stop()


def test_take_ready_items_fail_with_flush_exception():
    sched_box = {}

    def pull_then_raise(items):
        for it in items:
            it.complete("first")
        deadline = time.perf_counter() + 5
        while not sched_box["s"].take_ready(1):
            if time.perf_counter() > deadline:
                raise AssertionError("late item never arrived")
            time.sleep(0.002)
        raise ValueError("mid-flush boom")

    sched = BatchScheduler(pull_then_raise, max_batch=1, max_wait_ms=0,
                           max_queue=8)
    sched_box["s"] = sched
    a = sched.submit("a")
    assert a.future.result(timeout=10) == "first"
    late = sched.submit("late")
    with pytest.raises(ValueError, match="mid-flush boom"):
        late.future.result(timeout=10)
    sched.stop()


def test_percentile_nearest_rank():
    """Nearest-rank index is ceil(q*n)-1 — the old int(q*n) sat one rank
    high (p50 of [1,2,3,4] came back 3)."""
    assert percentile([], 0.5) == 0.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.0) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 1.0) == 4.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.75) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.76) == 4.0
    assert percentile([7.0], 0.0) == 7.0
    assert percentile([7.0], 0.5) == 7.0
    assert percentile([7.0], 1.0) == 7.0
    # p99 of 100 sorted values is the 99th (index 98), not the maximum
    vals = [float(i) for i in range(1, 101)]
    assert percentile(vals, 0.99) == 99.0
    assert percentile(vals, 0.5) == 50.0


def test_latency_percentiles_and_validation():
    rec = Recorder()
    sched = BatchScheduler(rec, max_batch=2, max_wait_ms=1, max_queue=8)
    items = sched.submit_many(list(range(6)))
    for it in items:
        it.future.result(timeout=10)
    st = sched.stats()
    assert 0 < st["p50_latency_s"] <= st["p90_latency_s"] <= st["p99_latency_s"]
    assert st["avg_latency_s"] > 0
    sched.stop()
    with pytest.raises(ValueError):
        BatchScheduler(rec, max_batch=0)
    with pytest.raises(ValueError):
        BatchScheduler(rec, max_queue=0)
    with pytest.raises(ValueError):
        BatchScheduler(rec, max_wait_ms=-1)


def test_cancel_mid_flush_does_not_poison_cobatched_requests():
    """A caller cancelling its future while the flush is answering the batch
    must not fail the OTHER items of that flush (the old check-then-set
    window raised InvalidStateError inside the flush callback)."""
    def flush(items):
        # deterministic lost race: the "caller" cancels item 1 after the
        # flush picked up the batch but before it answers anything
        if len(items) > 1:
            items[1].future.cancel()
        for it in items:
            it.complete(("done", it.payload))

    sched = BatchScheduler(flush, max_batch=4, max_wait_ms=10_000, max_queue=8)
    items = sched.submit_many(["a", "b", "c", "d"])
    for i in (0, 2, 3):
        assert items[i].future.result(timeout=10) == ("done", items[i].payload)
    assert items[1].future.cancelled()
    st = sched.stats()
    assert st["cancelled"] == 1
    assert st["completed"] == 3 and st["failed"] == 0
    assert st["completed"] + st["failed"] + st["cancelled"] == st["submitted"]
    sched.stop()


def test_cancel_during_straggler_fail_does_not_kill_worker():
    """The post-flush straggler loop must survive a cancel racing it: a
    flush that leaves items unanswered AND sees them cancelled must not
    leak InvalidStateError out of _worker (which silently killed the
    thread and hung every later submit)."""
    def forgetful(items):
        for it in items[1:]:
            it.future.cancel()      # cancelled AND unanswered stragglers
        items[0].complete("answered")

    sched = BatchScheduler(forgetful, max_batch=3, max_wait_ms=1, max_queue=8)
    items = sched.submit_many(["a", "b", "c"])
    assert items[0].future.result(timeout=10) == "answered"
    for it in items[1:]:
        assert it.future.cancelled()
    # the worker must still be alive to serve this
    again = sched.submit("again")
    assert again.future.result(timeout=10) == "answered"
    st = sched.stats()
    assert st["cancelled"] == 2
    assert st["completed"] + st["failed"] + st["cancelled"] == st["submitted"]
    sched.stop()


def test_cancel_hammer_invariant_and_worker_survival():
    """Hammer thread cancels futures mid-flush while traffic flows: no
    InvalidStateError may escape, every non-cancelled item resolves, and
    completed + failed + cancelled == submitted at quiesce."""
    def flush(items):
        time.sleep(0.001)           # widen the cancel window
        for it in items:
            it.complete(("done", it.payload))

    sched = BatchScheduler(flush, max_batch=4, max_wait_ms=0.5, max_queue=512)
    all_items, items_lock = [], threading.Lock()
    stop_hammer = threading.Event()

    def hammer():
        i = 0
        while not stop_hammer.is_set():
            with items_lock:
                pending = [it for it in all_items if not it.future.done()]
            for it in pending[i % 2::2]:    # alternate halves
                it.future.cancel()
            i += 1
            time.sleep(0.0005)

    hammer_t = threading.Thread(target=hammer)
    hammer_t.start()
    try:
        for _round in range(30):
            items = sched.submit_many(list(range(8)))
            with items_lock:
                all_items.extend(items)
            time.sleep(0.002)
    finally:
        stop_hammer.set()
        hammer_t.join(timeout=10)
    # quiesce: every item must reach a terminal state
    for it in all_items:
        if not it.future.cancelled():
            assert it.future.result(timeout=10)[0] == "done"
    sched.stop(timeout=10)
    st = sched.stats()
    assert st["completed"] + st["failed"] + st["cancelled"] == st["submitted"]
    assert st["failed"] == 0, "cancel races must not fail co-batched items"
    assert st["submitted"] == 240
    # the worker survived the whole hammer session
    again = sched.submit("alive")
    assert again.future.result(timeout=10) == ("done", "alive")
    sched.stop()


def test_submit_many_counts_rejected_items_and_times_out():
    """submit_many parity with submit: a rejected run counts every ITEM in
    `rejected` (not 1 per call), and timeout= raises QueueFullError after
    the deadline instead of blocking forever."""
    gate = threading.Event()
    rec = Recorder(gate=gate)
    sched = BatchScheduler(rec, max_batch=1, max_wait_ms=0, max_queue=2)
    first = sched.submit("a")
    assert rec.entered.wait(10.0)   # worker gated: queue can only grow
    while sched.queue_depth() < 2:
        sched.submit("fill", block=False)
    with pytest.raises(QueueFullError):
        sched.submit_many(["x", "y", "z"], block=False)
    assert sched.stats()["rejected"] == 3
    t0 = time.perf_counter()
    with pytest.raises(QueueFullError):
        sched.submit_many(["x", "y"], timeout=0.05)
    assert time.perf_counter() - t0 < 5.0
    assert sched.stats()["rejected"] == 5
    gate.set()
    assert first.future.result(timeout=10) == ("done", "a")
    items = sched.submit_many(["p", "q"], timeout=10)
    for it in items:
        assert it.future.result(timeout=10)[0] == "done"
    sched.stop()


def test_restart_after_stop():
    """A stopped scheduler restarts transparently on the next submit —
    items can never sit in a queue with no worker to drain them."""
    rec = Recorder()
    sched = BatchScheduler(rec, max_batch=2, max_wait_ms=1, max_queue=8)
    a = sched.submit("a")
    assert a.future.result(timeout=10) == ("done", "a")
    sched.stop(timeout=10)
    b = sched.submit("b")
    assert b.future.result(timeout=10) == ("done", "b")
    sched.stop(timeout=10)


# ------------------------------------------------------------- SLO classes
def test_interactive_class_jumps_batch_backlog_and_meets_deadline():
    """A deadline-bearing interactive request submitted behind a full
    batch-class backlog rides the very next flush (priority order), not
    the end of the queue — so its SLO holds under batch pressure."""
    from repro.serve.scheduler import ClassSpec

    gate = threading.Event()
    rec = Recorder(gate=gate)
    sched = BatchScheduler(
        rec, max_batch=4, max_wait_ms=10_000, max_queue=64,
        classes=[ClassSpec("batch", priority=0, weight=1.0),
                 ClassSpec("interactive", priority=10, weight=1.0,
                           deadline_ms=5_000)])
    with sched:
        batch_items = sched.submit_many([f"b{i}" for i in range(12)],
                                        klass="batch")
        # first flush (4 batch items) is now blocked on the gate; the
        # other 8 batch items sit queued ahead of the interactive arrival
        assert rec.entered.wait(10.0)
        hot = sched.submit("hot", klass="interactive")
        gate.set()
        assert hot.future.result(timeout=10) == ("done", "hot")
        for it in batch_items:
            it.future.result(timeout=10)
    # the interactive item outran the 8 queued batch items: it is in the
    # flush right after the gated one
    assert "hot" in rec.batches[1]
    assert hot.deadline_missed is False
    st = sched.stats()
    assert st["class_completed"]["interactive"] == 1
    assert st["class_deadline_missed"]["interactive"] == 0
    assert st["per_class_p99"]["interactive"] > 0.0


def test_slo_class_flushes_early_without_cobatch_traffic():
    """A lone deadline-class request flushes after ~deadline/4, not after
    the scheduler-wide max_wait."""
    from repro.serve.scheduler import ClassSpec

    rec = Recorder()
    sched = BatchScheduler(
        rec, max_batch=64, max_wait_ms=30_000, max_queue=8,
        classes=[ClassSpec("interactive", priority=1, deadline_ms=200)])
    t0 = time.perf_counter()
    item = sched.submit("solo", klass="interactive")
    assert item.future.result(timeout=10) == ("done", "solo")
    assert time.perf_counter() - t0 < 10.0   # not the 30s scheduler wait
    st = sched.stats()
    assert st["flush_slo"] == 1
    assert item.deadline_at is not None
    sched.stop()


def test_weighted_fair_admission_caps_lower_tier_only():
    """A lower-priority flood hits its weighted quota and backpressures
    while the top tier still admits freely."""
    from repro.serve.scheduler import ClassSpec

    gate = threading.Event()
    rec = Recorder(gate=gate)
    sched = BatchScheduler(
        rec, max_batch=4, max_wait_ms=10_000, max_queue=9,
        classes=[ClassSpec("batch", priority=0, weight=1.0),
                 ClassSpec("interactive", priority=10, weight=1.0)])
    with sched:
        # park the flush thread on 4 default-class items so later
        # submissions stay queued
        parked = sched.submit_many(list(range(4)))
        assert rec.entered.wait(10.0)
        # quota for the lower tier: max_queue * w/total_w = 9/3 = 3
        flood = [sched.submit(f"b{i}", klass="batch", block=False)
                 for i in range(3)]
        with pytest.raises(QueueFullError):
            sched.submit("b3", klass="batch", block=False)
        # the top tier is NOT capped by the flood
        hot = sched.submit("hot", klass="interactive", block=False)
        gate.set()
        for it in parked + flood + [hot]:
            it.future.result(timeout=10)
    st = sched.stats()
    assert st["rejected"] == 1
    assert st["class_completed"]["batch"] == 3
    assert st["class_completed"]["interactive"] == 1
