"""Integration: prefill + decode == full forward, per family (fp32 exact)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.models import lm


def _fp32(tree):
    return jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, tree)


@pytest.mark.parametrize("name", ["qwen1.5-32b", "gemma2-27b", "dbrx-132b",
                                  "deepseek-moe-16b", "mamba2-780m", "zamba2-7b"])
def test_prefill_decode_matches_forward(name):
    cfg = get_reduced(name)
    params = _fp32(lm.init_lm(cfg, jax.random.PRNGKey(0)))
    B, T, EXTRA = 2, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + EXTRA), 0, cfg.vocab)
    full = lm.lm_forward(cfg, params, toks, q_chunk=4, kv_chunk=4, ssd_chunk=4)
    lg, st = lm.prefill_forward(cfg, params, toks[:, :T], q_chunk=4, kv_chunk=4,
                                ssd_chunk=4)
    st = lm.pad_prefill_caches(cfg, st, T + EXTRA)
    st = st._replace(caches=_fp32(st.caches))
    errs = [float(jnp.abs(lg - full[:, T - 1]).max())]
    for t in range(EXTRA):
        lg, st = lm.decode_step(cfg, params, toks[:, T + t:T + t + 1], st)
        errs.append(float(jnp.abs(lg - full[:, T + t]).max()))
    assert max(errs) < 1e-4, f"{name}: {errs}"


def test_encoder_prefill_returns_frame_logits():
    cfg = get_reduced("hubert-xlarge")
    params = _fp32(lm.init_lm(cfg, jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    logits, state = lm.prefill_forward(cfg, params, x, q_chunk=4, kv_chunk=4)
    assert logits.shape == (2, 16, cfg.vocab)
    assert state is None
