"""Sampler-layer invariants: store views, seeded sampling, compaction.

Property tests (shim-compatible hypothesis strategies) cover the ISSUE-9
sampler contract: every sampled edge exists in the parent graph, per-hop
fanout caps hold, sampling is bit-deterministic in (seed, batch), and
compaction relabels round-trip through their inverse maps. The
partitioned-store client must be BIT-identical to the monolithic store —
that equivalence is what lets the two-subprocess bench verify the
cross-host exchange against a local reference.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.plan_repair import EdgeDelta
from repro.data.graphs import seed_batches, seed_splits
from repro.sampling import (
    Frontier, GraphStore, PartitionedStoreClient, sample_frontier,
)
from conftest import make_powerlaw_csr


def _store(n=80, seed=0, normalize=False):
    return GraphStore.build(make_powerlaw_csr(n=n, seed=seed),
                            normalize=normalize)


# ------------------------------------------------------------------- store
def test_store_views_are_mirrors():
    store = _store(normalize=True)
    assert np.array_equal(store.in_adj.to_dense(),
                          store.out_adj.to_dense().T)


def test_store_in_adj_is_transpose_of_input():
    g = make_powerlaw_csr(n=50, seed=2)
    store = GraphStore.build(g)
    assert np.array_equal(store.in_adj.to_dense(), g.to_dense().T)


def test_store_apply_delta_updates_both_views():
    store = _store(n=40, seed=1)
    # an edge u -> v not present yet
    dense = store.out_adj.to_dense()
    u, v = np.argwhere(dense == 0)[0]
    ver = store.apply_delta(EdgeDelta(insert_src=[u], insert_dst=[v],
                                      insert_val=[2.5]))
    assert ver == 1 and store.version == 1
    assert store.out_adj.to_dense()[u, v] == 2.5
    assert store.in_adj.to_dense()[v, u] == 2.5
    assert np.array_equal(store.in_adj.to_dense(),
                          store.out_adj.to_dense().T)


def test_store_listener_gets_touched_aggregation_rows():
    store = _store(n=30, seed=3)
    seen = []
    store.add_listener(lambda rows, delta: seen.append(rows))
    dense = store.out_adj.to_dense()
    u, v = np.argwhere(dense == 0)[0]
    store.apply_delta(EdgeDelta(insert_src=[u], insert_dst=[v]))
    assert len(seen) == 1
    assert np.array_equal(seen[0], np.array([v]))  # agg row = destination


def test_store_rejects_unowned_nodes():
    store = _store(n=40)
    shard = store.partition(2)[0]
    hi = shard.node_range[1]
    with pytest.raises(ValueError, match="outside owned range"):
        shard.sample_in_neighbors(np.array([hi]), None)


def test_partition_shards_preserve_owned_rows():
    store = _store(n=61, seed=5)   # odd n: uneven ranges
    shards = store.partition(3)
    full = store.in_adj.to_dense()
    covered = 0
    for sh in shards:
        lo, hi = sh.node_range
        d = sh.in_adj.to_dense()
        assert np.array_equal(d[lo:hi], full[lo:hi])
        assert d[:lo].sum() == 0 and d[hi:].sum() == 0
        assert np.array_equal(sh.in_adj.to_dense(),
                              sh.out_adj.to_dense().T)
        covered += hi - lo
    assert covered == store.n_nodes


# ------------------------------------------------------------- seed helpers
def test_seed_splits_disjoint_and_deterministic():
    a1, b1, c1 = seed_splits(100, [0.5, 0.3, 0.2], seed=4)
    a2, b2, _ = seed_splits(100, [0.5, 0.3, 0.2], seed=4)
    assert np.array_equal(a1, a2) and np.array_equal(b1, b2)
    assert (a1 & b1).sum() == 0 and (a1 & c1).sum() == 0
    assert a1.sum() == 50 and b1.sum() == 30 and c1.sum() == 20
    other, = seed_splits(100, [0.5], seed=5)
    assert not np.array_equal(a1, other)


def test_seed_splits_rejects_over_unity():
    with pytest.raises(ValueError):
        seed_splits(10, [0.8, 0.4])


def test_seed_batches_deterministic_and_complete():
    mask, = seed_splits(64, [0.5], seed=0)
    run1 = list(seed_batches(mask, 10, seed=3, epochs=2))
    run2 = list(seed_batches(mask, 10, seed=3, epochs=2))
    assert len(run1) == len(run2) == 2 * 4  # ceil(32/10) per epoch
    for b1, b2 in zip(run1, run2):
        assert np.array_equal(b1, b2)
    # each epoch covers every seed exactly once
    epoch1 = np.sort(np.concatenate(run1[:4]))
    assert np.array_equal(epoch1, np.flatnonzero(mask))
    # different seed -> different order
    run3 = list(seed_batches(mask, 10, seed=4))
    assert any(not np.array_equal(a, b) for a, b in zip(run1, run3))


def test_seed_batches_no_shuffle_is_sequential():
    ids = np.array([5, 1, 9])
    out = list(seed_batches(ids, 2, shuffle=False))
    assert np.array_equal(out[0], [5, 1]) and np.array_equal(out[1], [9])


# ------------------------------------------------------- sampler properties
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500),
       sample_seed=st.integers(0, 500),
       fanout=st.sampled_from([1, 2, 4, None]),
       seeds=st.lists(st.integers(0, 59), min_size=1, max_size=8))
def test_sampled_edges_exist_in_parent_and_caps_hold(seed, sample_seed,
                                                     fanout, seeds):
    store = _store(n=60, seed=seed)
    dense = store.in_adj.to_dense()
    f = sample_frontier(store.sample_in_neighbors, np.array(seeds),
                        [fanout, fanout], seed=sample_seed)
    for block in f.blocks:
        g = block.graph
        assert g.n_rows == len(block.dst_nodes)
        assert g.n_cols == len(block.src_nodes)
        for i in range(g.n_rows):
            lo, hi = g.rowptr[i], g.rowptr[i + 1]
            if fanout is not None:
                assert hi - lo <= fanout          # per-hop cap
            v = block.dst_nodes[i]
            for j in g.colidx[lo:hi]:
                u = block.src_nodes[j]
                # edge exists in parent (dense sums parallel edges, so
                # existence is the right check on a multigraph)
                assert dense[v, u] != 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500),
       seeds=st.lists(st.integers(0, 49), min_size=1, max_size=6),
       fanout=st.sampled_from([1, 3, None]))
def test_sampling_bit_deterministic(seed, seeds, fanout):
    store = _store(n=50, seed=1)
    f1 = sample_frontier(store.sample_in_neighbors, np.array(seeds),
                         [fanout, fanout], seed=seed)
    f2 = sample_frontier(store.sample_in_neighbors, np.array(seeds),
                         [fanout, fanout], seed=seed)
    assert f1.content_key() == f2.content_key()
    for l1, l2 in zip(f1.layers, f2.layers):
        assert np.array_equal(l1, l2)


def test_sampling_independent_of_batch_composition():
    # node v's sampled neighborhood must not depend on which OTHER seeds
    # share its batch (rng keys on (seed, hop, node) only)
    store = _store(n=60, seed=7)
    alone = sample_frontier(store.sample_in_neighbors, np.array([11]),
                            [2], seed=9)
    grouped = sample_frontier(store.sample_in_neighbors,
                              np.array([11, 40, 3]), [2], seed=9)
    b_a, b_g = alone.blocks[0], grouped.blocks[0]
    i = int(np.searchsorted(b_g.dst_nodes, 11))
    lo, hi = b_g.graph.rowptr[i], b_g.graph.rowptr[i + 1]
    got = np.sort(b_g.src_nodes[b_g.graph.colidx[lo:hi]])
    lo_a, hi_a = b_a.graph.rowptr[0], b_a.graph.rowptr[1]
    exp = np.sort(b_a.src_nodes[b_a.graph.colidx[lo_a:hi_a]])
    assert np.array_equal(got, exp)


@settings(max_examples=10, deadline=None)
@given(seeds=st.lists(st.integers(0, 79), min_size=1, max_size=8),
       sample_seed=st.integers(0, 100))
def test_capped_fanout_matches_numpy_reference(seeds, sample_seed):
    """The service sampler must equal an independent numpy reference:
    rng([seed, hop, node]) over the in-adjacency row, sorted slots."""
    store = _store(n=80, seed=4)
    fanout = 2
    f = sample_frontier(store.sample_in_neighbors, np.array(seeds),
                        [fanout], seed=sample_seed)
    b = f.blocks[0]
    a = store.in_adj
    for i, v in enumerate(b.dst_nodes):
        lo, hi = int(a.rowptr[v]), int(a.rowptr[v + 1])
        d = hi - lo
        if d <= fanout:
            idx = np.arange(lo, hi)
        else:
            rng = np.random.default_rng([sample_seed, 0, int(v)])
            idx = lo + np.sort(rng.choice(d, size=fanout, replace=False))
        exp = a.colidx[idx]
        got = b.src_nodes[
            b.graph.colidx[b.graph.rowptr[i]:b.graph.rowptr[i + 1]]]
        assert np.array_equal(np.asarray(exp), np.asarray(got))


def test_sampling_with_replacement_caps_and_exists():
    store = _store(n=40, seed=6)
    f = sample_frontier(store.sample_in_neighbors, np.arange(10), [3],
                        seed=1, replace=True)
    dense = store.in_adj.to_dense()
    b = f.blocks[0]
    for i in range(b.graph.n_rows):
        lo, hi = b.graph.rowptr[i], b.graph.rowptr[i + 1]
        v = b.dst_nodes[i]
        if int(store.in_degrees(np.array([v]))[0]) > 0:
            assert hi - lo == 3    # with replacement: always exactly fanout
        for j in b.graph.colidx[lo:hi]:
            assert dense[v, b.src_nodes[j]] != 0


# ------------------------------------------------------------- compaction
@settings(max_examples=10, deadline=None)
@given(seeds=st.lists(st.integers(0, 59), min_size=1, max_size=6),
       fanout=st.sampled_from([2, None]))
def test_compaction_relabel_roundtrip(seeds, fanout):
    store = _store(n=60, seed=8)
    f = sample_frontier(store.sample_in_neighbors, np.array(seeds),
                        [fanout, fanout], seed=0)
    assert isinstance(f, Frontier)
    for k, block in enumerate(f.blocks):
        # id maps are sorted-unique and equal the layer sets
        assert np.array_equal(block.dst_nodes, f.layers[k])
        assert np.array_equal(block.src_nodes, f.layers[k + 1])
        # local -> global -> local round-trips
        local = np.arange(len(block.src_nodes))
        assert np.array_equal(block.to_local_src(block.src_nodes[local]),
                              local)
        local_d = np.arange(len(block.dst_nodes))
        assert np.array_equal(block.to_local_dst(block.dst_nodes[local_d]),
                              local_d)
    # layers nest
    for a, b in zip(f.layers[:-1], f.layers[1:]):
        assert np.all(np.isin(a, b))
    # seed rows recover the caller's order
    rows = f.seed_rows()
    assert np.array_equal(f.layers[0][rows], f.seeds)


def test_full_fanout_block_rows_keep_parent_order():
    # within a compacted row, edges keep the parent CSR's relative order —
    # the property that makes full-fanout aggregation bit-exact
    store = _store(n=50, seed=2, normalize=True)
    f = sample_frontier(store.sample_in_neighbors, np.arange(50), [None])
    b = f.blocks[0]
    a = store.in_adj
    assert np.array_equal(b.dst_nodes, np.arange(50))
    for v in range(50):
        lo, hi = a.rowptr[v], a.rowptr[v + 1]
        got = b.src_nodes[
            b.graph.colidx[b.graph.rowptr[v]:b.graph.rowptr[v + 1]]]
        assert np.array_equal(got, a.colidx[lo:hi])
        assert np.array_equal(
            b.graph.values[b.graph.rowptr[v]:b.graph.rowptr[v + 1]],
            a.values[lo:hi])


# ------------------------------------------------------- partitioned client
def _partitioned(store, n_parts):
    shards = store.partition(n_parts)
    bounds = [sh.node_range[0] for sh in shards] + [store.n_nodes]
    remote = {r: shards[r].sample_in_neighbors for r in range(1, n_parts)}
    return PartitionedStoreClient(shards[0], bounds, remote, 0)


@settings(max_examples=8, deadline=None)
@given(seeds=st.lists(st.integers(0, 69), min_size=1, max_size=6),
       fanout=st.sampled_from([2, None]),
       n_parts=st.sampled_from([2, 3]))
def test_partitioned_client_bit_identical_to_monolith(seeds, fanout,
                                                      n_parts):
    store = _store(n=70, seed=9)
    client = _partitioned(store, n_parts)
    fm = sample_frontier(store.sample_in_neighbors, np.array(seeds),
                         [fanout, fanout], seed=5)
    fp = sample_frontier(client.sample_in_neighbors, np.array(seeds),
                         [fanout, fanout], seed=5)
    assert fm.content_key() == fp.content_key()


def test_partitioned_client_routes_by_ownership():
    store = _store(n=60, seed=3)
    client = _partitioned(store, 2)
    # seeds straddle the partition boundary, so both shards must serve
    f = sample_frontier(client.sample_in_neighbors,
                        np.array([1, 58]), [None])
    assert f.blocks[0].n_edges > 0
    assert client.remote_edges > 0 and client.local_edges > 0
    with pytest.raises(KeyError, match="no channel"):
        PartitionedStoreClient(
            store.partition(2)[0], [0, 30, 60], {}, 0
        ).sample_in_neighbors(np.array([45]), None)


def test_partitioned_client_validates_bounds():
    store = _store(n=60)
    shards = store.partition(2)
    with pytest.raises(ValueError, match="bounds slot"):
        PartitionedStoreClient(shards[1], [0, 30, 60], {}, 0)
