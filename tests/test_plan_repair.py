"""Versioned plan repair: delta semantics, chained keys, and the core
acceptance property — a repaired plan is SpMM-OUTPUT-identical to a full
rebuild of the post-delta graph, through both batched kernel backends.

``tests/conftest.py`` wires the ``hypothesis`` import to the real library
when installed and to the deterministic shim in ``tests/_compat``
otherwise, so the property tests run everywhere.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from repro.core.graph import csr_apply_edge_delta, csr_from_edges, gcn_normalize
from repro.core.plan_cache import PartitionConfig, build_partition_plan
from repro.core.plan_repair import (EdgeDelta, apply_and_repair,
                                    delta_chain_hash, repair_plan)
from repro.kernels.ops import spmm_batched

from conftest import make_powerlaw_csr

BACKENDS = ["blocked", "pallas"]

# a small bound so tiny test graphs cross the pattern/split boundary
SMALL_CFG = PartitionConfig(max_block_warps=4, max_warp_nzs=2)  # deg_bound 8


def _dense(g):
    a = np.zeros((g.n_rows, g.n_cols), np.float64)
    row = np.repeat(np.arange(g.n_rows), np.diff(g.rowptr))
    np.add.at(a, (row, g.colidx.astype(np.int64)), g.values.astype(np.float64))
    return a


def _spmm(plan, x, backend):
    """Kernel output re-ordered back to original rows (kernels emit in the
    plan's sorted-position order; ``inv_perm[row]`` is the row's position)."""
    y = spmm_batched([plan.slabs], [jnp.asarray(x, jnp.float32)],
                     [plan.n_rows], backend=backend)[0]
    return np.asarray(y)[np.asarray(plan.inv_perm)]


def _check_equivalent(pv, g_new, cfg):
    """The acceptance property: repaired plan == fresh build == dense, on
    every batched backend, for a random feature block."""
    x = np.random.default_rng(3).normal(size=(g_new.n_cols, 6))
    fresh = build_partition_plan(g_new, cfg)
    ref = _dense(g_new) @ x
    for backend in BACKENDS:
        got = _spmm(pv.plan, x, backend)
        np.testing.assert_allclose(got, ref, atol=1e-3, rtol=1e-3,
                                   err_msg=f"repair vs dense ({backend})")
        np.testing.assert_allclose(got, _spmm(fresh, x, backend),
                                   atol=1e-4, rtol=1e-4,
                                   err_msg=f"repair vs rebuild ({backend})")


def _graph(n=60, seed=0):
    return gcn_normalize(make_powerlaw_csr(n=n, seed=seed))


# --------------------------------------------------------- delta semantics

def test_delta_insert_delete_roundtrip():
    g = csr_from_edges(np.array([0, 1, 2]), np.array([1, 2, 0]), 8)
    delta = EdgeDelta(insert_src=[0, 1], insert_dst=[5, 7],
                      insert_val=[2.0, 3.0],
                      delete_src=[2], delete_dst=[0])
    g2 = delta.apply(g)
    d = _dense(g2) - _dense(g)
    assert d[0, 5] == pytest.approx(2.0)
    assert d[1, 7] == pytest.approx(3.0)
    assert d[2, 0] == pytest.approx(-1.0)
    assert g2.nnz == g.nnz + 1
    assert g.nnz == 3  # g untouched


def test_duplicate_insert_error_and_replace():
    g = csr_from_edges(np.array([0, 1]), np.array([1, 2]), 4)
    with pytest.raises(ValueError):
        csr_apply_edge_delta(g, insert_src=[0], insert_dst=[1])
    g2 = csr_apply_edge_delta(g, insert_src=[0], insert_dst=[1],
                              insert_val=[9.0], on_duplicate="replace")
    assert g2.nnz == g.nnz  # degree unchanged: value overwritten in place
    assert _dense(g2)[0, 1] == pytest.approx(9.0)
    # same (src, dst) twice in one insert list: last occurrence wins
    g3 = csr_apply_edge_delta(g, insert_src=[0, 0], insert_dst=[3, 3],
                              insert_val=[1.0, 7.0], on_duplicate="replace")
    assert _dense(g3)[0, 3] == pytest.approx(7.0)


def test_missing_delete_error_and_ignore():
    g = csr_from_edges(np.array([0, 1]), np.array([1, 2]), 4)
    with pytest.raises(ValueError):
        csr_apply_edge_delta(g, delete_src=[2], delete_dst=[3])
    g2 = csr_apply_edge_delta(g, delete_src=[2], delete_dst=[3],
                              on_missing="ignore")
    assert g2.nnz == g.nnz


def test_delete_removes_every_copy():
    # builders do not dedup: (0, 1) twice, one delete removes both copies
    g = csr_from_edges(np.array([0, 0, 1]), np.array([1, 1, 2]), 4)
    assert g.nnz == 3
    g2 = csr_apply_edge_delta(g, delete_src=[0], delete_dst=[1])
    assert g2.nnz == 1
    assert _dense(g2)[0, 1] == 0.0


def test_delta_range_validation():
    g = csr_from_edges(np.array([0]), np.array([1]), 4)
    with pytest.raises(ValueError):
        csr_apply_edge_delta(g, insert_src=[g.n_rows], insert_dst=[0])
    with pytest.raises(ValueError):
        csr_apply_edge_delta(g, insert_src=[0], insert_dst=[g.n_cols])
    with pytest.raises(ValueError):
        csr_apply_edge_delta(g, delete_src=[-1], delete_dst=[0])
    with pytest.raises(ValueError):
        EdgeDelta(insert_src=[0, 1], insert_dst=[2])  # length mismatch


# ------------------------------------------------------------ chained keys

def test_delta_chain_hash_deterministic_and_sensitive():
    d1 = EdgeDelta(insert_src=[0], insert_dst=[1])
    d1b = EdgeDelta(insert_src=[0], insert_dst=[1])
    d2 = EdgeDelta(insert_src=[0], insert_dst=[2])
    h = delta_chain_hash("parent", d1)
    assert h == delta_chain_hash("parent", d1b)   # same delta -> same key
    assert h != delta_chain_hash("parent", d2)    # different delta
    assert h != delta_chain_hash("other", d1)     # different parent
    assert h != "parent"
    # policy strings are part of the key (they change the transition)
    d1c = EdgeDelta(insert_src=[0], insert_dst=[1], on_duplicate="replace")
    assert h != delta_chain_hash("parent", d1c)


def test_repair_uses_chained_key_and_version_chain():
    g = _graph()
    plan = build_partition_plan(g, SMALL_CFG)
    delta = EdgeDelta(insert_src=[1], insert_dst=[2],
                      on_duplicate="replace")
    g2, pv = apply_and_repair(plan, g, delta)
    assert pv.version == plan.version + 1 == pv.plan.version
    assert pv.plan.graph_hash == delta_chain_hash(plan.graph_hash, delta)
    assert pv.plan.graph_hash != plan.graph_hash
    _check_equivalent(pv, g2, SMALL_CFG)


def test_empty_delta_advances_version_only():
    g = _graph()
    plan = build_partition_plan(g, SMALL_CFG)
    pv = repair_plan(plan, g, g, np.empty(0, np.int64), graph_hash="k2")
    assert pv.repaired and pv.version == plan.version + 1
    assert pv.plan.slabs["colidx"] is plan.slabs["colidx"]  # by reference


# ---------------------------------------------------- repair == rebuild

def test_repair_smoke_fixed_seed():
    """Fast CI smoke: one mixed delta, both backends, dense oracle."""
    g = _graph(n=80, seed=4)
    plan = build_partition_plan(g, SMALL_CFG)
    rng = np.random.default_rng(1)
    rows = rng.choice(g.n_rows, 6, replace=False)
    eids = rng.choice(g.nnz, 4, replace=False)
    delta = EdgeDelta(
        insert_src=rows, insert_dst=(rows * 3 + 1) % g.n_cols,
        insert_val=rng.normal(size=6).astype(np.float32),
        delete_src=np.searchsorted(g.rowptr, eids, side="right") - 1,
        delete_dst=g.colidx[eids],
        on_duplicate="replace", on_missing="ignore")
    g2, pv = apply_and_repair(plan, g, delta)
    assert pv.repaired
    _check_equivalent(pv, g2, SMALL_CFG)


def test_repair_row_crossing_deg_bound():
    """A row pushed across deg_bound moves between the pattern blocks and
    the split chunks; repair must re-emit it on the right side."""
    bound = SMALL_CFG.deg_bound
    n = 24
    src = np.repeat(np.arange(n), 3)
    dst = (src + np.tile(np.arange(1, 4), n)) % n   # row r: r+1, r+2, r+3
    g = csr_from_edges(src, dst, n)
    # grow row 5 to exactly the bound, then one past it
    plan = build_partition_plan(g, SMALL_CFG)
    up = EdgeDelta(insert_src=[5] * (bound - 3),
                   insert_dst=(5 + 4 + np.arange(bound - 3)) % n)
    g2, pv = apply_and_repair(plan, g, up)
    assert np.diff(g2.rowptr)[5] == bound
    _check_equivalent(pv, g2, SMALL_CFG)
    over = EdgeDelta(insert_src=[5], insert_dst=[(5 + bound + 2) % n])
    g3, pv2 = apply_and_repair(pv.plan, g2, over)
    assert np.diff(g3.rowptr)[5] > bound
    _check_equivalent(pv2, g3, SMALL_CFG)
    # and back down below the bound
    down = EdgeDelta(delete_src=[5] * 4,
                     delete_dst=g3.colidx[g3.rowptr[5]:g3.rowptr[5] + 4],
                     on_missing="ignore")
    g4, pv3 = apply_and_repair(pv2.plan, g3, down)
    _check_equivalent(pv3, g4, SMALL_CFG)


def test_repair_empties_and_refills_degree_bucket():
    """Deleting the only row of a degree class empties its bucket; a later
    insert refills it from a zero-degree row."""
    src = np.array([0, 0, 0, 1, 2])          # row 0 is the only deg-3 row
    g = csr_from_edges(src, np.array([1, 2, 3, 0, 1]), 4)
    plan = build_partition_plan(g, SMALL_CFG)
    wipe = EdgeDelta(delete_src=[0, 0, 0], delete_dst=[1, 2, 3])
    g2, pv = apply_and_repair(plan, g, wipe)
    assert np.diff(g2.rowptr)[0] == 0
    _check_equivalent(pv, g2, SMALL_CFG)
    refill = EdgeDelta(insert_src=[3, 3], insert_dst=[0, 2])  # deg-0 row 3
    g3, pv2 = apply_and_repair(pv.plan, g2, refill)
    _check_equivalent(pv2, g3, SMALL_CFG)


def test_churn_threshold_falls_back_to_rebuild():
    g = _graph(n=40)
    plan = build_partition_plan(g, SMALL_CFG)
    rows = np.arange(g.n_rows)               # touch every row
    delta = EdgeDelta(insert_src=rows, insert_dst=(rows + 1) % g.n_cols,
                      on_duplicate="replace")
    g2, pv = apply_and_repair(plan, g, delta, churn_threshold=0.25)
    assert not pv.repaired and "churn" in pv.reason
    assert pv.version == plan.version + 1
    _check_equivalent(pv, g2, SMALL_CFG)


def test_fragmentation_guard_recompacts_chained_repairs():
    """Every repair appends blocks; the guard must eventually trade the
    accumulated fragments for one full rebuild."""
    g = _graph(n=50, seed=2)
    plan = build_partition_plan(g, SMALL_CFG)
    saw_fragmentation_rebuild = False
    cur_g = g
    for step in range(80):
        r = int(np.random.default_rng(step).integers(0, g.n_rows))
        delta = EdgeDelta(insert_src=[r], insert_dst=[(r + step) % g.n_cols],
                          on_duplicate="replace")
        cur_g, pv = apply_and_repair(plan, cur_g, delta, churn_threshold=1.0)
        plan = pv.plan
        if not pv.repaired:
            assert "fragmentation" in pv.reason
            saw_fragmentation_rebuild = True
            break
    assert saw_fragmentation_rebuild, "guard never fired over 80 repairs"
    _check_equivalent(pv, cur_g, SMALL_CFG)


def test_repair_validates_inputs():
    g = _graph(n=30)
    plan = build_partition_plan(g, SMALL_CFG)
    with pytest.raises(ValueError):          # touched out of range
        repair_plan(plan, g, g, [g.n_rows], graph_hash="x")
    g_grown = csr_from_edges(np.array([0]), np.array([1]), g.n_rows + 1)
    with pytest.raises(ValueError):          # row count changed
        repair_plan(plan, g, g_grown, [0], graph_hash="x")
    g_other = csr_from_edges(np.array([0]), np.array([1]), g.n_rows)
    with pytest.raises(ValueError):          # plan built for other nnz
        repair_plan(plan, g_other, g_other, [0], graph_hash="x")


# ----------------------------------------------------- property (hypothesis)

@pytest.mark.slow
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       steps=st.integers(min_value=1, max_value=4),
       mode=st.sampled_from(["tpu", "paper"]))
def test_repair_chain_matches_rebuild_property(seed, steps, mode):
    """Random delta sequences over a power-law graph: after every step the
    repaired chain must agree with a dense oracle AND a fresh rebuild on
    both batched backends."""
    cfg = PartitionConfig(max_block_warps=4, max_warp_nzs=2, mode=mode)
    rng = np.random.default_rng(seed)
    g = gcn_normalize(make_powerlaw_csr(n=int(rng.integers(30, 90)),
                                        seed=seed))
    plan = build_partition_plan(g, cfg)
    for _ in range(steps):
        k_ins = int(rng.integers(0, 8))
        k_del = int(rng.integers(0, min(8, g.nnz)))
        eids = rng.choice(g.nnz, k_del, replace=False)
        delta = EdgeDelta(
            insert_src=rng.integers(0, g.n_rows, k_ins),
            insert_dst=rng.integers(0, g.n_cols, k_ins),
            insert_val=rng.normal(size=k_ins).astype(np.float32),
            delete_src=np.searchsorted(g.rowptr, eids, side="right") - 1,
            delete_dst=g.colidx[eids],
            on_duplicate="replace", on_missing="ignore")
        g, pv = apply_and_repair(plan, g, delta)
        assert pv.version == plan.version + 1
        plan = pv.plan
        _check_equivalent(pv, g, cfg)
