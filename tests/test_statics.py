"""Regression tests for the invariant analyzer and the lock-order witness.

The seeded-violation corpus lives in tests/fixtures/statics/: each bad_*
file must trip exactly its intended rule(s), the clean/suppressed files
must pass, and the CLI must exit 0 on the real tree but non-zero on the
corpus.
"""

import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.statics import ALL_RULES, analyze_paths
from repro.statics.witness import InstrumentedLock, LockWitness
from repro.statics import witness as witness_mod

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "statics"
REPO = Path(__file__).resolve().parent.parent
CLI = REPO / "scripts" / "check_invariants.py"

EXPECTED = {
    "bad_lock_discipline.py": {"locked-call-outside-lock"},
    "bad_guarded_attr.py": {"guarded-attr-outside-lock"},
    "bad_blocking_under_lock.py": {"blocking-call-under-lock"},
    "bad_pallas_static_args.py": {"pallas-static-args"},
    "bad_pallas_traced_branch.py": {"pallas-traced-branch"},
    "bad_pallas_closure.py": {"pallas-closure-numpy"},
    "bad_pallas_tile.py": {"pallas-tile-divisibility"},
    "bad_future_settlement.py": {"future-leak", "future-double-settle"},
    "bad_suppression.py": {"bad-suppression", "blocking-call-under-lock"},
}


# ---------------------------------------------------------------- analyzer

@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_seeded_violation_caught(name):
    findings, n_files = analyze_paths([FIXTURES / name])
    assert n_files == 1
    assert {f.rule for f in findings} == EXPECTED[name], [f.format() for f in findings]


@pytest.mark.parametrize("name", ["clean_serving.py", "suppressed.py"])
def test_clean_fixture_passes(name):
    findings, _ = analyze_paths([FIXTURES / name])
    assert findings == [], [f.format() for f in findings]


def test_corpus_covers_every_rule():
    findings, _ = analyze_paths([FIXTURES])
    caught = {f.rule for f in findings}
    missing = set(ALL_RULES) - caught
    assert not missing, f"no fixture triggers: {sorted(missing)}"


def test_static_args_flags_both_params():
    findings, _ = analyze_paths([FIXTURES / "bad_pallas_static_args.py"])
    msgs = " ".join(f.message for f in findings)
    assert "'n_rows'" in msgs and "'f_tile'" in msgs


def test_rule_filter():
    findings, _ = analyze_paths(
        [FIXTURES], rules={"locked-call-outside-lock"}
    )
    assert findings and all(f.rule == "locked-call-outside-lock" for f in findings)


def test_cli_clean_on_tree():
    r = subprocess.run(
        [sys.executable, str(CLI)], capture_output=True, text=True, cwd=REPO
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_fails_on_corpus():
    r = subprocess.run(
        [sys.executable, str(CLI), str(FIXTURES)],
        capture_output=True, text=True, cwd=REPO,
    )
    assert r.returncode == 1, r.stdout + r.stderr
    for rule in ALL_RULES:
        assert rule in r.stdout, f"corpus run did not report {rule}"


# ----------------------------------------------------------------- witness

def _run_threads(*fns):
    threads = [threading.Thread(target=fn) for fn in fns]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


def test_witness_detects_toy_cycle():
    w = LockWitness()
    a = InstrumentedLock(threading.Lock(), w, "A")
    b = InstrumentedLock(threading.Lock(), w, "B")

    def ab():
        with a:
            with b:
                pass

    def ba():
        with b:
            with a:
                pass

    # run sequentially on two threads: records A->B then B->A, a cycle
    # in the order graph even though no run ever deadlocks
    _run_threads(ab)
    _run_threads(ba)
    assert w.cycles
    with pytest.raises(AssertionError, match="acquisition-order cycle"):
        w.assert_no_cycles()


def test_witness_consistent_order_is_clean():
    w = LockWitness()
    a = InstrumentedLock(threading.Lock(), w, "A")
    b = InstrumentedLock(threading.Lock(), w, "B")

    def ab():
        with a:
            with b:
                pass

    _run_threads(ab, ab)
    _run_threads(ab)
    assert not w.cycles
    w.assert_no_cycles()


def test_witness_rlock_reentry_not_a_cycle():
    w = LockWitness()
    r = InstrumentedLock(threading.RLock(), w, "R")
    with r:
        with r:  # reentrant: must not self-edge
            pass
    assert not w.cycles


def test_witness_condition_wait_releases_lock():
    """cond.wait() built on an instrumented lock must pop the held stack
    during the blocking window, so a notifier taking (other -> cond) does
    not fabricate an inversion against the waiter's (cond -> nothing)."""
    w = LockWitness()
    lk = InstrumentedLock(threading.RLock(), w, "cond-lock")
    cond = threading.Condition(lk)
    ready = threading.Event()
    woke = []

    def waiter():
        with cond:
            ready.set()
            cond.wait(timeout=5)
            woke.append(True)

    def notifier():
        assert ready.wait(5)
        with cond:
            cond.notify_all()

    _run_threads(waiter, notifier)
    assert woke == [True]
    assert not w.cycles


def test_witness_install_patches_repro_factories():
    if witness_mod.current() is not None:
        pytest.skip("session-level witness already installed")
    w = witness_mod.install(module_prefix=__name__)
    try:
        assert isinstance(threading.Lock(), InstrumentedLock)
        assert isinstance(threading.RLock(), InstrumentedLock)
    finally:
        witness_mod.uninstall()
    # restored: plain factories again
    assert not isinstance(threading.Lock(), InstrumentedLock)


def test_witness_on_real_scheduler():
    """End-to-end: instrumented locks under the real BatchScheduler —
    validates the Condition delegation protocol (wait/notify through an
    InstrumentedLock) and that the serving path is cycle-free."""
    from repro.serve.scheduler import BatchScheduler

    pre = witness_mod.current()
    w = pre if pre is not None else witness_mod.install()
    try:
        def flush(items):
            for it in items:
                it.complete(("ok", it.payload))

        sched = BatchScheduler(flush, max_batch=4, max_wait_ms=1, max_queue=64)
        with sched:
            items = sched.submit_many(list(range(16)))
            results = [it.future.result(timeout=10) for it in items]
        assert results == [("ok", i) for i in range(16)]
        w.assert_no_cycles()
    finally:
        if pre is None:
            witness_mod.uninstall()
