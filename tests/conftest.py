import os
import sys

# Tests must see the single real CPU device (the 512-device override is
# confined to launch/dryrun.py per the dry-run spec).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Prefer the real hypothesis (installed in CI via requirements-dev.txt); fall
# back to the deterministic shim in tests/_compat for hermetic environments.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))

import numpy as np
import pytest


@pytest.fixture(scope="session", autouse=True)
def _lock_order_witness():
    """Opt-in lockdep: REPRO_LOCK_WITNESS=1 wraps every lock created by
    repro.* modules for the whole session and fails teardown if the
    acquisition-order graph contains a cycle (potential deadlock, even if
    no run ever deadlocked). Nightly runs the threaded test modules under
    this; the default path patches nothing."""
    if os.environ.get("REPRO_LOCK_WITNESS") != "1":
        yield
        return
    from repro.statics import witness as _witness

    wit = _witness.install()
    yield
    _witness.uninstall()
    wit.assert_no_cycles()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_powerlaw_csr(n=200, seed=0, zipf=1.8, cap=500, n_cols=None):
    """Shared helper: small power-law CSR graph."""
    from repro.core.graph import csr_from_edges
    rng = np.random.default_rng(seed)
    deg = np.minimum(rng.zipf(zipf, n), cap)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n_cols or n, len(src))
    return csr_from_edges(src, dst, n_cols or n)


def make_wide_csr(n_rows, n_cols, nnz, seed):
    """Sparse rectangular graph: few rows, a huge feature-row space — the
    shape that overflows the resident VMEM budget while staying CI-cheap."""
    from repro.core.graph import csr_from_edges, gcn_normalize
    rng = np.random.default_rng(seed)
    src = np.sort(rng.integers(0, n_rows, nnz))
    dst = rng.integers(0, n_cols, nnz)
    return gcn_normalize(csr_from_edges(src, dst, n_cols))
