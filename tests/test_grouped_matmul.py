"""Grouped-GEMM Pallas kernel vs oracle: shape/dtype/group sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.ops import grouped_matmul_blocked
from repro.kernels.ref import grouped_matmul_ref


def _case(E, K, N, mt, sizes, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    gsz = np.asarray(sizes, np.int32)
    M = int(gsz.sum())
    x = rng.normal(size=(M, K)).astype(dtype) * 0.2
    w = rng.normal(size=(E, K, N)).astype(dtype) * 0.2
    be = np.repeat(np.arange(E), gsz // mt).astype(np.int32)
    out = grouped_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(be),
                         m_tile=mt, interpret=True)
    ref = grouped_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(gsz))
    return np.asarray(out), np.asarray(ref)


@pytest.mark.parametrize("K,N,mt", [(64, 64, 32), (256, 128, 128), (128, 96, 16),
                                    (512, 256, 64)])
def test_shapes(K, N, mt):
    out, ref = _case(4, K, N, mt, [mt * 2, 0, mt, mt * 3])
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=1e-3)


def test_empty_and_single_groups():
    out, ref = _case(5, 64, 64, 16, [0, 16, 0, 0, 48])
    np.testing.assert_allclose(out, ref, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(e=st.integers(1, 6), nblocks=st.lists(st.integers(0, 4), min_size=1,
                                             max_size=6), seed=st.integers(0, 99))
def test_hypothesis_groups(e, nblocks, seed):
    nblocks = (nblocks + [1] * e)[:e]
    if sum(nblocks) == 0:
        nblocks[0] = 1
    mt = 16
    out, ref = _case(e, 32, 32, mt, [b * mt for b in nblocks], seed=seed)
    np.testing.assert_allclose(out, ref, atol=2e-3, rtol=1e-3)


def test_blocked_twin_matches_kernel():
    rng = np.random.default_rng(4)
    E, K, N, mt = 3, 64, 48, 8
    gsz = np.array([16, 8, 24], np.int32)
    M = int(gsz.sum())
    x = rng.normal(size=(M, K)).astype(np.float32)
    w = rng.normal(size=(E, K, N)).astype(np.float32)
    be = np.repeat(np.arange(E), gsz // mt).astype(np.int32)
    a = grouped_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(be), m_tile=mt)
    b = grouped_matmul_blocked(jnp.asarray(x), jnp.asarray(w), jnp.asarray(be),
                               m_tile=mt)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
