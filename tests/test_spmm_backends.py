"""All SpMM backends agree; 18-benchmark-graph analogues (reduced sizes)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import gcn_normalize
from repro.core.spmm import make_accel_spmm
from repro.data.graphs import BENCHMARK_GRAPHS, make_power_law_graph
from repro.kernels.ref import csr_spmm_ref
from conftest import make_powerlaw_csr


def test_all_backends_agree():
    g = gcn_normalize(make_powerlaw_csr(n=300, seed=5))
    X = jnp.asarray(np.random.default_rng(0).normal(size=(300, 64)),
                    dtype=jnp.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, X))
    op = make_accel_spmm(g, with_baselines=True)
    for be in ["pallas", "blocked", "segment", "warp", "dense"]:
        out = np.asarray(op(X, backend=be))
        np.testing.assert_allclose(out, ref, atol=1e-3, rtol=1e-3,
                                   err_msg=f"backend {be}")


@pytest.mark.parametrize("name", sorted(BENCHMARK_GRAPHS))
def test_benchmark_graph_analogues(name):
    """Every Table-I graph analogue (scaled to ~1/500 size for CI speed):
    correctness of the full preprocessing + blocked backend."""
    n_full, e_full, scale = BENCHMARK_GRAPHS[name]
    n = max(50, n_full // 500)
    e = max(100, int(e_full * scale) // 500)
    g = gcn_normalize(make_power_law_graph(n, e, seed=hash(name) % 2**31))
    X = jnp.asarray(np.random.default_rng(1).normal(size=(g.n_rows, 32)),
                    dtype=jnp.float32)
    ref = np.asarray(csr_spmm_ref(g.rowptr, g.colidx, g.values, X))
    op = make_accel_spmm(g, backend="blocked")
    np.testing.assert_allclose(np.asarray(op(X)), ref, atol=1e-3, rtol=1e-3)
